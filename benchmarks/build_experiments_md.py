"""Assemble the generated sections of EXPERIMENTS.md from the dry-run
JSONs: §Dry-run summary, §Roofline tables (both meshes), and the
hillclimb before/after table. Static narrative sections live in
EXPERIMENTS.md directly; this script rewrites only the blocks between
the AUTOGEN markers.

  PYTHONPATH=src python benchmarks/build_experiments_md.py
"""
from __future__ import annotations

import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline_report import fmt_s, load, summary, table

HERE = os.path.dirname(__file__)
MD = os.path.join(HERE, "..", "EXPERIMENTS.md")


def hillclimb_table() -> str:
    cells = [
        ("smollm-360m", "train_4k",
         [("baseline", "16x16__baseline"), ("dp_all", "16x16__step1"),
          ("final", "16x16")]),
        ("kimi-k2-1t-a32b", "train_4k",
         [("baseline", "16x16__baseline"), ("shard_map MoE + ep_moe",
                                            "16x16__step1"),
          ("final", "16x16")]),
        ("mixtral-8x7b", "train_4k",
         [("baseline", "16x16__baseline"), ("shard_map MoE + moe_tp",
                                            "16x16__step1"),
          ("final", "16x16")]),
        ("jamba-1.5-large-398b", "train_4k",
         [("baseline", "16x16__baseline"), ("shard_map MoE (EP-16)",
                                            "16x16__step1"),
          ("final", "16x16")]),
    ]
    lines = ["| cell | config | compute | memory | collective | "
             "dominant | fraction |",
             "|---|---|---|---|---|---|---|"]
    for arch, shape, steps in cells:
        for label, mesh in steps:
            res = load(mesh).get((arch, shape))
            if not res or "roofline" not in res:
                lines.append(f"| {arch} × {shape} | {label} | — | — | — "
                             f"| *missing* | |")
                continue
            t = res["roofline"]
            frac = t["compute_s"] / max(t["compute_s"], t["memory_s"],
                                        t["collective_s"])
            lines.append(
                f"| {arch} × {shape} | {label} | "
                f"{fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
                f"{fmt_s(t['collective_s'])} | {t['dominant']} | "
                f"{100*frac:.1f}% |")
    return "\n".join(lines)


def replace_block(text: str, tag: str, content: str) -> str:
    start = f"<!-- AUTOGEN:{tag} -->"
    end = f"<!-- /AUTOGEN:{tag} -->"
    pattern = re.compile(re.escape(start) + ".*?" + re.escape(end),
                         re.S)
    return pattern.sub(start + "\n" + content + "\n" + end, text)


def main():
    with open(MD) as f:
        text = f.read()
    s1 = summary("16x16")
    s2 = summary("2x16x16")
    dry = (f"Single-pod (16×16 = 256 chips): **{s1['ok']} cells "
           f"compiled**, {s1['skipped']} skipped (long_500k on pure "
           f"full-attention archs), {s1['errors']} errors.\n\n"
           f"Multi-pod (2×16×16 = 512 chips): **{s2['ok']} cells "
           f"compiled**, {s2['skipped']} skipped, {s2['errors']} "
           f"errors.")
    text = replace_block(text, "dryrun_summary", dry)
    text = replace_block(text, "roofline_16x16", table("16x16"))
    text = replace_block(text, "roofline_2x16x16", table("2x16x16"))
    text = replace_block(text, "hillclimb", hillclimb_table())
    with open(MD, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated:",
          {"16x16": s1, "2x16x16": s2})


if __name__ == "__main__":
    main()
