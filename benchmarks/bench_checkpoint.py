"""Delta-checkpoint store: save/restore latency, chain-reconstruction
depth scaling, storage split (snapshots vs deltas) per policy."""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import DeltaCheckpointStore, DeltaPolicy
from repro.config import TrainConfig, reduced
from repro.configs import get_config
from repro.runtime import init_train_state


def run():
    rows = []
    cfg = reduced(get_config("smollm-360m"))
    tcfg = TrainConfig(param_dtype="float32")
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    n_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(state))
    for kind in ("periodic", "opcount", "similarity"):
        with tempfile.TemporaryDirectory() as d:
            store = DeltaCheckpointStore(
                d, DeltaPolicy(kind=kind, period=5, op_budget=5e6,
                               drift=0.01))
            t0 = time.perf_counter()
            s = state
            for step in range(12):
                s = jax.tree.map(
                    lambda x: x + 0.001 if jnp.issubdtype(
                        x.dtype, jnp.floating) else x, s)
                store.save(step, s)
            save_ms = (time.perf_counter() - t0) / 12 * 1e3
            t0 = time.perf_counter()
            store.restore(0, state)   # deepest chain
            restore_ms = (time.perf_counter() - t0) * 1e3
            b = store.storage_bytes()
            rows.append((f"ckpt/{kind}/save_ms", save_ms,
                         f"state={n_bytes/1e6:.1f}MB"))
            rows.append((f"ckpt/{kind}/restore_depth12_ms", restore_ms,
                         f"snapshots={len(store.manifest['snapshots'])}"))
            rows.append((f"ckpt/{kind}/bytes_snapshots", b["snapshots"],
                         ""))
            rows.append((f"ckpt/{kind}/bytes_deltas", b["deltas"], ""))
    return rows


def main():
    for name, val, note in run():
        print(f"{name},{val},{note}")


if __name__ == "__main__":
    main()
