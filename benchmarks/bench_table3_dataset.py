"""Paper Table 3: synthetic dataset characteristics (targets vs
achieved by our generator).

Audited against the segmented-by-default store: ``store.stats()``
counts ops across sealed segments + open tail, matching the targets
to <0.1% rel err."""
from __future__ import annotations

import time

TARGETS = {"inserted_nodes": 5063, "inserted_edges": 41067,
           "removed_edges": 18280, "total_ops": 64410}


def run(seed=7):
    from repro.core.generate import paper_table3
    t0 = time.perf_counter()
    store = paper_table3(seed=seed)
    dt = time.perf_counter() - t0
    stats = store.stats()
    rows = []
    for k, target in TARGETS.items():
        got = stats[k]
        rows.append((f"table3/{k}", got, target,
                     abs(got - target) / target))
    return rows, dt, store


def main():
    rows, dt, _ = run()
    for name, got, target, relerr in rows:
        print(f"{name},{got},target={target},rel_err={relerr:.4f}")
    print(f"table3/build_seconds,{dt:.2f},")


if __name__ == "__main__":
    main()
