"""Edge-slot vs dense execution at scale: qps and peak bytes vs N.

The tentpole claim of the O(E) path: per-query serving cost on the
dense layout is O(N²) (the LWW cell scatter materializes two i32[N, N]
index planes per reconstruction), on the edge layout O(E + M).  This
bench sweeps N ∈ {4k, 16k, 64k} at fixed E/N (≈ m_attach·2) and runs
the same forced-two-phase degree/num_edges workload through both
layouts, recording queries/sec and peak memory:

* ``est_peak_bytes`` — analytic per-program scatter footprint
  (dense: 2·4·N²·B_group + N²; edge: (2·4·e_cap + 5·4·M)·B_group),
* ``max_rss_bytes``  — measured ru_maxrss of the worker process.

A dense config whose estimate exceeds ``--mem-budget`` is recorded as
**infeasible** and skipped — at N=64k the dense scatter alone wants
~32 GB/query, which is the point: the edge path runs the same workload
in a few hundred MB.  Each (layout, N) config runs in its own
subprocess so RSS is per-config and device arrays are truly freed.

  PYTHONPATH=src python benchmarks/bench_edge_scaling.py [--fast|--smoke]

``--smoke`` is the CI sanity tier: one small edge config, no artifact
refresh.  Results land in ``benchmarks/BENCH_edge_scaling.json``
(schema: benchmarks/artifacts.py).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
OUT_JSON = os.path.join(HERE, "BENCH_edge_scaling.json")

SIZES = (4096, 16384, 65536)
E_OVER_N = 8  # m_attach=4 → ~8 live edge slots per node


def _est_peak_bytes(layout: str, n: int, e_cap: int, delta_cap: int,
                    b_group: int) -> int:
    """Analytic scatter footprint of one two-phase group program."""
    if layout == "dense":
        # first/last i32[N, N] per vmapped query + the bool adjacency
        return 2 * 4 * n * n * b_group + n * n
    # first/last i32[E] per query + the masked log columns (5 × i32[M])
    return (2 * 4 * e_cap + 5 * 4 * delta_cap) * b_group + e_cap


def _workload(t_cur: int, n_nodes: int, b: int, seed: int = 0):
    """Forced-two-phase degree/num_edges mix with *distinct* times, so
    the engine's reconstruction cache cannot shortcut the replay."""
    import numpy as np

    from repro.core.plans import Query
    rng = np.random.default_rng(seed)
    ts = rng.choice(np.arange(1, max(t_cur, b + 1)), size=b,
                    replace=False)
    qs = []
    for i, t in enumerate(sorted(int(t) for t in ts)):
        v = int(rng.integers(0, n_nodes))
        if i % 4 == 3:
            qs.append(Query("point", "global", "num_edges", t_k=t))
        else:
            qs.append(Query("point", "node", "degree", t_k=t, v=v))
    return qs


def worker(layout: str, n_nodes: int, b: int, reps: int) -> dict:
    import resource

    from repro.core.generate import EvolutionParams, build_store

    t0 = time.perf_counter()
    store = build_store(
        n_nodes,
        EvolutionParams(m_attach=E_OVER_N // 2, lam_extra=0.5,
                        lam_remove=0.5, events_per_unit=max(
                            8, n_nodes // 256)),
        seed=7, layout=layout)
    build_s = time.perf_counter() - t0
    eng = store.engine()
    delta_cap = store.delta().capacity
    e_cap = eng.current_edge.e_cap if eng.current_edge is not None else 0
    queries = _workload(store.t_cur, n_nodes, b)

    kw = dict(plan="two_phase", layout=layout)
    eng.evaluate_many(queries, **kw)              # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        eng.evaluate_many(queries, **kw)
    dt = (time.perf_counter() - t0) / reps
    # the executor groups by (kind, scope, measure): batch per program
    b_group = max(len(idx) for idx in (
        [q for q in queries if q.scope == "node"],
        [q for q in queries if q.scope == "global"]))
    return {
        "layout": layout,
        "n_nodes": n_nodes,
        "qps": b / dt,
        "us_per_query": dt / b * 1e6,
        "n_queries": b,
        "reps": reps,
        "t_cur": int(store.t_cur),
        "total_ops": int(store.stats()["total_ops"]),
        "e_cap": int(e_cap),
        "delta_cap": int(delta_cap),
        "build_s": build_s,
        "est_peak_bytes": _est_peak_bytes(layout, store.n_cap, e_cap,
                                          delta_cap, b_group),
        "max_rss_bytes": resource.getrusage(
            resource.RUSAGE_SELF).ru_maxrss * 1024,
    }


def spawn(layout: str, n_nodes: int, args) -> dict:
    if ROOT not in sys.path:
        sys.path.insert(0, ROOT)
    from benchmarks.artifacts import merge_xla_flags
    env = dict(os.environ)
    # single-device workload; append to (don't clobber) pre-set flags
    env["XLA_FLAGS"] = merge_xla_flags(
        env.get("XLA_FLAGS"),
        "--xla_force_host_platform_device_count=1")
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    b = args.dense_queries if layout == "dense" else args.edge_queries
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           "--layout", layout, "--n-nodes", str(n_nodes),
           "--n-queries", str(b), "--reps", str(args.reps)]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"worker {layout}@{n_nodes} failed:\n"
                           f"{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout.splitlines()[-1])


def run(args) -> tuple[list, dict]:
    rows, configs = [], []
    for n in args.sizes:
        for layout in ("dense", "edge"):
            # rough dense estimate before paying the subprocess: the
            # group batch is ~3/4 of the query count (node-degree share)
            b = (args.dense_queries if layout == "dense"
                 else args.edge_queries)
            est = _est_peak_bytes(layout, n, 16 * n, 16 * n,
                                  max(1, 3 * b // 4))
            if est > args.mem_budget:
                configs.append({"layout": layout, "n_nodes": n,
                                "infeasible": True,
                                "est_peak_bytes": est})
                rows.append((f"edge_scaling/{layout}@N={n}", "infeasible",
                             f"est {est / 1e9:.1f} GB > budget "
                             f"{args.mem_budget / 1e9:.1f} GB"))
                continue
            res = spawn(layout, n, args)
            configs.append(res)
            rows.append((f"edge_scaling/{layout}@N={n}",
                         f"{res['qps']:.2f} qps",
                         f"{res['us_per_query']:.0f} us/query, "
                         f"rss {res['max_rss_bytes'] / 1e9:.2f} GB"))
    speedups = {}
    by = {(c["layout"], c["n_nodes"]): c for c in configs}
    for n in args.sizes:
        d, e = by.get(("dense", n)), by.get(("edge", n))
        if d and e and not d.get("infeasible") and not e.get("infeasible"):
            s = d["us_per_query"] / e["us_per_query"]
            speedups[str(n)] = s
            rows.append((f"edge_scaling/speedup@N={n}", f"{s:.1f}x",
                         "dense us/query ÷ edge us/query"))
        elif (d and d.get("infeasible") and e
                and not e.get("infeasible")):
            speedups[str(n)] = None
            rows.append((f"edge_scaling/speedup@N={n}", "inf",
                         "dense infeasible, edge "
                         f"{e['us_per_query']:.0f} us/query"))
    results = {"configs": configs, "speedup_per_query": speedups,
               "e_over_n": E_OVER_N, "mem_budget": args.mem_budget,
               "sizes": list(args.sizes)}
    return rows, results


def write_json(results: dict) -> None:
    if ROOT not in sys.path:
        sys.path.insert(0, ROOT)
    from benchmarks.artifacts import make_artifact, write_artifact
    write_artifact(OUT_JSON, make_artifact("edge_scaling", results,
                                           device_count=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sizes / fewer reps, no artifact")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sanity: ONE small edge config, no artifact")
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--layout", default="edge")
    ap.add_argument("--n-nodes", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--mem-budget", type=int, default=8 << 30,
                    help="skip configs whose est. scatter bytes exceed "
                         "this (records them as infeasible)")
    args = ap.parse_args()

    if args.worker:
        print(json.dumps(worker(args.layout, args.n_nodes,
                                args.n_queries, args.reps or 2)))
        return

    if args.smoke:
        args.sizes = (2048,)
        args.dense_queries, args.edge_queries, args.reps = 4, 8, 1
        # smoke covers exactly one config: the edge path
        res = spawn("edge", args.sizes[0], args)
        assert res["qps"] > 0 and res["layout"] == "edge", res
        print(f"edge_scaling/smoke@N={args.sizes[0]},"
              f"{res['qps']:.2f} qps,"
              f"rss {res['max_rss_bytes'] / 1e9:.2f} GB")
        print("edge_scaling smoke OK")
        return

    args.sizes = (1024, 4096) if args.fast else SIZES
    args.dense_queries = 4
    args.edge_queries = 8 if args.fast else 16
    args.reps = args.reps or (1 if args.fast else 2)

    rows, results = run(args)
    for name, val, note in rows:
        print(f"{name},{val},{note}")
    if args.fast:
        print(f"--fast: skipping {OUT_JSON} refresh")
    else:
        write_json(results)
        print(f"wrote {OUT_JSON}")


if __name__ == "__main__":
    main()
