"""Reconstruction engines at increasing depth: the paper-faithful
sequential replay vs the vectorized last-writer-wins (beyond-paper) vs
the Pallas delta_apply kernel (interpret mode on CPU — reported for
completeness, its target is TPU), and the effect of materialized
snapshots with time- vs operation-based selection.

Audited against the segmented-by-default store: ``store.delta()`` and
``snapshot_at`` route through the segmented view unchanged, so these
numbers remain comparable across the segmentation PRs."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.generate import EvolutionParams, build_store
from repro.core.materialize import MaterializationPolicy
from repro.core.reconstruct import reconstruct_dense, reconstruct_sequential


def _timeit(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e3


def run(n_nodes=1024, reps=3, with_kernel=False):
    store = build_store(n_nodes, EvolutionParams(
        m_attach=4, lam_extra=1.0, lam_remove=1.2), seed=2)
    d = store.delta()
    rows = []
    for frac in (0.25, 0.5, 0.75, 1.0):
        t_q = int(store.t_cur * (1 - frac))
        seq = _timeit(lambda: reconstruct_sequential(
            store.current, d, store.t_cur, t_q).adj, reps)
        vec = _timeit(lambda: reconstruct_dense(
            store.current, d, store.t_cur, t_q).adj, reps)
        rows.append((f"recon/sequential@{frac}", seq))
        rows.append((f"recon/vectorized@{frac}", vec))
        rows.append((f"recon/speedup@{frac}", seq / vec))
        if with_kernel:
            from repro.kernels.delta_apply import delta_apply
            k = _timeit(lambda: delta_apply(
                store.current, d, store.t_cur, t_q, tile=256,
                cap=1 << 14)[0].adj, reps)
            rows.append((f"recon/pallas_interpret@{frac}", k))

    # materialization: reconstruct at random times with/without snapshots
    store_m = build_store(n_nodes, EvolutionParams(
        m_attach=4, lam_extra=1.0, lam_remove=1.2), seed=2,
        policy=MaterializationPolicy(kind="opcount", op_budget=2000))
    rng = np.random.default_rng(0)
    ts = [int(x) for x in rng.integers(0, store_m.t_cur, 5)]
    for sel in ("time", "ops"):
        tot = 0.0
        for t in ts:
            tot += _timeit(lambda: store_m.snapshot_at(
                t, use_materialized=True, selection=sel).adj, 1)
        rows.append((f"recon/materialized_{sel}", tot / len(ts)))
    # windowed (temporal-index) reconstruction: anchor selection now
    # shrinks the work the LWW scatter does
    for sel in ("time", "ops"):
        tot = 0.0
        for t in ts:
            tot += _timeit(lambda: store_m.snapshot_at(
                t, use_materialized=True, selection=sel,
                windowed=True).adj, 1)
        rows.append((f"recon/materialized_{sel}_windowed", tot / len(ts)))
    tot = 0.0
    for t in ts:
        tot += _timeit(lambda: store_m.snapshot_at(
            t, use_materialized=False).adj, 1)
    rows.append(("recon/no_materialization", tot / len(ts)))
    return rows


def main():
    for name, ms in run():
        print(f"{name},{ms*1e3:.1f},")


if __name__ == "__main__":
    main()
