"""Durability cost: WAL'd ingest overhead + crash-recovery time.

Two questions the persistence layer (``repro/persist``) must answer
with numbers:

1. **What does durability cost on the write path?**  The same
   closed-loop append/swap workload is driven through ``GraphSession``
   in three modes — in-memory, durable with per-record fsync (the
   default contract: an acknowledged op survives kill -9), and durable
   without fsync (page-cache durability; survives process death, not
   power loss).  Recorded per mode: ingest drain throughput (ops
   absorbed into served epochs per second) and swap latency.  The
   acceptance bar (ISSUE 7): WAL-on drain stays within **1.5x** of
   in-memory (``overhead_ratio`` in the artifact).

2. **What does recovery cost as history grows?**  For each history
   length H: open a checkpointed root (manifest + mmap'd segments +
   base-record-only WAL — the fast path ``close()`` buys) and a
   crashed root (same history, ~one epoch of WAL tail to replay).
   Recorded: open seconds for both paths vs H.

``--smoke`` runs the down-scaled sweep only; the CI fast lane guards
its ``wal_drain_ops_per_sec`` via
``scripts/check_bench_baseline.py --bench persistence``.

  PYTHONPATH=src python benchmarks/bench_persistence.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import os
import shutil
import statistics
import sys
import tempfile
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, HERE)

OUT_JSON = os.path.join(HERE, "BENCH_persistence.json")

FULL = dict(n_cap=128, per_unit=512, epoch_units=8, n_epochs=10,
            warmup_epochs=2, hist_units=(64, 256, 1024),
            replay_units=8)
SMOKE = dict(n_cap=128, per_unit=512, epoch_units=8, n_epochs=4,
             warmup_epochs=1, hist_units=(16, 64), replay_units=8)


def _churn_unit(rng, n_cap, t, per_unit):
    from repro.core.delta import ADD_EDGE, REM_EDGE
    from repro.core.store import Op
    ops = []
    for _ in range(per_unit):
        u, v = int(rng.integers(0, n_cap)), int(rng.integers(0, n_cap))
        if u == v:
            continue
        kind = ADD_EDGE if rng.random() < 0.55 else REM_EDGE
        ops.append(Op(kind, u, v, t))
    return ops


def _open_session(mode: str, cfg: dict, root: str | None):
    from repro.api import GraphSession
    if mode == "memory":
        return GraphSession(n_cap=cfg["n_cap"])
    return GraphSession.open(root, n_cap=cfg["n_cap"],
                             fsync=(mode == "wal"))


def measure_ingest(mode: str, cfg: dict) -> dict:
    """Closed-loop append/swap drain throughput for one mode."""
    import numpy as np

    from repro.core.delta import ADD_NODE
    from repro.core.store import Op

    rng = np.random.default_rng(7)
    n_cap, per_unit = cfg["n_cap"], cfg["per_unit"]
    root = tempfile.mkdtemp(prefix=f"bench_persist_{mode}_") \
        if mode != "memory" else None
    try:
        session = _open_session(mode, cfg, root)
        session.ingest([Op(ADD_NODE, v, v, 1) for v in range(n_cap)])
        session.flush()
        t = 1

        def one_epoch():
            nonlocal t
            batch = []
            for _ in range(cfg["epoch_units"]):
                t += 1
                batch += _churn_unit(rng, n_cap, t, per_unit)
            # one append per epoch: clients batch writes (the serving
            # frontend already coalesces), so the WAL pays one fsync'd
            # record per batch, not one per op
            n = session.ingest(batch)
            rec = session.flush()
            return n, rec.seconds

        for _ in range(cfg["warmup_epochs"]):
            one_epoch()
        t0 = time.perf_counter()
        results = [one_epoch() for _ in range(cfg["n_epochs"])]
        wall = time.perf_counter() - t0
        session.close()
    finally:
        if root is not None:
            shutil.rmtree(root, ignore_errors=True)
    absorbed = sum(n for n, _ in results)
    return {
        "drain_ops_per_sec": absorbed / wall,
        "swap_median_s": statistics.median(s for _, s in results),
        "ops_absorbed": absorbed,
    }


def measure_recovery(hist_units: int, cfg: dict) -> dict:
    """Open-time for a checkpointed vs a crashed (replaying) root."""
    import numpy as np

    from repro.api import GraphSession
    from repro.core.delta import ADD_NODE
    from repro.core.store import Op

    rng = np.random.default_rng(11)
    n_cap, per_unit = cfg["n_cap"], cfg["per_unit"]
    root = tempfile.mkdtemp(prefix="bench_persist_rec_")
    try:
        with GraphSession.open(root, n_cap=n_cap) as s:
            s.ingest([Op(ADD_NODE, v, v, 1) for v in range(n_cap)])
            t = 1
            batch = []
            for i in range(hist_units):
                t += 1
                batch += _churn_unit(rng, n_cap, t, per_unit)
                if (i + 1) % cfg["epoch_units"] == 0:
                    s.ingest(batch)
                    batch = []
                    s.flush()
            if batch:
                s.ingest(batch)
            s.flush()
            history_ops = s.store.stats()["total_ops"]
        GraphSession.open(root).close()   # warm the open path's jits
        # clean, checkpointed open: manifest + mmap + base-record WAL
        t0 = time.perf_counter()
        s2 = GraphSession.open(root)
        open_ckpt = time.perf_counter() - t0
        # now crash it mid-epoch: durable WAL tail, no checkpoint
        for _ in range(cfg["replay_units"]):
            t += 1
            s2.ingest(_churn_unit(rng, n_cap, t, per_unit))
        s2.live.swap()                    # seals + checkpoints
        for _ in range(cfg["replay_units"]):
            t += 1
            s2.ingest(_churn_unit(rng, n_cap, t, per_unit))
        del s2                            # kill -9 stand-in: no close()
        t0 = time.perf_counter()
        s3 = GraphSession.open(root)
        open_replay = time.perf_counter() - t0
        s3.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return {
        "history_ops": int(history_ops),
        "open_checkpointed_s": open_ckpt,
        "open_with_replay_s": open_replay,
    }


def run_sweep(cfg: dict) -> dict:
    out: dict = {"config": dict(cfg)}
    # all modes run the identical workload, so one throwaway pass
    # warms every jit shape the measured passes will hit — without it
    # the first mode pays all the compiles and the comparison is noise
    measure_ingest("memory", cfg)
    for mode in ("memory", "wal", "wal_nofsync"):
        out[mode] = measure_ingest(mode, cfg)
        print(f"{mode:11s}: drain "
              f"{out[mode]['drain_ops_per_sec']:9.0f} ops/s, swap p50 "
              f"{out[mode]['swap_median_s'] * 1e3:7.2f} ms", flush=True)
    out["overhead_ratio"] = (out["memory"]["drain_ops_per_sec"]
                             / out["wal"]["drain_ops_per_sec"])
    out["wal_drain_ops_per_sec"] = out["wal"]["drain_ops_per_sec"]
    recovery = {}
    for hu in cfg["hist_units"]:
        cell = measure_recovery(hu, cfg)
        recovery[str(cell["history_ops"])] = cell
        print(f"recovery hist={cell['history_ops']:>6d} ops: "
              f"checkpointed {cell['open_checkpointed_s'] * 1e3:7.1f} ms, "
              f"with replay {cell['open_with_replay_s'] * 1e3:7.1f} ms",
              flush=True)
    out["recovery"] = recovery
    print(f"WAL ingest overhead: {out['overhead_ratio']:.2f}x over "
          "in-memory (acceptance bar 1.5x)", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled sweep only (CI fast lane)")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()

    from artifacts import make_artifact, write_artifact

    results = {"smoke": run_sweep(SMOKE)}
    if not args.smoke:
        results["full"] = run_sweep(FULL)
    write_artifact(args.out, make_artifact("persistence", results))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
