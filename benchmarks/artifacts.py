"""Shared BENCH_*.json artifact schema.

Every benchmark that persists results writes through here so the
artifacts are machine-comparable across PRs:

  {
    "name":         benchmark name ("engine_batch", "distributed", ...),
    "git_sha":      short sha of the work tree (or "unknown"),
    "device_count": visible jax devices when the bench ran,
    "schema":       1,
    "results":      benchmark-specific payload (qps numbers etc.)
  }

``write_artifact`` refreshes the file atomically (write + rename) so a
crashed bench never leaves a truncated artifact behind.
"""
from __future__ import annotations

import json
import os
import subprocess

HERE = os.path.dirname(os.path.abspath(__file__))
SCHEMA_VERSION = 1


def git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=HERE, capture_output=True, text=True,
                             timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def make_artifact(name: str, results: dict,
                  device_count: int | None = None) -> dict:
    if device_count is None:
        import jax
        device_count = len(jax.devices())
    return {
        "name": name,
        "git_sha": git_sha(),
        "device_count": device_count,
        "schema": SCHEMA_VERSION,
        "results": results,
    }


def merge_xla_flags(existing: str | None, *forced: str) -> str:
    """Append ``forced`` XLA flags to a pre-set ``XLA_FLAGS`` value
    instead of clobbering it (benchmark re-exec paths run under CI
    lanes that already export flags).  A forced flag replaces any
    existing setting of the same ``--flag=`` key; everything else the
    caller had set is preserved."""
    keys = {f.split("=", 1)[0] for f in forced}
    kept = [f for f in (existing or "").split()
            if f.split("=", 1)[0] not in keys]
    return " ".join(kept + list(forced))


def write_artifact(path: str, artifact: dict) -> str:
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(artifact, fh, indent=2)
        fh.write("\n")
    os.replace(tmp, path)
    return path
