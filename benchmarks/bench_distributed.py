"""Multi-device serving throughput (sharded evaluate_many).

Measures queries/sec of the engine's batched executor at device_count
∈ {1, 8} on the same workload as bench_engine_batch (mixed node-centric
point / diff / agg stream plus a two-phase global slice, auto-planned).
The device count is locked at first jax init, so the driver re-execs
itself once per device count with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and collects one
JSON line per worker; results land in ``benchmarks/BENCH_distributed.json``
(schema: benchmarks/artifacts.py).

On a CPU host the 8 forced devices share the machine's cores, so the
measured speedup depends on how many cores are free (anywhere from
< 1x under load to a few x on an idle multi-core host) — the artifact
records it honestly; what matters for real parts is that the
per-device work drops to 1/D.

  PYTHONPATH=src python benchmarks/bench_distributed.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
OUT_JSON = os.path.join(HERE, "BENCH_distributed.json")
DEVICE_COUNTS = (1, 8)


def _workload(store, n_queries: int, seed: int = 0):
    import numpy as np

    from repro.core.plans import Query
    rng = np.random.default_rng(seed)
    tc = store.t_cur
    qs = []
    for i in range(n_queries):
        v = int(rng.integers(0, store.n_cap))
        t1 = int(rng.integers(1, max(2, tc)))
        t2 = min(tc, t1 + int(rng.integers(0, 8)))
        kind = ("point", "diff", "agg", "global")[i % 4]
        if kind == "point":
            qs.append(Query("point", "node", "degree", t_k=t1, v=v))
        elif kind == "diff":
            qs.append(Query("diff", "node", "degree", t_k=t1, t_l=t2, v=v))
        elif kind == "agg":
            qs.append(Query("agg", "node", "degree", t_k=t1, t_l=t2, v=v,
                            agg="mean"))
        else:
            qs.append(Query("point", "global", "num_edges", t_k=t1))
    return qs


def worker(n_nodes: int, n_queries: int, reps: int, seed: int) -> dict:
    """Runs inside one fixed-device-count process; prints a JSON dict."""
    import jax

    from repro.core.generate import EvolutionParams, build_store
    from repro.sharding.graph import graph_mesh, single_device

    n_dev = len(jax.devices())
    # n_cap must split evenly for the row-sharded two-phase groups
    n_cap = -(-n_nodes // 8) * 8
    store = build_store(n_nodes, EvolutionParams(
        m_attach=3, lam_extra=1.0, lam_remove=1.0), seed=seed, n_cap=n_cap)
    queries = _workload(store, n_queries, seed)
    mesh = graph_mesh()
    eng = (store.engine() if single_device(mesh)
           else store.place_on_mesh(mesh))

    kw = {} if single_device(mesh) else dict(mesh=mesh)
    eng.evaluate_many(queries, **kw)              # warm-up / compile
    sharded_groups = sum(m is not None
                         for *_, m in eng.last_group_stats)
    t0 = time.perf_counter()
    for _ in range(reps):
        eng.evaluate_many(queries, **kw)
    dt = (time.perf_counter() - t0) / reps
    return {
        "device_count": n_dev,
        "qps": n_queries / dt,
        "us_per_query": dt / n_queries * 1e6,
        "n_queries": n_queries,
        "groups": len(eng.last_group_stats),
        "sharded_groups": sharded_groups,
        "t_cur": int(store.t_cur),
        "total_ops": int(store.stats()["total_ops"]),
    }


def spawn(n_dev: int, args) -> dict:
    if ROOT not in sys.path:
        sys.path.insert(0, ROOT)
    from benchmarks.artifacts import merge_xla_flags
    env = dict(os.environ)
    # append to (don't clobber) a pre-set XLA_FLAGS — only the device
    # count is forced, everything else the caller exported is kept
    env["XLA_FLAGS"] = merge_xla_flags(
        env.get("XLA_FLAGS"),
        f"--xla_force_host_platform_device_count={n_dev}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           "--n-nodes", str(args.n_nodes), "--n-queries",
           str(args.n_queries), "--reps", str(args.reps)]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"worker D={n_dev} failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    return json.loads(r.stdout.splitlines()[-1])


def run(args) -> tuple[list, dict]:
    """(rows, results) like the other bench modules."""
    per_dev = {}
    rows = []
    for n_dev in DEVICE_COUNTS:
        res = spawn(n_dev, args)
        assert res["device_count"] == n_dev, res
        per_dev[str(n_dev)] = res
        rows.append((f"distributed/qps@D={n_dev}", f"{res['qps']:.1f}",
                     f"{res['us_per_query']:.0f} us/query, "
                     f"{res['sharded_groups']}/{res['groups']} groups "
                     "sharded"))
    speedup = per_dev["8"]["qps"] / max(per_dev["1"]["qps"], 1e-9)
    rows.append(("distributed/speedup@D=8", f"{speedup:.2f}x",
                 "host-CPU devices share cores; see module docstring"))
    results = {"qps": {d: r["qps"] for d, r in per_dev.items()},
               "speedup_8_vs_1": speedup,
               "per_device_count": per_dev,
               "n_nodes": args.n_nodes, "n_queries": args.n_queries,
               "reps": args.reps}
    return rows, results


def write_json(results: dict) -> None:
    """Refresh BENCH_distributed.json (shared schema, one writer for
    both the standalone bench and benchmarks/run.py)."""
    if ROOT not in sys.path:  # direct `python benchmarks/...` invocation
        sys.path.insert(0, ROOT)
    from benchmarks.artifacts import make_artifact, write_artifact
    # the orchestrating process has 1 device; record the max measured
    write_artifact(OUT_JSON, make_artifact(
        "distributed", results, device_count=max(DEVICE_COUNTS)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--n-nodes", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    args.n_nodes = args.n_nodes or (150 if args.fast else 300)
    args.n_queries = args.n_queries or (64 if args.fast else 256)
    args.reps = args.reps or (2 if args.fast else 3)

    if args.worker:
        print(json.dumps(worker(args.n_nodes, args.n_queries, args.reps,
                                seed=0)))
        return

    rows, results = run(args)
    for name, val, note in rows:
        print(f"{name},{val},{note}")
    if args.fast:
        # --fast is a sanity tier: don't clobber the committed
        # default-config artifact with incomparable numbers
        print(f"--fast: skipping {OUT_JSON} refresh")
    else:
        write_json(results)
        print(f"wrote {OUT_JSON}")


if __name__ == "__main__":
    main()
