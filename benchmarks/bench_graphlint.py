"""graphlint throughput benchmark: whole-repo analysis wall time.

The lint gate runs on every CI push, so its cost is part of the
development loop's budget: the pass suite must stay cheap enough to
run on the whole tree (src + scripts + benchmarks) in a couple of
seconds, or people will start skipping it.  This bench times exactly
what CI runs — ``analyze_paths`` over the default targets with every
registered pass — and records files/sec (bigger is better, so the
shared ``check_bench_baseline.py`` floor logic applies unchanged).

The findings counts ride along in the artifact: the committed numbers
double as a visible record of the repo's lint state at the time the
artifact was refreshed (0 unsuppressed findings, suppressions with
reasons).

  PYTHONPATH=src python benchmarks/bench_graphlint.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.analysis.driver import analyze_paths  # noqa: E402
from artifacts import make_artifact, write_artifact  # noqa: E402

OUT_JSON = os.path.join(HERE, "BENCH_graphlint.json")
TARGETS = ("src", "scripts", "benchmarks")


def run_once() -> tuple[float, object]:
    paths = [os.path.join(ROOT, t) for t in TARGETS
             if os.path.isdir(os.path.join(ROOT, t))]
    t0 = time.perf_counter()
    report = analyze_paths(paths)
    return time.perf_counter() - t0, report


def measure(repeats: int) -> dict:
    best = float("inf")
    report = None
    for _ in range(repeats):
        dt, report = run_once()
        best = min(best, dt)
    return {
        "wall_s": round(best, 4),
        "files": report.files,
        "files_per_sec": round(report.files / best, 1),
        "findings": len(report.findings),
        "suppressed": len(report.suppressed),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="single timed run (CI guard config)")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()

    repeats = 1 if args.smoke else 5
    smoke = measure(repeats=1)
    results = {"smoke": smoke}
    if not args.smoke:
        results["full"] = measure(repeats=repeats)

    artifact = make_artifact("graphlint", results, device_count=0)
    write_artifact(args.out, artifact)
    print(json.dumps(results, indent=2))
    scale = results.get("full", smoke)
    print(f"graphlint: {scale['files']} files in {scale['wall_s']}s "
          f"({scale['files_per_sec']} files/s), "
          f"{scale['findings']} findings, "
          f"{scale['suppressed']} suppressed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
