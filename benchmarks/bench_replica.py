"""Replicated serving cost: routed read qps, replica lag, failover time.

Three questions the replication layer (``repro/replica``) must answer
with numbers:

1. **What does routed serving cost?**  A fleet of D read replicas
   (D in {1, 2, 4}; smoke {1, 2}) is synced from one published root and
   a ``QueryRouter`` drives a fixed batched read load through the
   whole stack — watermark check, candidate ordering, replica engine
   dispatch.  Recorded per D: routed queries/second.  (All replicas
   share one process and device here, so this measures the serving
   path's overhead, not horizontal scale-out.)
2. **How far behind does a polling replica run under write churn?**
   The writer streams epoch after epoch; after every checkpoint the
   replica's pre-sync staleness (time units behind the writer) and its
   catch-up sync time are recorded.  The incremental paths (WAL growth
   / rotation suffix) keep the catch-up cost bounded by the epoch, not
   the history.
3. **What does failover cost?**  Two replicas behind a router; the one
   currently serving is killed (its transport and serving surface both
   go dark) and the next routed call must come back from the survivor.
   Recorded: median/max seconds for that first post-death answer —
   detection + failover + retry, measured at the client.

``--smoke`` runs the down-scaled sweep only; the CI fast lane guards
its ``routed_qps`` via ``scripts/check_bench_baseline.py --bench
replica``.

  PYTHONPATH=src python benchmarks/bench_replica.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import os
import shutil
import statistics
import sys
import tempfile
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, HERE)

OUT_JSON = os.path.join(HERE, "BENCH_replica.json")

FULL = dict(n_cap=128, per_unit=256, epoch_units=4, warm_epochs=6,
            churn_epochs=12, replica_counts=(1, 2, 4), batch_q=32,
            n_batches=120, warmup_batches=10, failover_trials=5)
SMOKE = dict(n_cap=64, per_unit=128, epoch_units=4, warm_epochs=3,
             churn_epochs=6, replica_counts=(1, 2), batch_q=32,
             n_batches=30, warmup_batches=5, failover_trials=3)


def _churn_unit(rng, n_cap, t, per_unit):
    from repro.core.delta import ADD_EDGE, REM_EDGE
    from repro.core.store import Op
    ops = []
    for _ in range(per_unit):
        u, v = int(rng.integers(0, n_cap)), int(rng.integers(0, n_cap))
        if u == v:
            continue
        ops.append(Op(ADD_EDGE if rng.random() < 0.55 else REM_EDGE,
                      u, v, t))
    return ops


def _seed_writer(cfg, tmp):
    """A durable writer with ``warm_epochs`` of published history."""
    import numpy as np

    from repro.api import GraphSession
    from repro.core.delta import ADD_NODE
    from repro.core.store import Op

    rng = np.random.default_rng(3)
    s = GraphSession.open(os.path.join(tmp, "writer"), n_cap=cfg["n_cap"])
    pub = s.publish_to(os.path.join(tmp, "pub"))
    s.ingest([Op(ADD_NODE, v, v, 1) for v in range(cfg["n_cap"])])
    t = 1
    for _ in range(cfg["warm_epochs"]):
        batch = []
        for _ in range(cfg["epoch_units"]):
            t += 1
            batch += _churn_unit(rng, cfg["n_cap"], t, cfg["per_unit"])
        s.ingest(batch)
        s.flush()
    return s, pub, rng, t


def _query_batches(cfg, watermark):
    from repro.core import Query
    qs = []
    for i in range(cfg["batch_q"]):
        t = 1 + (i * 7) % watermark
        if i % 4 == 0:
            qs.append(Query("point", "global", "num_edges", t_k=t))
        else:
            qs.append(Query("point", "node", "degree", t_k=t,
                            v=i % cfg["n_cap"]))
    return qs


def measure_routed_qps(cfg: dict) -> dict:
    """Routed read throughput vs fleet size over identical state."""
    from repro.api import GraphSession
    from repro.replica import ReadReplica

    out = {}
    tmp = tempfile.mkdtemp(prefix="bench_replica_qps_")
    try:
        s, pub, _rng, _t = _seed_writer(cfg, tmp)
        qs = _query_batches(cfg, s.watermark)
        for d in cfg["replica_counts"]:
            replicas = {}
            for i in range(d):
                r = ReadReplica(pub.transport(),
                                os.path.join(tmp, f"rep{d}_{i}"),
                                name=f"r{i}")
                r.sync()
                replicas[r.name] = r
            router = GraphSession.open_router(replicas)
            for _ in range(cfg["warmup_batches"]):
                router.evaluate_many(qs)
            t0 = time.perf_counter()
            for _ in range(cfg["n_batches"]):
                router.evaluate_many(qs)
            wall = time.perf_counter() - t0
            qps = cfg["n_batches"] * len(qs) / wall
            out[str(d)] = qps
            print(f"routed qps  D={d}: {qps:9.0f}", flush=True)
        s.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return out


def measure_lag_under_churn(cfg: dict) -> dict:
    """Per-epoch staleness and catch-up time of a polling replica."""
    from repro.replica import ReadReplica

    tmp = tempfile.mkdtemp(prefix="bench_replica_lag_")
    try:
        s, pub, rng, t = _seed_writer(cfg, tmp)
        replica = ReadReplica(pub.transport(), os.path.join(tmp, "rep"))
        replica.sync()
        lags, sync_s, applied = [], [], []
        for _ in range(cfg["churn_epochs"]):
            batch = []
            for _ in range(cfg["epoch_units"]):
                t += 1
                batch += _churn_unit(rng, cfg["n_cap"], t,
                                     cfg["per_unit"])
            s.ingest(batch)
            s.flush()
            lags.append(s.watermark - replica.watermark)
            rec = replica.sync()
            sync_s.append(rec["seconds"])
            applied.append(rec["records_applied"])
            assert replica.watermark == s.watermark
        s.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    cell = {
        "pre_sync_lag_units_median": statistics.median(lags),
        "pre_sync_lag_units_max": max(lags),
        "catchup_s_median": statistics.median(sync_s),
        "catchup_s_max": max(sync_s),
        "records_per_catchup_median": statistics.median(applied),
        "epochs": cfg["churn_epochs"],
    }
    print(f"lag under churn: pre-sync p50 "
          f"{cell['pre_sync_lag_units_median']:.0f} units, catch-up p50 "
          f"{cell['catchup_s_median'] * 1e3:.1f} ms", flush=True)
    return cell


class _Killable:
    """Serving proxy whose death is a switch — the router sees the
    same surface a remote replica process would expose."""

    def __init__(self, replica):
        self.replica = replica
        self.dead = False

    def status(self):
        if self.dead:
            raise ConnectionError("replica down")
        return self.replica.status()

    def evaluate_many(self, queries, plan="auto", **kw):
        if self.dead:
            raise ConnectionError("replica down")
        return self.replica.evaluate_many(queries, plan, **kw)


def measure_failover(cfg: dict) -> dict:
    """Client-observed seconds for the first answer after the serving
    replica dies (detection + mark-down + retry on the survivor)."""
    from repro.api import GraphSession
    from repro.replica import ReadReplica

    tmp = tempfile.mkdtemp(prefix="bench_replica_fo_")
    try:
        s, pub, _rng, _t = _seed_writer(cfg, tmp)
        qs = _query_batches(cfg, s.watermark)
        proxies = {}
        for i in range(2):
            r = ReadReplica(pub.transport(), os.path.join(tmp, f"rep{i}"),
                            name=f"r{i}")
            r.sync()
            proxies[r.name] = _Killable(r)
        router = GraphSession.open_router(proxies)
        for _ in range(cfg["warmup_batches"]):
            router.evaluate_many(qs)
        trials = []
        for _ in range(cfg["failover_trials"]):
            # kill whichever replica is about to be picked
            victim = max(proxies.values(),
                         key=lambda p: p.replica.stats.queries_served)
            victim.dead = True
            t0 = time.perf_counter()
            router.evaluate_many(qs)      # must answer from the survivor
            trials.append(time.perf_counter() - t0)
            victim.dead = False
            router.heartbeat()            # readmit before the next trial
        s.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    cell = {
        "first_answer_s_median": statistics.median(trials),
        "first_answer_s_max": max(trials),
        "trials": len(trials),
    }
    print(f"failover: first post-death answer p50 "
          f"{cell['first_answer_s_median'] * 1e3:.1f} ms "
          f"(max {cell['first_answer_s_max'] * 1e3:.1f} ms)", flush=True)
    return cell


def run_sweep(cfg: dict) -> dict:
    out: dict = {"config": dict(cfg)}
    out["qps_by_replicas"] = measure_routed_qps(cfg)
    out["routed_qps"] = out["qps_by_replicas"][
        str(min(cfg["replica_counts"]))]
    out["lag"] = measure_lag_under_churn(cfg)
    out["failover"] = measure_failover(cfg)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled sweep only (CI fast lane)")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()

    from artifacts import make_artifact, write_artifact

    results = {"smoke": run_sweep(SMOKE)}
    if not args.smoke:
        results["full"] = run_sweep(FULL)
    write_artifact(args.out, make_artifact("replica", results))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
