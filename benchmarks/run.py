"""Benchmark driver: one function per paper table/figure (+ the
framework benches). Prints ``name,us_per_call,derived`` CSV lines.

Sections that persist results refresh their ``BENCH_*.json`` artifacts
through the shared schema in ``benchmarks/artifacts.py`` (name, qps,
device_count, git sha), so artifacts are comparable across PRs.  Any
section raising an exception is reported AND makes the driver exit
non-zero — a red benchmark run never looks green.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graphs / fewer reps")
    ap.add_argument("--skip-distributed", action="store_true",
                    help="skip the multi-process device-count sweep")
    args = ap.parse_args()

    failures = []

    def section(name, fn):
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:
            failures.append((name, e))
            traceback.print_exc()
            print(f"{name},ERROR,{type(e).__name__}")

    # Paper Table 3 — dataset
    from benchmarks import bench_table3_dataset

    def t3():
        rows, dt, _ = bench_table3_dataset.run()
        for name, got, target, relerr in rows:
            print(f"{name},{got},target={target} rel_err={relerr:.4f}")
        print(f"table3/build_seconds,{dt:.2f},")

    section("paper Table 3 (dataset)", t3)

    # Paper Figure 1 — four query plans vs time depth
    from benchmarks import bench_fig1_plans

    def f1():
        store = None
        if args.fast:
            from repro.core.generate import EvolutionParams, build_store
            store = build_store(600, EvolutionParams(
                m_attach=4, lam_extra=1.0, lam_remove=1.0), seed=1)
        for name, ops, ms in bench_fig1_plans.run(
                store=store, reps=2 if args.fast else 3):
            print(f"{name},{ms*1e3:.1f},ops_applied={ops}")

    section("paper Figure 1 (query plans)", f1)

    # Reconstruction engines (paper-faithful vs beyond-paper)
    from benchmarks import bench_reconstruction

    def rec():
        for name, ms in bench_reconstruction.run(
                n_nodes=384 if args.fast else 1024,
                reps=2 if args.fast else 3):
            if "speedup" in name:  # dimensionless ratio
                print(f"{name},{ms:.1f}x,")
            else:
                print(f"{name},{ms*1e3:.1f},")

    section("reconstruction engines", rec)

    # Batched multi-query engine throughput
    from benchmarks import bench_engine_batch

    def eb():
        rows, result = bench_engine_batch.run(
            n_nodes=150 if args.fast else 300,
            n_queries=64 if args.fast else 256,
            reps=2 if args.fast else 3)
        for name, val, note in rows:
            print(f"{name},{val},{note}")
        if not args.fast:   # --fast numbers are not comparable
            bench_engine_batch.write_json(result)

    section("engine batched serving", eb)

    # Multi-device serving (qps vs device count, subprocess sweep)
    from benchmarks import bench_distributed

    def dist():
        dargs = argparse.Namespace(
            n_nodes=150 if args.fast else 300,
            n_queries=64 if args.fast else 256,
            reps=2 if args.fast else 3)
        rows, results = bench_distributed.run(dargs)
        for name, val, note in rows:
            print(f"{name},{val},{note}")
        if not args.fast:
            bench_distributed.write_json(results)

    if not args.skip_distributed:
        section("distributed serving", dist)

    # Kernels
    from benchmarks import bench_kernels

    def ker():
        for name, val, note in bench_kernels.run():
            print(f"{name},{val},{note}")

    section("kernels", ker)

    # Delta checkpointing
    from benchmarks import bench_checkpoint

    def ck():
        for name, val, note in bench_checkpoint.run():
            print(f"{name},{val},{note}")

    section("delta checkpoint store", ck)

    # Roofline summary (from cached dry-run artifacts)
    from benchmarks import roofline_report

    def roof():
        import os
        base = roofline_report.DRYRUN
        if not os.path.isdir(base):
            print("roofline,SKIP,no dryrun results yet")
            return
        for mesh in sorted(os.listdir(base)):
            s = roofline_report.summary(mesh)
            print(f"roofline/{mesh},{s['ok']} ok,"
                  f"{s['skipped']} skipped {s['errors']} errors")

    section("roofline summary", roof)

    if failures:
        print(f"\n{len(failures)} section(s) failed:", file=sys.stderr)
        for name, e in failures:
            print(f"  {name}: {e}", file=sys.stderr)
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
