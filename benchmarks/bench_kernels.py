"""Kernel micro-benchmarks.

Pallas kernels execute in interpret mode on CPU (their target is TPU),
so the honest comparison here is allclose vs the oracle plus the XLA
path's walltime; interpret-mode walltime is reported for completeness
only."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e3


def run():
    rows = []
    from repro.core.generate import EvolutionParams, build_store
    from repro.kernels.delta_apply import delta_apply, delta_apply_ref

    store = build_store(512, EvolutionParams(m_attach=4, lam_extra=1.0,
                                             lam_remove=1.2), seed=3)
    d = store.delta()
    tq = store.t_cur // 2
    g_k, ovf = delta_apply(store.current, d, store.t_cur, tq, tile=128,
                           cap=4096)
    g_r = delta_apply_ref(store.current, d, store.t_cur, tq)
    ok = bool(jnp.all(g_k.adj == g_r.adj)) and not bool(ovf)
    rows.append(("kernel/delta_apply_allclose", float(ok),
                 f"tile=128 cap=4096 M={int(d.n_ops)}"))
    rows.append(("kernel/delta_apply_ref_xla_ms",
                 _timeit(lambda: delta_apply_ref(
                     store.current, d, store.t_cur, tq).adj), ""))

    from repro.kernels.degree_series import (degree_series_kernel,
                                             degree_series_ref)
    out, ovf = degree_series_kernel(store.current, d, tq, 16, tile=128,
                                    cap=8192)
    ref = degree_series_ref(store.current, d, tq, store.t_cur, 16)
    rows.append(("kernel/degree_series_allclose",
                 float(bool(jnp.all(out == ref)) and not bool(ovf)), ""))

    from repro.kernels.flash_attention import attention_ref, flash_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)),
                    dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)),
                    dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)),
                    dtype=jnp.float32)
    out = flash_attention(q, k, v, True, None, None, 128, 128, True)
    ref = attention_ref(q, k, v, causal=True, scale=64 ** -0.5)
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(("kernel/flash_attention_max_err", err, "256x256 GQA2"))
    rows.append(("kernel/attention_ref_xla_ms",
                 _timeit(lambda: attention_ref(q, k, v, causal=True,
                                               scale=64 ** -0.5)), ""))
    return rows


def main():
    for name, val, note in run():
        print(f"{name},{val},{note}")


if __name__ == "__main__":
    main()
