"""Closed-loop live-serving benchmark: mixed read/write, hot-tail reads.

Drives the full serving stack — ``LiveGraphStore`` (double-buffered
ingest + epoch swaps) behind a ``MicroBatchFrontend`` (coalescing +
exact result cache) — with a closed-loop client:

* **Mix**: ≥80/20 read/write.  Writes are the continuation of the
  scale-free evolution stream, appended to the pending buffer in small
  batches; an epoch swap runs every ``swap_every`` read bursts.
* **Read times**: hot-tail — a heavy band around a fixed historical
  time (default t_serv/3, the "everyone analyses the incident window"
  shape) plus an exponential tail decaying back from the watermark.
* **Measured**: sustained qps, p50/p99 request latency
  (submit→future-done through the frontend), ingest lag at each swap
  (pending ops + time units behind), frontend cache hit rate.

The same closed loop runs twice under the same device-byte budget:
once with ``WorkloadMaterializationPolicy`` (query histogram places
the anchors) and once with the static ``PeriodicMaterializationPolicy``
cadence — the artifact records both, and the workload-driven policy
must win on p99 for this distribution (two-phase queries in the hot
band reconstruct through a short window instead of the whole suffix).

``--smoke`` runs a down-scaled config only (CI fast lane; the
committed artifact keeps a ``smoke`` section from the full run so
``scripts/check_bench_baseline.py`` can compare apples to apples).

  PYTHONPATH=src python benchmarks/bench_serving.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, HERE)

OUT_JSON = os.path.join(HERE, "BENCH_serving.json")

# The interesting serving regime is the paper's: a long churning op
# log over a bounded node set, so reconstruction cost is dominated by
# the delta window an anchor choice implies (ops >> N²) — that is
# where materialization placement moves the latency needle.
# Request mix: each burst issues ``burst`` read requests and
# ``writes_per_burst`` write requests (one append call of one complete
# time unit each) — burst=8 / writes=2 is the 80/20 read/write point.
FULL = dict(n_cap=64, prime_units=360, per_unit=48, n_bursts=150,
            burst=8, writes_per_burst=2, swap_every=25, warm_bursts=50,
            budget_snapshots=2, min_gap_ops=1500, seed=7)
SMOKE = dict(n_cap=64, prime_units=80, per_unit=16, n_bursts=40,
             burst=4, writes_per_burst=1, swap_every=10, warm_bursts=10,
             budget_snapshots=2, min_gap_ops=300, seed=7)


def _percentile(xs, p):
    xs = sorted(xs)
    if not xs:
        return 0.0
    k = min(len(xs) - 1, int(round(p / 100 * (len(xs) - 1))))
    return xs[k]


def _hot_tail_time(rng, t_served, hot_center, hot_width):
    """60% hot historical band, 40% exponential tail off the watermark."""
    if rng.random() < 0.6:
        t = hot_center + int(rng.integers(-hot_width, hot_width + 1))
    else:
        t = t_served - int(rng.exponential(max(t_served / 8.0, 1.0)))
    return int(min(max(t, 1), t_served))


def churn_ops(n_cap, units, per_unit, rng, t0=1):
    """A churning op stream over a fixed node set: ``per_unit`` random
    add/remove-edge proposals per time unit (the store rejects illegal
    transitions, so proposals are admissible input).  This is the
    paper's serving regime — a log much larger than the graph — where
    reconstruction cost is the delta window, i.e. where anchors live.
    """
    from repro.core.store import Op
    from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE
    ops = [Op(ADD_NODE, v, v, t0) for v in range(n_cap)]
    for t in range(t0 + 1, t0 + 1 + units):
        for _ in range(per_unit):
            u, v = int(rng.integers(0, n_cap)), int(rng.integers(0, n_cap))
            if u == v:
                continue
            kind = ADD_EDGE if rng.random() < 0.55 else REM_EDGE
            ops.append(Op(kind, u, v, t))
    return ops


def closed_loop(policy_kind: str, cfg: dict) -> dict:
    """One closed-loop run; returns the measured stats dict."""
    import numpy as np

    from repro.core.engine import _snapshot_bytes
    from repro.core.plans import Query
    from repro.core.store import TemporalGraphStore
    from repro.serving import (LiveGraphStore, MicroBatchFrontend,
                               PeriodicMaterializationPolicy,
                               WorkloadMaterializationPolicy)

    rng = np.random.default_rng(cfg["seed"])
    # prime history + the continuation the write stream will append
    # (one complete time unit per write request — a swap closes units)
    write_units = cfg["n_bursts"] * cfg["writes_per_burst"] + 2
    ops = churn_ops(cfg["n_cap"], cfg["prime_units"] + write_units,
                    cfg["per_unit"], rng)
    t_prime = cfg["prime_units"] + 1
    prime = next(i for i, o in enumerate(ops) if o.t > t_prime)

    # the byte budget buys the same #snapshots for either policy
    probe = TemporalGraphStore(n_cap=cfg["n_cap"])
    budget = cfg["budget_snapshots"] * _snapshot_bytes(probe.current) + 1
    if policy_kind == "workload":
        policy = WorkloadMaterializationPolicy(
            budget_bytes=budget, min_gap_ops=cfg["min_gap_ops"],
            decay=0.5)
    else:
        # the static cadence: snapshots on a uniform time grid
        policy = PeriodicMaterializationPolicy(
            period=max(t_prime // (cfg["budget_snapshots"] + 1), 4),
            budget_bytes=budget)

    # pre-size the device log and pad every group to the burst size:
    # swaps and batch fragmentation then never change a kernel shape,
    # so steady-state latency has no recompiles
    live = LiveGraphStore(n_cap=cfg["n_cap"], policy=policy,
                          delta_cap_hint=2 * len(ops),
                          group_pad_min=cfg["burst"])
    live.append(ops[:prime])
    live.swap()
    fe = MicroBatchFrontend(live, max_batch=cfg["burst"])
    hot_center = max(live.t_served // 3, 2)
    hot_width = 6

    def burst_queries():
        qs = []
        for _ in range(cfg["burst"]):
            t = _hot_tail_time(rng, live.t_served, hot_center, hot_width)
            if rng.random() < 0.5:
                qs.append(Query("point", "node", "degree", t_k=t,
                                v=int(rng.integers(0, cfg["n_cap"]))))
            else:
                qs.append(Query("point", "global", "num_edges", t_k=t))
        return qs

    lat, lags = [], []
    write_ptr = prime
    n_reads = n_write_reqs = n_write_ops = 0
    measuring = False
    t0 = time.perf_counter()
    for i in range(cfg["n_bursts"]):
        if i == cfg["warm_bursts"]:
            # measurement starts once the policy has converged and the
            # program shapes are compiled; writes/swaps keep flowing
            # through the measured phase — this is the steady state
            lat, measuring = [], True
            n_reads = n_write_reqs = n_write_ops = 0
            t0 = time.perf_counter()
        for _ in range(cfg["writes_per_burst"]):
            if write_ptr >= len(ops):
                break
            # one write request = one append of one complete time unit
            # (a swap closes every pending unit; a mid-unit cut would
            # make the stream continuation un-appendable, by design)
            end = write_ptr + 1
            while end < len(ops) and ops[end].t == ops[write_ptr].t:
                end += 1
            batch = ops[write_ptr:end]
            write_ptr = end
            live.append(batch)
            n_write_reqs += 1
            n_write_ops += len(batch)
        qs = burst_queries()
        t_sub = time.perf_counter()
        futs = [fe.submit(q) for q in qs]
        fe.flush()
        done = time.perf_counter()
        for f in futs:
            f.result()
            lat.append(done - t_sub)
        n_reads += len(qs)
        if (i + 1) % cfg["swap_every"] == 0:
            if measuring:
                lags.append(live.ingest_lag())
            live.swap()
    elapsed = time.perf_counter() - t0

    return {
        "policy": policy_kind,
        "reads": n_reads,
        "write_requests": n_write_reqs,
        "write_ops": n_write_ops,
        "read_fraction": n_reads / max(n_reads + n_write_reqs, 1),
        "qps": n_reads / elapsed,
        "p50_ms": _percentile(lat, 50) * 1e3,
        "p99_ms": _percentile(lat, 99) * 1e3,
        "epochs": live.epoch,
        "max_pending_ops": max((g["pending_ops"] for g in lags),
                               default=0),
        "max_t_behind": max((g["t_behind"] for g in lags), default=0),
        "mean_swap_seconds": (sum(r.seconds for r in live.swap_history)
                              / max(len(live.swap_history), 1)),
        "anchors": list(live.store.materialized.times),
        "cache_hit_rate": fe.stats.cache_hits / max(fe.stats.submitted, 1),
        "coalesced_dupes": fe.stats.coalesced_dupes,
    }


def run_config(cfg_name: str) -> dict:
    """Run each policy's closed loop in its OWN subprocess: a shared
    process would hand whichever runs second a warm jit cache, skewing
    the comparison (the house rule — see bench_edge_scaling)."""
    import json
    import subprocess
    out = {}
    for kind in ("workload", "static"):
        cmd = [sys.executable, os.path.abspath(__file__), "--worker",
               kind, "--config", cfg_name]
        r = subprocess.run(cmd, capture_output=True, text=True,
                           cwd=ROOT, timeout=1800)
        if r.returncode != 0:
            raise RuntimeError(f"worker {kind} failed:\n{r.stdout}\n"
                               f"{r.stderr}")
        out[kind] = json.loads(r.stdout.splitlines()[-1])
    cfg = dict(FULL if cfg_name == "full" else SMOKE)
    return {
        "config": cfg,
        "workload": out["workload"],
        "static": out["static"],
        "p99_speedup_workload_vs_static":
            out["static"]["p99_ms"] / max(out["workload"]["p99_ms"], 1e-9),
        "qps": out["workload"]["qps"],
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled run only (CI fast lane)")
    ap.add_argument("--out", default=OUT_JSON)
    ap.add_argument("--worker", default=None,
                    choices=("workload", "static"),
                    help="internal: run one closed loop, print JSON")
    ap.add_argument("--config", default="smoke",
                    choices=("smoke", "full"))
    args = ap.parse_args()

    if args.worker:
        import json
        cfg = FULL if args.config == "full" else SMOKE
        print(json.dumps(closed_loop(args.worker, cfg)))
        return 0

    from artifacts import make_artifact, write_artifact

    results = {"smoke": run_config("smoke")}
    print("smoke:", {k: round(v, 3) if isinstance(v, float) else v
                     for k, v in results["smoke"]["workload"].items()
                     if k in ("qps", "p50_ms", "p99_ms",
                              "cache_hit_rate")})
    if not args.smoke:
        results["closed_loop"] = run_config("full")
        for kind in ("workload", "static"):
            r = results["closed_loop"][kind]
            print(f"{kind:9s} qps={r['qps']:9.1f}  p50={r['p50_ms']:7.2f}ms"
                  f"  p99={r['p99_ms']:7.2f}ms  lag≤{r['max_pending_ops']}"
                  f" ops/{r['max_t_behind']}tu  anchors={r['anchors']}")
        print("p99 speedup (workload vs static): "
              f"{results['closed_loop']['p99_speedup_workload_vs_static']:.2f}x")
    write_artifact(args.out, make_artifact("serving", results))
    print("wrote", args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
