"""Paper Figure 1: run time of a point node-centric degree query at
increasing time depth (x-axis backwards from the current snapshot,
measured in #ops applied), for the four plans:

  two-phase, hybrid, two-phase-index, hybrid-index

plus the paper-faithful *sequential* two-phase baseline (one-op-at-a-
time replay — what the Java/Neo4j implementation does) so the
beyond-paper vectorized gain is visible (EXPERIMENTS.md §Perf).

Audited against the segmented-by-default store: ``store.delta()`` is
the monolithic compat view (``SegmentedDeltaView.full_delta``), so the
plan timings here measure the same device log as before segmentation.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.generate import paper_table3
from repro.core.index import count_window_ops
from repro.core.plans import (hybrid_point_degree,
                              hybrid_point_degree_indexed, two_phase,
                              Query)


def _timeit(fn, reps=3):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e3, out  # ms, value


def run(store=None, depths=(0.1, 0.3, 0.5, 0.7, 0.9), reps=3,
        sequential_too=True, seq_depths=(0.3, 0.9)):
    """Figure 1: the sequential (paper-faithful, Neo4j-like one-op-at-a-
    time) baseline is measured at fewer depths with reps=1 — it is
    ~100-1000× slower than the vectorized engine, which is the point."""
    store = store or paper_table3()
    d = store.delta()
    index = store.node_index()
    rng = np.random.default_rng(0)
    rows = []
    for frac in depths:
        t_q = int(store.t_cur * (1 - frac))
        ops_applied = int(count_window_ops(d, t_q, store.t_cur))
        v = int(rng.integers(0, store.n_cap))
        q = Query("point", "node", "degree", t_k=t_q, v=v)

        plans = {
            "two_phase": lambda: two_phase(store.current, d, store.t_cur,
                                           q, partial_rows=True),
            "hybrid": lambda: hybrid_point_degree(store.current, d, v,
                                                  t_q, store.t_cur),
            "two_phase_index": lambda: two_phase(
                store.current, d, store.t_cur, q, partial_rows=True,
                passes=1),
            "hybrid_index": lambda: hybrid_point_degree_indexed(
                store.current, d, index, v, t_q, store.t_cur, 2048),
        }
        if sequential_too and frac in seq_depths:
            plans["two_phase_sequential"] = lambda: two_phase(
                store.current, d, store.t_cur, q, sequential=True)
        vals = {}
        ms = {}
        for name, fn in plans.items():
            r = 1 if name == "two_phase_sequential" else reps
            ms[name], out = _timeit(fn, r)
            vals[name] = int(np.asarray(jax.device_get(out)))
        assert len(set(vals.values())) == 1, (vals, frac)
        for name, m in ms.items():
            rows.append((f"fig1/{name}", ops_applied, m))
    return rows


def main():
    for name, ops, ms in run():
        print(f"{name},{ms*1e3:.1f},ops_applied={ops}")


if __name__ == "__main__":
    main()
