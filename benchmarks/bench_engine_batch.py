"""Batched multi-query serving throughput (engine.evaluate_many).

The paper's successor system batches multi-snapshot retrieval into
single scans; our analogue is the engine's batched executor: B
historical queries grouped by (plan, anchor) and run as one vmapped
device program per group, instead of B separate host dispatches.

Workload: a synthetic evolving graph and a mixed stream of node-centric
degree queries (point / range-differential / range-aggregate — the
serving mix of examples/serve_historical.py), auto-planned.  We measure
queries/sec for the single-query loop (B=1) and for batched execution
at B ∈ {8, 64, 256}, and write the rows to
``benchmarks/BENCH_engine_batch.json`` next to the other BENCH
artifacts.

  PYTHONPATH=src python benchmarks/bench_engine_batch.py [--fast]
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core.generate import EvolutionParams, build_store
from repro.core.plans import Query

HERE = os.path.dirname(__file__)
OUT_JSON = os.path.join(HERE, "BENCH_engine_batch.json")


def make_workload(store, n_queries: int, seed: int = 0) -> list[Query]:
    rng = np.random.default_rng(seed)
    tc = store.t_cur
    qs: list[Query] = []
    for i in range(n_queries):
        v = int(rng.integers(0, store.n_cap))
        t1 = int(rng.integers(1, max(2, tc)))
        t2 = min(tc, t1 + int(rng.integers(0, 8)))
        kind = ("point", "diff", "agg")[i % 3]
        if kind == "point":
            qs.append(Query("point", "node", "degree", t_k=t1, v=v))
        elif kind == "diff":
            qs.append(Query("diff", "node", "degree", t_k=t1, t_l=t2, v=v))
        else:
            qs.append(Query("agg", "node", "degree", t_k=t1, t_l=t2, v=v,
                            agg="mean"))
    return qs


def _serve(engine, queries: list[Query], batch: int) -> None:
    for i in range(0, len(queries), batch):
        engine.evaluate_many(queries[i:i + batch])


def run(n_nodes: int = 300, n_queries: int = 256,
        batch_sizes: tuple[int, ...] = (1, 8, 64, 256), reps: int = 3,
        seed: int = 0):
    """Returns (rows, result_dict); rows are (name, value, note) like
    the other bench modules."""
    store = build_store(n_nodes, EvolutionParams(
        m_attach=3, lam_extra=1.0, lam_remove=1.0), seed=seed)
    engine = store.engine()
    queries = make_workload(store, n_queries, seed)
    # B > n_queries would silently re-measure the full batch under a
    # mislabeled row
    batch_sizes = tuple(b for b in batch_sizes if b <= n_queries)

    qps: dict[int, float] = {}
    rows = []
    for b in batch_sizes:
        _serve(engine, queries, b)         # warm-up / compile
        t0 = time.perf_counter()
        for _ in range(reps):
            _serve(engine, queries, b)
        dt = (time.perf_counter() - t0) / reps
        qps[b] = n_queries / dt
        rows.append((f"engine_batch/qps@B={b}", f"{qps[b]:.1f}",
                     f"{dt / n_queries * 1e6:.0f} us/query"))

    base = qps[min(batch_sizes)]
    speedups = {b: qps[b] / base for b in batch_sizes}
    for b in batch_sizes[1:]:
        rows.append((f"engine_batch/speedup@B={b}",
                     f"{speedups[b]:.1f}x", ""))

    result = {
        "n_nodes": n_nodes,
        "n_queries": n_queries,
        "t_cur": int(store.t_cur),
        "total_ops": int(store.stats()["total_ops"]),
        "reps": reps,
        "qps": {str(b): qps[b] for b in batch_sizes},
        "speedup_vs_b1": {str(b): speedups[b] for b in batch_sizes},
    }
    return rows, result


def write_json(result: dict) -> None:
    """Refresh BENCH_engine_batch.json with the shared artifact schema
    (benchmarks/artifacts.py)."""
    import sys
    root = os.path.dirname(HERE)
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.artifacts import make_artifact, write_artifact
    write_artifact(OUT_JSON, make_artifact("engine_batch", result))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    rows, result = run(n_nodes=150 if args.fast else 300,
                       n_queries=64 if args.fast else 256,
                       reps=2 if args.fast else 3)
    for name, val, note in rows:
        print(f"{name},{val},{note}")
    if args.fast:
        # --fast is a sanity tier: don't clobber the committed
        # default-config artifact with incomparable numbers
        print(f"--fast: skipping {OUT_JSON} refresh")
    else:
        write_json(result)
        print(f"wrote {OUT_JSON}")
    s64 = result["speedup_vs_b1"].get("64")
    if s64 is not None and s64 < 5.0:
        print(f"WARNING: B=64 speedup {s64:.1f}x below the 5x target")


if __name__ == "__main__":
    main()
