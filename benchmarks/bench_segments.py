"""Epoch-swap cost: segmented vs monolithic delta log.

The ISSUE this benchmark guards: a serving epoch swap used to rebuild
the whole device log from the full host history — O(total history)
conversion per swap — so swap latency (and therefore ingest lag) grew
with the age of the deployment.  The segmented log
(``core/segments.py``) seals + converts only the ops since the last
swap, so swap latency must stay flat while history grows.

Protocol: for each history length H (a churning op stream over a
bounded node set, the paper's ops ≫ N² regime) and each mode
(``segmented=True`` / ``False``), prime a ``LiveGraphStore`` with H
ops, then measure K epoch swaps each absorbing the same number of
pending ops.  Recorded per (mode, H): median/mean swap seconds and the
ingest drain rate (ops absorbed per second).  The artifact also
records the *flatness ratio* — median swap latency at the largest
history over the smallest (≥16x apart): the acceptance criterion is
segmented ≤ 2x while monolithic grows with H.

``--smoke`` runs the down-scaled sweep only (CI fast lane;
``scripts/check_bench_baseline.py --bench segments`` compares its
swaps/sec against the committed artifact).

  PYTHONPATH=src python benchmarks/bench_segments.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import os
import statistics
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, HERE)

OUT_JSON = os.path.join(HERE, "BENCH_segments.json")

# history sweep (ops ≈ units × per_unit); largest/smallest = 16x
FULL = dict(n_cap=64, per_unit=32, hist_units=(256, 1024, 4096),
            epoch_units=4, n_swaps=8, warmup_swaps=2)
SMOKE = dict(n_cap=64, per_unit=16, hist_units=(32, 128, 512),
             epoch_units=4, n_swaps=5, warmup_swaps=1)


def _churn_unit(rng, n_cap, t, per_unit):
    from repro.core.delta import ADD_EDGE, REM_EDGE
    from repro.core.store import Op
    ops = []
    for _ in range(per_unit):
        u, v = int(rng.integers(0, n_cap)), int(rng.integers(0, n_cap))
        if u == v:
            continue
        kind = ADD_EDGE if rng.random() < 0.55 else REM_EDGE
        ops.append(Op(kind, u, v, t))
    return ops


def measure_mode(segmented: bool, hist_units: int, cfg: dict) -> dict:
    """One (mode, history length) cell: prime, warm up, measure."""
    import numpy as np

    from repro.core.delta import ADD_NODE
    from repro.core.store import Op, TemporalGraphStore
    from repro.serving import LiveGraphStore

    rng = np.random.default_rng(7)
    n_cap, per_unit = cfg["n_cap"], cfg["per_unit"]
    store = TemporalGraphStore(n_cap=n_cap, segmented=segmented)
    live = LiveGraphStore(store=store)
    prime = [Op(ADD_NODE, v, v, 1) for v in range(n_cap)]
    t = 1
    for _ in range(hist_units):
        t += 1
        prime += _churn_unit(rng, n_cap, t, per_unit)
    live.append(prime)
    live.swap()

    def one_swap():
        nonlocal t
        batch = []
        for _ in range(cfg["epoch_units"]):
            t += 1
            batch += _churn_unit(rng, n_cap, t, per_unit)
        live.append(batch)
        return live.swap()

    for _ in range(cfg["warmup_swaps"]):
        one_swap()
    recs = [one_swap() for _ in range(cfg["n_swaps"])]
    secs = [r.seconds for r in recs]
    absorbed = [r.ops_absorbed for r in recs]
    med = statistics.median(secs)
    return {
        "history_ops": store.stats()["total_ops"] - sum(absorbed),
        "epoch_ops": int(statistics.median(absorbed)),
        "swap_median_s": med,
        "swap_mean_s": statistics.fmean(secs),
        "swaps_per_sec": (1.0 / med) if med > 0 else 0.0,
        "ingest_drain_ops_per_sec": statistics.median(absorbed) / med,
        "segments": (len(store._segments) if segmented else 0),
    }


def run_sweep(cfg: dict) -> dict:
    out: dict = {"config": dict(cfg)}
    for mode, segmented in (("segmented", True), ("monolithic", False)):
        cells = {}
        for hu in cfg["hist_units"]:
            cells[str(hu * cfg["per_unit"])] = measure_mode(
                segmented, hu, cfg)
            last = cells[str(hu * cfg["per_unit"])]
            print(f"{mode:11s} hist={hu * cfg['per_unit']:>6d} ops: "
                  f"swap p50 {last['swap_median_s'] * 1e3:8.2f} ms, "
                  f"drain {last['ingest_drain_ops_per_sec']:9.0f} ops/s",
                  flush=True)
        meds = [cells[str(hu * cfg["per_unit"])]["swap_median_s"]
                for hu in cfg["hist_units"]]
        out[mode] = cells
        out.setdefault("flatness_ratio", {})[mode] = (
            meds[-1] / meds[0] if meds[0] > 0 else float("inf"))
    # the guarded metric: segmented swap throughput at the LARGEST
    # history — exactly where the monolithic path degrades
    biggest = str(cfg["hist_units"][-1] * cfg["per_unit"])
    out["swaps_per_sec"] = out["segmented"][biggest]["swaps_per_sec"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled sweep only (CI fast lane)")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()

    from artifacts import make_artifact, write_artifact

    results = {"smoke": run_sweep(SMOKE)}
    if not args.smoke:
        results["full"] = run_sweep(FULL)
    for tier in results:
        fr = results[tier]["flatness_ratio"]
        print(f"[{tier}] swap-latency growth over "
              f"{results[tier]['config']['hist_units'][-1] // results[tier]['config']['hist_units'][0]}x history: "
              f"segmented {fr['segmented']:.2f}x vs monolithic "
              f"{fr['monolithic']:.2f}x", flush=True)
    write_artifact(args.out, make_artifact("segments", results))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
