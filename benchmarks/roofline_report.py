"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import os

HERE = os.path.dirname(__file__)
DRYRUN = os.path.join(HERE, "results", "dryrun")

ARCH_ORDER = ["whisper-small", "mixtral-8x7b", "kimi-k2-1t-a32b",
              "gemma-2b", "smollm-360m", "glm4-9b", "olmo-1b",
              "internvl2-1b", "mamba2-130m", "jamba-1.5-large-398b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str) -> dict:
    out = {}
    d = os.path.join(DRYRUN, mesh)
    if not os.path.isdir(d):
        return out
    for f in os.listdir(d):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                r = json.load(fh)
            out[(r["arch"], r["shape"])] = r
    return out


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}µs"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def table(mesh: str) -> str:
    res = load(mesh)
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "HLO GFLOPs/dev | model/HLO | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = res.get((a, s))
            if r is None:
                lines.append(f"| {a} | {s} | — | — | — | *missing* | | | |")
                continue
            if "skipped" in r:
                lines.append(
                    f"| {a} | {s} | — | — | — | *skipped (full attn)* "
                    f"| | | |")
                continue
            if "error" in r:
                lines.append(f"| {a} | {s} | — | — | — | **ERROR** | | | "
                             f"{r['error'][:40]} |")
                continue
            t = r["roofline"]
            mem = r.get("memory_analysis", {})
            hbm = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("output_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 2**30
            ratio = r.get("useful_flops_ratio")
            try:  # recompute with the current (attention-aware) model
                from repro.config import SHAPES
                from repro.configs import get_config
                from repro.launch.roofline import model_flops
                mf = model_flops(get_config(a), SHAPES[s])
                if r.get("flops_per_device"):
                    ratio = (mf / r["n_chips"]) / r["flops_per_device"]
            except Exception:
                pass
            lines.append(
                f"| {a} | {s} | {fmt_s(t['compute_s'])} | "
                f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
                f"{t['dominant']} | {r['flops_per_device']/1e9:.1f} | "
                f"{ratio:.2f} | {hbm:.1f}GiB |"
                if ratio is not None else
                f"| {a} | {s} | {fmt_s(t['compute_s'])} | "
                f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
                f"{t['dominant']} | {r['flops_per_device']/1e9:.1f} | "
                f"n/a | {hbm:.1f}GiB |")
    return "\n".join(lines)


def summary(mesh: str) -> dict:
    res = load(mesh)
    ok = sum(1 for r in res.values()
             if "roofline" in r)
    skip = sum(1 for r in res.values() if "skipped" in r)
    err = sum(1 for r in res.values() if "error" in r)
    return {"mesh": mesh, "ok": ok, "skipped": skip, "errors": err,
            "total": len(res)}


def main():
    for mesh in sorted(os.listdir(DRYRUN)) if os.path.isdir(DRYRUN) \
            else []:
        print(f"\n## mesh {mesh}: {summary(mesh)}\n")
        print(table(mesh))


if __name__ == "__main__":
    main()
