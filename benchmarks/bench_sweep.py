"""Time-sweep (``evolve``) queries vs B independent point queries.

The ISSUE this benchmark guards: an evolution plot over B sample times
used to cost B full query dispatches — B reconstructions, B device
round-trips — even though consecutive samples differ by a handful of
ops.  ``store.evolve`` executes the whole sweep as ONE device program
(reconstruct once at ``t_lo``, then alternate apply-segment/measure in
a ``lax.scan``), so a 64-point dashboard sweep must run several times
faster than 64 independent point queries while staying bit-identical
to them.

Protocol, per layout (dense / edge): prime a churning op stream over a
bounded node set, seal segments as history grows, then time

* ``sweep``  — one ``store.evolve(measure, t_lo, t_hi)`` call,
* ``points`` — the same B sample times issued as B *independent*
  ``evaluate_many`` calls (the naive dashboard loop), and
* ``points_batched`` — the B point queries co-batched in one
  ``evaluate_many`` (the engine's own grouping, recorded for honesty —
  the sweep must beat the naive loop; the batched number shows how
  much of the win is batching vs the incremental scan),

asserting the sweep output is bit-equal to the stacked point results
before trusting any timing.  The artifact records per-layout medians,
the sweep/points speedup, and the merged-delta-tree coverage counts
(``window_cover`` leaf vs ``merged=True``) — tree ops must be strictly
below leaf ops on the long-history store.

``--smoke`` runs the down-scaled config only (CI fast lane;
``scripts/check_bench_baseline.py --bench sweep`` compares its
sweeps/sec against the committed artifact).

  PYTHONPATH=src python benchmarks/bench_sweep.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import os
import statistics
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, HERE)

OUT_JSON = os.path.join(HERE, "BENCH_sweep.json")

# sweep_units is B, the number of sampled times per evolve call; the
# acceptance criterion is the FULL config's 64-unit window
FULL = dict(n_cap=64, per_unit=24, hist_units=256, seal_every=8,
            sweep_units=64, stride=1, measure="num_edges",
            n_iters=5, warmup=1)
SMOKE = dict(n_cap=48, per_unit=12, hist_units=64, seal_every=4,
             sweep_units=32, stride=1, measure="num_edges",
             n_iters=3, warmup=1)


def _churn_unit(rng, n_cap, t, per_unit):
    from repro.core.delta import ADD_EDGE, REM_EDGE
    from repro.core.store import Op
    ops = []
    for _ in range(per_unit):
        u, v = int(rng.integers(0, n_cap)), int(rng.integers(0, n_cap))
        if u == v:
            continue
        kind = ADD_EDGE if rng.random() < 0.55 else REM_EDGE
        ops.append(Op(kind, u, v, t))
    return ops


def _build_store(layout: str, cfg: dict):
    import numpy as np

    from repro.core.delta import ADD_NODE
    from repro.core.store import Op, TemporalGraphStore

    rng = np.random.default_rng(13)
    n_cap = cfg["n_cap"]
    store = TemporalGraphStore(n_cap=n_cap, layout=layout)
    store.ingest([Op(ADD_NODE, v, v, 1) for v in range(n_cap)])
    t = 1
    for u in range(cfg["hist_units"]):
        t += 1
        store.ingest(_churn_unit(rng, n_cap, t, cfg["per_unit"]))
        if (u + 1) % cfg["seal_every"] == 0:
            store.advance_to(t)
            store.freeze_serving_state()
    store.advance_to(t)
    store.freeze_serving_state()
    return store


def _median_time(fn, n_iters: int, warmup: int) -> float:
    for _ in range(warmup):
        fn()
    secs = []
    for _ in range(n_iters):
        t0 = time.perf_counter()
        fn()
        secs.append(time.perf_counter() - t0)
    return statistics.median(secs)


def _cover_stats(view, t_lo: int, t_hi: int) -> dict:
    leaf = view.window_cover(t_lo, t_hi)
    tree = view.window_cover(t_lo, t_hi, merged=True)
    return {
        "leaf_items": len(leaf),
        "leaf_ops": int(sum(s.n_ops for s in leaf)),
        "tree_items": len(tree),
        "tree_ops": int(sum(s.n_ops for s in tree)),
    }


def measure_layout(layout: str, cfg: dict) -> dict:
    import numpy as np

    from repro.core.plans import Query

    store = _build_store(layout, cfg)
    stride = cfg["stride"]
    t_hi = store.t_cur - 1
    t_lo = t_hi - (cfg["sweep_units"] - 1) * stride
    assert t_lo >= 2, (t_lo, store.t_cur)
    measure = cfg["measure"]
    ts = list(range(t_lo, t_hi + 1, stride))
    point_qs = [Query("point", "global", measure, t_k=t) for t in ts]

    # bit-exactness gate before any timing is trusted
    swept = np.asarray(store.evolve(measure, t_lo, t_hi, stride=stride))
    pts = np.asarray(store.evaluate_many(point_qs))
    if not np.array_equal(swept, pts):
        raise AssertionError(
            f"sweep != points on {layout}: {swept} vs {pts}")

    sweep_s = _median_time(
        lambda: store.evolve(measure, t_lo, t_hi, stride=stride),
        cfg["n_iters"], cfg["warmup"])

    def points_independent():
        for q in point_qs:
            store.evaluate_many([q])

    points_s = _median_time(points_independent, cfg["n_iters"],
                            cfg["warmup"])
    batched_s = _median_time(lambda: store.evaluate_many(point_qs),
                             cfg["n_iters"], cfg["warmup"])

    cell = {
        "samples": len(ts),
        "window": [int(t_lo), int(t_hi)],
        "sweep_median_s": sweep_s,
        "points_independent_median_s": points_s,
        "points_batched_median_s": batched_s,
        "speedup_vs_points": points_s / sweep_s if sweep_s > 0 else 0.0,
        "speedup_vs_batched": batched_s / sweep_s if sweep_s > 0 else 0.0,
        "sweeps_per_sec": (1.0 / sweep_s) if sweep_s > 0 else 0.0,
    }
    if layout == "dense":
        view = store.delta_view()
        cell["cover"] = {
            "sweep_window": _cover_stats(view, t_lo, t_hi),
            "full_history": _cover_stats(view, 0, store.t_cur),
        }
        full = cell["cover"]["full_history"]
        if full["tree_ops"] >= full["leaf_ops"]:
            raise AssertionError(
                "merged tree did not shrink the full-history cover: "
                f"{full}")
    return cell


def run_sweep(cfg: dict) -> dict:
    out: dict = {"config": dict(cfg)}
    for layout in ("dense", "edge"):
        cell = measure_layout(layout, cfg)
        out[layout] = cell
        print(f"{layout:5s}: sweep B={cell['samples']} "
              f"{cell['sweep_median_s'] * 1e3:7.2f} ms vs points "
              f"{cell['points_independent_median_s'] * 1e3:8.2f} ms "
              f"({cell['speedup_vs_points']:5.1f}x, batched "
              f"{cell['speedup_vs_batched']:4.1f}x)", flush=True)
    full = out["dense"]["cover"]["full_history"]
    print(f"cover (full history): tree {full['tree_items']} items / "
          f"{full['tree_ops']} ops vs leaf {full['leaf_items']} items / "
          f"{full['leaf_ops']} ops", flush=True)
    # the guarded metric: whole-sweep dispatch throughput on the
    # default layout — a regression to per-sample dispatch tanks it
    out["sweeps_per_sec"] = out["dense"]["sweeps_per_sec"]
    out["speedup_vs_points"] = min(
        out["dense"]["speedup_vs_points"], out["edge"]["speedup_vs_points"])
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled sweep only (CI fast lane)")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()

    from artifacts import make_artifact, write_artifact

    results = {"smoke": run_sweep(SMOKE)}
    if not args.smoke:
        results["full"] = run_sweep(FULL)
    write_artifact(args.out, make_artifact("sweep", results))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
