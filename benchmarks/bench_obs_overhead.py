"""Observability overhead benchmark: serving qps off / on / tracing.

The unified metrics layer rides every hot path (engine dispatch,
frontend scheduling, swap phases, WAL appends), so its cost contract
is explicit: **metrics on must stay within ~3% of metrics off** for
the serving loop, and tracing adds only the span-record cost on top.
This bench measures exactly that — the same closed serve loop (ingest
bursts + epoch swaps + batched historical queries through the
frontend) three times:

* ``off``   — the session is built on a ``NullRegistry`` (every child
  op is a shared no-op) and no slow-query log; the "observability
  compiled out" floor.
* ``on``    — a real ``MetricsRegistry`` (the default production
  configuration) plus the slow-query log at its default threshold.
* ``trace`` — ``on`` plus an installed bounded-ring ``Tracer``, so
  every span site records.

Each mode runs in its own subprocess (fresh jit cache — the house
rule) and reports the best of ``repeats`` measured windows, which
de-noises shared-CI jitter better than means.  The artifact records
``overhead_pct`` (on vs off) and ``trace_overhead_pct`` (trace vs
off); the in-script gate fails when on-vs-off overhead exceeds
``3 * --slack`` percent.

  PYTHONPATH=src python benchmarks/bench_obs_overhead.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, HERE)

OUT_JSON = os.path.join(HERE, "BENCH_obs_overhead.json")
MODES = ("off", "on", "trace")

FULL = dict(n_cap=64, prime_units=240, per_unit=32, n_bursts=120,
            burst=8, ingest_every=6, swap_every=24, warm_windows=2,
            repeats=5, seed=11)
SMOKE = dict(n_cap=64, prime_units=60, per_unit=16, n_bursts=40,
             burst=8, ingest_every=6, swap_every=20, warm_windows=2,
             repeats=3, seed=11)


def serve_loop(mode: str, cfg: dict) -> dict:
    """One mode's closed loop; returns {"qps": best, "qps_runs": [...]}."""
    import numpy as np

    from repro.api import GraphSession
    from repro.core import ADD_EDGE, ADD_NODE, REM_EDGE, Query
    from repro.obs.metrics import MetricsRegistry, NullRegistry
    from repro.obs.trace import Tracer, install_tracer, uninstall_tracer

    rng = np.random.default_rng(cfg["seed"])
    if mode == "off":
        reg, slow_ms = NullRegistry(), None
    else:
        reg, slow_ms = MetricsRegistry(), 250.0
    sess = GraphSession(n_cap=cfg["n_cap"], metrics=reg,
                        slow_query_ms=slow_ms)
    if mode == "trace":
        install_tracer(Tracer(capacity=4096))

    # prime: node set + churn history (log >> graph, the paper regime)
    n = cfg["n_cap"]
    ops = [(ADD_NODE, v, v, 1) for v in range(n)]
    t = 1
    for _ in range(cfg["prime_units"]):
        t += 1
        for _ in range(cfg["per_unit"]):
            u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
            if u != v:
                kind = ADD_EDGE if rng.random() < 0.55 else REM_EDGE
                ops.append((kind, u, v, t))
    sess.ingest(ops)
    sess.flush()

    def burst_queries():
        # fixed half/half composition: exactly two engine group shapes
        # per burst, so compilation converges in the first window and
        # the measured windows compare mode overhead, not jit warmup
        qs = []
        for i in range(cfg["burst"]):
            tq = int(rng.integers(1, sess.watermark + 1))
            if i % 2 == 0:
                qs.append(Query(kind="point", scope="node",
                                measure="degree", t_k=tq,
                                v=int(rng.integers(0, n))))
            else:
                qs.append(Query(kind="point", scope="global",
                                measure="num_edges", t_k=tq))
        return qs

    def one_window(durations=None):
        """One serve window; optionally collects per-burst seconds."""
        nonlocal t
        for i in range(cfg["n_bursts"]):
            if (i + 1) % cfg["ingest_every"] == 0:
                t += 1
                batch = []
                for _ in range(cfg["per_unit"]):
                    u, v = (int(x) for x in rng.integers(0, n, size=2))
                    if u != v:
                        kind = ADD_EDGE if rng.random() < 0.55 else REM_EDGE
                        batch.append((kind, u, v, t))
                sess.ingest(batch)
            if (i + 1) % cfg["swap_every"] == 0:
                sess.flush()
            qs = burst_queries()
            t0 = time.perf_counter()
            sess.query_many(qs)
            if durations is not None:
                durations.append(time.perf_counter() - t0)

    for _ in range(cfg["warm_windows"]):
        one_window()                      # compile + caches warm
    durs: list[float] = []
    for _ in range(cfg["repeats"]):
        one_window(durs)
    uninstall_tracer()
    sess.close()
    # median per-burst latency: robust to single-core scheduler spikes
    # and GC pauses that wreck window-level qps on a shared box
    durs.sort()
    med = durs[len(durs) // 2]
    return {"qps": cfg["burst"] / med,
            "median_burst_ms": med * 1e3,
            "p90_burst_ms": durs[min(int(len(durs) * 0.9),
                                     len(durs) - 1)] * 1e3,
            "bursts_measured": len(durs)}


def run_config(cfg_name: str) -> dict:
    out = {}
    for mode in MODES:
        cmd = [sys.executable, os.path.abspath(__file__), "--worker",
               mode, "--config", cfg_name]
        r = subprocess.run(cmd, capture_output=True, text=True,
                           cwd=ROOT, timeout=1800)
        if r.returncode != 0:
            raise RuntimeError(f"worker {mode} failed:\n{r.stdout}\n"
                               f"{r.stderr}")
        out[mode] = json.loads(r.stdout.splitlines()[-1])
    qps_off, qps_on = out["off"]["qps"], out["on"]["qps"]
    qps_trace = out["trace"]["qps"]
    return {
        "config": dict(FULL if cfg_name == "full" else SMOKE),
        "qps_off": qps_off,
        "qps_on": qps_on,
        "qps_trace": qps_trace,
        "overhead_pct": 100.0 * (1.0 - qps_on / qps_off),
        "trace_overhead_pct": 100.0 * (1.0 - qps_trace / qps_off),
        "detail": out,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled run only (CI fast lane)")
    ap.add_argument("--out", default=OUT_JSON)
    ap.add_argument("--slack", type=float, default=3.0,
                    help="fail when on-vs-off overhead > 3%% * slack")
    ap.add_argument("--worker", default=None, choices=MODES,
                    help="internal: run one mode, print JSON")
    ap.add_argument("--config", default="smoke", choices=("smoke", "full"))
    args = ap.parse_args()

    if args.worker:
        cfg = FULL if args.config == "full" else SMOKE
        print(json.dumps(serve_loop(args.worker, cfg)))
        return 0

    from artifacts import make_artifact, write_artifact

    results = {"smoke": run_config("smoke")}
    if not args.smoke:
        results["full"] = run_config("full")
    for name, r in results.items():
        print(f"{name}: off={r['qps_off']:.1f} qps  on={r['qps_on']:.1f} "
              f"qps ({r['overhead_pct']:+.2f}%)  "
              f"trace={r['qps_trace']:.1f} qps "
              f"({r['trace_overhead_pct']:+.2f}%)")
    write_artifact(args.out, make_artifact("obs_overhead", results))
    print("wrote", args.out)

    # the cost contract, gated on the most reliable section we ran
    gate = results.get("full", results["smoke"])
    limit = 3.0 * args.slack
    if gate["overhead_pct"] > limit:
        print(f"FAIL: metrics-on overhead {gate['overhead_pct']:.2f}% "
              f"> {limit:.1f}% budget")
        return 1
    print(f"overhead within budget ({gate['overhead_pct']:.2f}% "
          f"<= {limit:.1f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
