"""Sharding rules: logical resolution, divisibility, param-path rules.
(Mesh-dependent behavior is tested in-subprocess in test_distributed.)"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import (LOGICAL_RULES, logical_rules, param_spec_for,
                            resolve, spec)


def test_resolve_no_mesh_is_none():
    # outside any mesh context every logical axis resolves to None
    assert resolve("batch", 128) is None
    assert spec("batch", None, "model", dims=(8, 4, 16)) == P(None, None,
                                                              None)


def test_param_rules_match_paths():
    s = param_spec_for("groups/l0/attn/wq", (2, 960, 15, 64))
    assert len(s) == 4  # stacked leading dim + 3 rule dims
    s2 = param_spec_for("m/groups/l0/moe/w_up/q", (2, 8, 128, 256))
    assert len(s2) == 4
    s3 = param_spec_for("embed/tok", (512, 64))
    assert len(s3) == 2
    s4 = param_spec_for("unknown/leaf", (3, 3))
    assert s4 == P(None, None)


def test_logical_rules_override():
    with logical_rules(fsdp=("pod", "data")):
        assert LOGICAL_RULES["fsdp"] == ("pod", "data")
    assert LOGICAL_RULES["fsdp"] == ("data",)


def test_norm_params_replicated():
    s = param_spec_for("groups/l0/norm1/scale", (2, 960))
    assert s == P(None, None)
