"""The unified front door (repro/api.GraphSession) and the validated
Query construction path (repro/core/plans.Query.__post_init__).

Two contracts: (1) every malformed query fails at build time with a
clear ValueError — never deep inside a jitted kernel — and watermark
violations surface as WatermarkError, itself a ValueError; (2) the
facade is a pure router: every result bit-matches the old entry points
it collapses (store.query / evaluate_many / evolve / snapshot_at).
"""
import numpy as np
import pytest

from repro.api import GraphSession, Op, Query, WatermarkError
from repro.core import TemporalGraphStore
from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE
from repro.core.generate import EvolutionParams, generate_ops

N_CAP = 64


def _ops(seed=3):
    return generate_ops(48, EvolutionParams(
        m_attach=3, lam_extra=1.0, lam_remove=1.0, p_remove_node=0.02,
        events_per_unit=6), seed=seed)


def _item(x):
    return np.asarray(x).item()


# ---------------------------------------------------------------------------
# Query validation
# ---------------------------------------------------------------------------


def test_query_valid_constructions():
    assert Query("point", "global", "num_edges", t_k=3).scope == "global"
    # scope inference: node iff v given
    assert Query(measure="degree", t_k=3, v=1).scope == "node"
    assert Query(measure="num_edges", t_k=3).scope == "global"
    q = Query("evolve", "global", "num_edges", t_k=1, t_l=9, stride=2)
    assert q.stride == 2
    Query("agg", "node", "degree", t_k=1, t_l=4, v=0, agg="max")
    Query("diff", "node", "degree", t_k=2, t_l=2, v=0)   # empty-width ok


@pytest.mark.parametrize("kw,match", [
    (dict(kind="window", measure="num_edges", t_k=1), "unknown query kind"),
    (dict(kind="point", scope="edgewise", measure="num_edges", t_k=1),
     "unknown scope"),
    (dict(measure="betweenness", t_k=1), "unknown global-scope measure"),
    (dict(measure="num_edges", v=3, t_k=1), "unknown node-scope measure"),
    (dict(kind="point", scope="node", measure="degree", t_k=1),
     "needs v="),
    (dict(kind="diff", measure="num_edges", t_k=5), "needs a time range"),
    (dict(kind="agg", measure="degree", v=0, t_k=5, t_l=3),
     "empty time range"),
    (dict(kind="evolve", measure="num_edges", t_k=1, t_l=9, stride=0),
     "stride must be >= 1"),
    (dict(kind="evolve", measure="num_edges", t_k=1, t_l=9, stride=-2),
     "stride must be >= 1"),
    (dict(kind="point", measure="num_edges", t_k=1, stride=4),
     "stride is an evolve parameter"),
    (dict(kind="agg", measure="degree", v=0, t_k=1, t_l=4, agg="median"),
     "unknown aggregate"),
])
def test_query_rejects_malformed(kw, match):
    with pytest.raises(ValueError, match=match):
        Query(**kw)


def test_watermark_error_is_a_valueerror():
    assert issubclass(WatermarkError, ValueError)
    assert issubclass(WatermarkError, RuntimeError)  # legacy handlers
    s = GraphSession(n_cap=8, stale="raise")
    s.ingest([(ADD_NODE, 0, 0, 1)])
    s.flush()
    with pytest.raises(ValueError):
        s.query("num_nodes", t=99)


# ---------------------------------------------------------------------------
# GraphSession facade
# ---------------------------------------------------------------------------


def test_session_inmemory_flow():
    with GraphSession(n_cap=16) as s:
        s.ingest([(ADD_NODE, 0, 0, 1), (ADD_NODE, 1, 1, 1),
                  Op(ADD_EDGE, 0, 1, 2)])
        # default stale="block": the session sees its own writes
        assert _item(s.query("degree", t=2, v=0)) == 1
        assert s.watermark == 2
        s.ingest([(REM_EDGE, 0, 1, 3)])
        assert _item(s.query("num_edges", t=3)) == 0
        got = s.query_many([Query("point", "global", "num_edges", t_k=2),
                            Query("point", "node", "degree", t_k=3, v=1)])
        assert [_item(x) for x in got] == [1, 0]
        sweep = s.sweep("num_edges", t_lo=1, t_hi=3)
        np.testing.assert_array_equal(sweep, [0, 1, 0])
        g = s.snapshot_at(2)
        assert _item(g.nodes.sum()) == 2
        st = s.stats()
        assert st["watermark"] == 3 and "pending_ops" in st
    with pytest.raises(ValueError):
        GraphSession()                   # in-memory needs n_cap


def test_session_requires_query_xor_kwargs():
    s = GraphSession(n_cap=8)
    q = Query("point", "global", "num_nodes", t_k=1)
    with pytest.raises(ValueError, match="not both"):
        s.query(q, t=1)
    s.ingest([(ADD_NODE, 0, 0, 1)])
    assert _item(s.query(q)) == 1        # Query object alone is fine


@pytest.mark.parametrize("layout", ["dense", "edge"])
def test_facade_parity_with_direct_paths(layout):
    """The facade routes, never reinterprets: results bit-match the
    direct store entry points it collapses."""
    ops = _ops()
    t_max = max(o.t for o in ops)
    direct = TemporalGraphStore(n_cap=N_CAP, layout=layout)
    direct.ingest(ops)
    direct.advance_to(t_max)

    s = GraphSession(n_cap=N_CAP, layout=layout)
    s.ingest(ops)
    s.flush()
    assert s.watermark == t_max

    qs = []
    for t in (1, t_max // 2, t_max):
        qs.append(Query("point", "global", "num_edges", t_k=t))
        qs.append(Query("point", "node", "degree", t_k=t, v=2))
    qs.append(Query("agg", "node", "degree", t_k=1, t_l=t_max, v=2,
                    agg="max"))
    got = s.query_many(qs)
    ref = direct.evaluate_many(qs)
    for g, r in zip(got, ref):
        assert np.array_equal(np.asarray(g), np.asarray(r))

    np.testing.assert_array_equal(
        s.sweep("num_edges", t_lo=1, t_hi=t_max, stride=2),
        direct.evolve("num_edges", 1, t_max, stride=2))

    g_f, g_d = s.snapshot_at(t_max // 2), direct.snapshot_at(t_max // 2)
    np.testing.assert_array_equal(np.asarray(g_f.nodes),
                                  np.asarray(g_d.nodes))


def test_snapshot_respects_watermark_mode():
    s = GraphSession(n_cap=8, stale="raise")
    s.ingest([(ADD_NODE, 0, 0, 1)])
    with pytest.raises(WatermarkError, match="watermark"):
        s.snapshot_at(1)                 # pending, not served, raise mode
    s.flush()
    assert _item(s.snapshot_at(1).nodes.sum()) == 1
    with pytest.raises(WatermarkError):
        s.snapshot_at(99)                # future: nothing to swap in
    blocking = GraphSession(n_cap=8)     # default "block" swaps for you
    blocking.ingest([(ADD_NODE, 0, 0, 1)])
    assert _item(blocking.snapshot_at(1).nodes.sum()) == 1


def test_session_close_is_idempotent_and_durable(tmp_path):
    root = str(tmp_path / "g")
    s = GraphSession.open(root, n_cap=16)
    s.ingest([(ADD_NODE, 0, 0, 1), (ADD_NODE, 1, 1, 2)])
    s.close()
    s.close()                            # second close is a no-op
    with GraphSession.open(root) as s2:
        # un-flushed-but-durable pending came back; ordering cursor too
        assert _item(s2.query("num_nodes", t=2)) == 2
        with pytest.raises(ValueError, match="time-ordered|immutable"):
            s2.ingest([(ADD_NODE, 2, 2, 1)])
        assert s2.ingest([(ADD_NODE, 2, 2, 3)]) == 1
