"""Mamba2/SSD: chunked dual form vs sequential recurrence, state
carry-over, decode step equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked, ssd_sequential

rng = np.random.default_rng(3)


def _inputs(b=2, s=64, nh=3, p=8, n=16):
    x = jnp.asarray(rng.standard_normal((b, s, nh, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, nh))
                     .astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (nh,)).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((b, s, n)).astype(np.float32))
    C = jnp.asarray(rng.standard_normal((b, s, n)).astype(np.float32))
    return x, dt, a, B, C


@pytest.mark.parametrize("chunk", [4, 8, 16, 32, 64])
def test_chunked_matches_sequential(chunk):
    x, dt, a, B, C = _inputs()
    y1, h1 = ssd_sequential(x, dt, a, B, C)
    y2, h2 = ssd_chunked(x, dt, a, B, C, chunk)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-4


def test_state_carry_composition():
    x, dt, a, B, C = _inputs(s=64)
    s0 = 0.1 * jnp.asarray(
        rng.standard_normal((2, 3, 8, 16)).astype(np.float32))
    ya, ha = ssd_chunked(x[:, :32], dt[:, :32], a, B[:, :32], C[:, :32],
                         8, s0)
    yb, hb = ssd_chunked(x[:, 32:], dt[:, 32:], a, B[:, 32:], C[:, 32:],
                         8, ha)
    yf, hf = ssd_sequential(x, dt, a, B, C, s0)
    assert float(jnp.max(jnp.abs(jnp.concatenate([ya, yb], 1) - yf))) \
        < 1e-4
    assert float(jnp.max(jnp.abs(hb - hf))) < 1e-4


def test_gradients_finite():
    x, dt, a, B, C = _inputs(s=32)

    def loss(x):
        y, _ = ssd_chunked(x, dt, a, B, C, 8)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(x)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_decode_equals_scan_tail():
    """decode_ssm over the last tokens == full-sequence apply_ssm."""
    from repro.config import reduced
    from repro.configs import get_config
    from repro.models.ssm import apply_ssm, decode_ssm, init_ssm
    cfg = reduced(get_config("mamba2-130m"))
    p = init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 24, cfg.d_model))
                    .astype(np.float32))
    y_full, _ = apply_ssm(p, x, cfg, return_cache=False)
    n_pre = 20
    _, cache = apply_ssm(p, x[:, :n_pre], cfg, return_cache=True)
    outs = []
    for i in range(n_pre, 24):
        y, cache = decode_ssm(p, x[:, i:i + 1], cfg, cache)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(y_dec - y_full[:, n_pre:])))
    assert err < 1e-4, err
