"""Delta checkpoint store: bit-exact reconstruction at every logged
step (Definition 4 on training state), both anchor-selection methods,
materialization policies, and the history-log query taxonomy."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (DeltaCheckpointStore, DeltaPolicy, HistoryLog,
                              save_pytree, load_into)
from repro.checkpoint.deltastore import _apply_bits, _bit_delta


def _rand_state(rng, scale=1.0):
    return {
        "w": jnp.asarray(rng.standard_normal((8, 8)) * scale,
                         dtype=jnp.float32),
        "emb": jnp.asarray(rng.standard_normal((16, 4)) * scale,
                           dtype=jnp.bfloat16),
        "step": jnp.int32(rng.integers(100)),
    }


def test_bit_delta_invertible_all_dtypes():
    rng = np.random.default_rng(0)
    for dtype in (np.float32, np.float16, np.int32):
        a = rng.standard_normal((32,)).astype(dtype)
        b = rng.standard_normal((32,)).astype(dtype)
        d = _bit_delta(b, a)
        assert np.array_equal(_apply_bits(a, d, True), b)
        assert np.array_equal(_apply_bits(b, d, False), a)


def test_restore_every_logged_step(tmp_path):
    rng = np.random.default_rng(1)
    store = DeltaCheckpointStore(str(tmp_path), DeltaPolicy(period=3))
    states = {}
    template = _rand_state(rng)
    for step in range(0, 50, 5):
        s = _rand_state(rng)
        store.save(step, s)
        states[step] = jax.device_get(s)
    for step, want in states.items():
        for method in ("time", "ops"):
            got = store.restore(step, template, method=method)
            for k in want:
                assert np.array_equal(np.asarray(got[k]),
                                      np.asarray(want[k])), (step, k)


def test_restart_resumes_from_manifest(tmp_path):
    rng = np.random.default_rng(2)
    store = DeltaCheckpointStore(str(tmp_path))
    s0 = _rand_state(rng)
    store.save(0, s0)
    s1 = _rand_state(rng)
    store.save(7, s1)
    # new process: reopen the same directory
    store2 = DeltaCheckpointStore(str(tmp_path))
    assert store2.latest_step() == 7
    got = store2.restore(7, s0)
    assert np.array_equal(np.asarray(got["w"]), np.asarray(s1["w"]))


@pytest.mark.parametrize("kind", ["periodic", "opcount", "similarity"])
def test_policies_materialize(tmp_path, kind):
    rng = np.random.default_rng(3)
    pol = DeltaPolicy(kind=kind, period=2, op_budget=10.0, drift=0.001)
    store = DeltaCheckpointStore(str(tmp_path), pol)
    for step in range(6):
        store.save(step, _rand_state(rng))
    assert len(store.manifest["snapshots"]) >= 2, kind


def test_similarity_policy_skips_when_similar(tmp_path):
    rng = np.random.default_rng(4)
    pol = DeltaPolicy(kind="similarity", drift=0.5)
    store = DeltaCheckpointStore(str(tmp_path), pol)
    base = _rand_state(rng)
    store.save(0, base)
    tweaked = dict(base)
    tweaked["w"] = base["w"] + 1e-4  # tiny drift
    store.save(1, tweaked)
    assert len(store.manifest["snapshots"]) == 1  # no new snapshot


def test_storage_delta_smaller_than_snapshots(tmp_path):
    """Deltas of sparse updates are no larger than full snapshots."""
    rng = np.random.default_rng(5)
    store = DeltaCheckpointStore(str(tmp_path),
                                 DeltaPolicy(period=1000))
    s = _rand_state(rng)
    store.save(0, s)
    for step in range(1, 5):
        s = dict(s)
        s["w"] = s["w"] + 0.01
        store.save(step, s)
    b = store.storage_bytes()
    assert b["deltas"] > 0 and b["snapshots"] > 0


def test_history_log_queries(tmp_path):
    h = HistoryLog(str(tmp_path / "h.json"))
    for step in range(0, 100, 10):
        h.record(step, {"loss": 10.0 - step / 10.0,
                        "norm/w": step * 1.0})
    assert h.point("loss", 50) == 5.0
    assert h.diff("loss", 20, 80) == 6.0
    assert h.agg("loss", 0, 90, "mean") == pytest.approx(5.5)
    assert h.agg("norm/w", 0, 90, "max") == 90.0
    # reload from disk
    h2 = HistoryLog(str(tmp_path / "h.json"))
    assert h2.point("loss", 50) == 5.0


def test_pytree_io_roundtrip(tmp_path):
    rng = np.random.default_rng(6)
    tree = _rand_state(rng)
    p = str(tmp_path / "x.npz")
    save_pytree(tree, p)
    back = load_into(jax.eval_shape(lambda: tree), p)
    for k in tree:
        assert np.array_equal(np.asarray(back[k]), np.asarray(tree[k]))
        assert back[k].dtype == tree[k].dtype
