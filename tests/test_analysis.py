"""graphlint: static passes, suppressions, CLI, and the runtime
lock-order sanitizer.

Each static pass gets a (bad, clean) fixture pair: the bad snippet
violates the invariant and must produce exactly the expected rule;
the clean twin is the idiomatic fix and must produce nothing.  Paths
are chosen so the pass's scope matching sees the same suffixes it
sees in the real tree (``repro/serving/ingest.py`` etc.).
"""
import json
import os
import subprocess
import sys
import textwrap
import threading

import pytest

from repro.analysis import analyze_files, analyze_paths, lockdep
from repro.analysis.base import parse_source
from repro.analysis.registry import create_passes, rule_catalog

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def lint(src, relpath, select=None):
    pf = parse_source(relpath, textwrap.dedent(src))
    return analyze_files([pf], select)


def rules_of(report):
    return sorted({f.rule for f in report.findings})


# ------------------------------------------------------------ registry

def test_registry_catalog_lists_all_passes():
    rows = rule_catalog()
    passes = {r[0] for r in rows}
    rules = {r[1] for r in rows}
    assert passes == {"lock-discipline", "wal-ordering",
                      "epoch-immutability", "jax-hotpath",
                      "clock-discipline"}
    assert {"lock-order", "unlocked-mutation", "wal-order",
            "epoch-freeze", "host-sync", "jit-unhashable-default",
            "clock"} <= rules


def test_registry_select_by_rule_and_unknown():
    assert [p.name for p in create_passes(["clock"])] == \
        ["clock-discipline"]
    with pytest.raises(KeyError):
        create_passes(["no-such-rule"])


# ----------------------------------------------------- lock-discipline

BAD_UNLOCKED = """
    import threading

    class Registry:
        def __init__(self):
            self._lock = threading.Lock()
            self._families = {}

        def add(self, name, fam):
            self._families[name] = fam
"""

CLEAN_LOCKED = """
    import threading

    class Registry:
        def __init__(self):
            self._lock = threading.Lock()
            self._families = {}

        def add(self, name, fam):
            with self._lock:
                self._families[name] = fam
"""


def test_unlocked_mutation_flagged_and_fixed():
    bad = lint(BAD_UNLOCKED, "repro/obs/reg.py", ["lock-discipline"])
    assert rules_of(bad) == ["unlocked-mutation"]
    assert "_families" in bad.findings[0].message
    clean = lint(CLEAN_LOCKED, "repro/obs/reg.py", ["lock-discipline"])
    assert clean.ok


BAD_ORDER = """
    import threading

    class Two:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()
            self._families = {}

        def one(self):
            with self._a:
                with self._b:
                    self._families["x"] = 1

        def other(self):
            with self._b:
                with self._a:
                    self._families["y"] = 2
"""


def test_lock_order_inversion_flagged():
    bad = lint(BAD_ORDER, "repro/obs/two.py", ["lock-discipline"])
    assert "lock-order" in rules_of(bad)
    msg = " ".join(f.message for f in bad.findings
                   if f.rule == "lock-order")
    assert "_a" in msg and "_b" in msg
    # same nesting order everywhere -> no cycle
    clean_src = BAD_ORDER.replace(
        "with self._b:\n                with self._a:",
        "with self._a:\n                with self._b:")
    clean = lint(clean_src, "repro/obs/two.py", ["lock-discipline"])
    assert "lock-order" not in rules_of(clean)


def test_nonreentrant_self_nesting_flagged():
    src = """
        import threading

        class Once:
            def __init__(self):
                self._lock = threading.Lock()

            def f(self):
                with self._lock:
                    self._g()

            def _g(self):
                with self._lock:
                    pass
    """
    bad = lint(src, "repro/obs/once.py", ["lock-discipline"])
    assert "lock-order" in rules_of(bad)
    # an RLock makes the same shape legal re-entry
    clean = lint(src.replace("threading.Lock()", "threading.RLock()"),
                 "repro/obs/once.py", ["lock-discipline"])
    assert clean.ok


def test_helper_mutation_covered_by_caller_lock_is_clean():
    src = """
        import threading

        class Store:
            def __init__(self):
                self._lock = threading.Lock()
                self._pending = []

            def push(self, x):
                with self._lock:
                    self._push_locked(x)

            def _push_locked(self, x):
                self._pending.append(x)
    """
    assert lint(src, "repro/obs/store.py", ["lock-discipline"]).ok


# -------------------------------------------------------- wal-ordering

BAD_WAL = """
    class Store:
        def append(self, batch):
            self._pending.extend(batch)
            self._persist.log_pending(batch)
"""

CLEAN_WAL = """
    class Store:
        def append(self, batch):
            self._persist.log_pending(batch)
            self._pending.extend(batch)
"""


def test_wal_order_ack_before_log_flagged():
    bad = lint(BAD_WAL, "serving/ingest.py", ["wal-ordering"])
    assert rules_of(bad) == ["wal-order"]
    assert lint(CLEAN_WAL, "serving/ingest.py", ["wal-ordering"]).ok
    # out of scope: same bad code elsewhere is not this pass's business
    assert lint(BAD_WAL, "repro/core/store.py", ["wal-ordering"]).ok


def test_wal_order_drain_rebind_is_not_an_ack():
    src = """
        class Store:
            def swap(self):
                pending, self._pending = self._pending, []
                self._persist.log_drain(len(pending))
                return pending
    """
    assert lint(src, "serving/ingest.py", ["wal-ordering"]).ok


# -------------------------------------------------- epoch-immutability

BAD_EPOCH = """
    def rewrite(view):
        view.segments = []
        view._cache = {}
"""


def test_epoch_freeze_write_from_non_owner_flagged():
    bad = lint(BAD_EPOCH, "repro/serving/frontend.py",
               ["epoch-immutability"])
    assert rules_of(bad) == ["epoch-freeze"]
    assert len(bad.findings) == 2
    # the owners may write the same state
    assert lint(BAD_EPOCH, "repro/core/segments.py",
                ["epoch-immutability"]).ok
    assert lint(BAD_EPOCH, "repro/core/store.py",
                ["epoch-immutability"]).ok


def test_epoch_freeze_ignores_unrelated_receivers():
    src = """
        def local_work(self):
            self.t_min = 3          # not a segment/view receiver
            batch.ops = []          # not a hinted name
    """
    assert lint(src, "repro/serving/frontend.py",
                ["epoch-immutability"]).ok


# --------------------------------------------------------- jax-hotpath

BAD_SYNC = """
    import jax.numpy as jnp

    def hot(x):
        y = jnp.sum(x * x)
        return float(y)
"""

CLEAN_SYNC = """
    import jax.numpy as jnp

    def hot(x):
        return jnp.sum(x * x)
"""


def test_host_sync_on_device_value_flagged():
    bad = lint(BAD_SYNC, "repro/core/engine.py", ["jax-hotpath"])
    assert rules_of(bad) == ["host-sync"]
    assert lint(CLEAN_SYNC, "repro/core/engine.py",
                ["jax-hotpath"]).ok
    # plain host ints are not device values
    assert lint("def f(t):\n    return int(t)\n",
                "repro/core/engine.py", ["jax-hotpath"]).ok


def test_jit_unhashable_default_flagged():
    src = """
        import jax

        @jax.jit
        def f(x, opts={}):
            return x
    """
    bad = lint(src, "repro/core/engine.py", ["jax-hotpath"])
    assert "jit-unhashable-default" in rules_of(bad)
    clean = src.replace("opts={}", "opts=None")
    assert lint(clean, "repro/core/engine.py", ["jax-hotpath"]).ok


# ----------------------------------------------------- clock-discipline

BAD_CLOCK = """
    import time

    def stamp():
        return time.time()
"""


def test_clock_rule_scope_and_fix():
    bad = lint(BAD_CLOCK, "repro/core/metrics_user.py",
               ["clock-discipline"])
    assert rules_of(bad) == ["clock"]
    # obs/ owns the clock; same code there is fine
    assert lint(BAD_CLOCK, "repro/obs/clock.py",
                ["clock-discipline"]).ok
    clean = """
        from repro.obs import clock

        def stamp():
            return clock.now()
    """
    assert lint(clean, "repro/core/metrics_user.py",
                ["clock-discipline"]).ok


def test_clock_rule_catches_from_import_and_datetime():
    src = """
        from time import perf_counter
        import datetime

        def f():
            return perf_counter(), datetime.datetime.now()
    """
    bad = lint(src, "repro/core/x.py", ["clock-discipline"])
    assert rules_of(bad) == ["clock"]
    assert len(bad.findings) >= 2


# --------------------------------------------------------- suppression

def test_suppression_moves_finding_and_keeps_reason():
    src = """
        import time

        def stamp():
            return time.time()  # graphlint: ignore[clock] boot banner only
    """
    rep = lint(src, "repro/core/x.py", ["clock-discipline"])
    assert rep.ok
    assert len(rep.suppressed) == 1
    finding, reason = rep.suppressed[0]
    assert finding.rule == "clock"
    assert reason == "boot banner only"


def test_suppression_standalone_line_and_star():
    src = """
        import time

        def stamp():
            # graphlint: ignore[*] measured host wall time on purpose
            return time.time()
    """
    rep = lint(src, "repro/core/x.py", ["clock-discipline"])
    assert rep.ok and len(rep.suppressed) == 1


def test_suppression_for_other_rule_does_not_apply():
    src = """
        import time

        def stamp():
            return time.time()  # graphlint: ignore[wal-order] wrong rule
    """
    rep = lint(src, "repro/core/x.py", ["clock-discipline"])
    assert not rep.ok


# ----------------------------------------------------------------- CLI

def run_cli(*args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "graphlint.py"),
         *args],
        capture_output=True, text=True, cwd=ROOT)


def test_cli_exit_codes(tmp_path):
    pkg = tmp_path / "repro" / "core"
    pkg.mkdir(parents=True)
    bad = pkg / "bad.py"
    bad.write_text("import time\n\ndef f():\n    return time.time()\n")
    proc = run_cli(str(tmp_path))
    assert proc.returncode == 1
    assert "clock" in proc.stdout

    bad.write_text("def f():\n    return 1\n")
    proc = run_cli(str(tmp_path))
    assert proc.returncode == 0
    assert "0 findings" in proc.stdout

    assert run_cli("--list").returncode == 0
    assert run_cli("--select", "bogus", str(tmp_path)).returncode == 2


def test_cli_json_format(tmp_path):
    pkg = tmp_path / "serving"
    pkg.mkdir(parents=True)
    (pkg / "ingest.py").write_text(textwrap.dedent("""
        class S:
            def append(self, b):
                self._pending.extend(b)
                self._persist.log_pending(b)
    """))
    proc = run_cli("--format", "json", str(tmp_path))
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload["findings"][0]["rule"] == "wal-order"
    assert payload["files"] == 1


def test_repo_is_clean():
    """The gate CI enforces: the shipped tree has zero unsuppressed
    findings (suppressions are allowed, but each carries a reason)."""
    rep = analyze_paths([os.path.join(ROOT, "src", "repro")])
    assert rep.ok, "\n" + "\n".join(f.render() for f in rep.findings)
    for finding, reason in rep.suppressed:
        assert reason.strip(), f"suppression without reason: {finding}"


# ------------------------------------------------------------- lockdep

@pytest.fixture
def sanitizer():
    """Fresh lockdep session (independent of the --lockdep autouse
    fixture, which steps aside when a test drives enable itself)."""
    was = lockdep.enabled()
    if was:
        lockdep.disable()
    lockdep.enable()
    try:
        yield lockdep
    finally:
        lockdep.disable()
        if was:
            lockdep.enable()


def test_lockdep_detects_ab_ba_inversion_deterministically(sanitizer):
    a = threading.Lock()
    b = threading.Lock()
    raised = []

    def first():
        with a:
            with b:
                pass

    def second():
        try:
            with b:
                with a:
                    pass
        except lockdep.LockOrderError as exc:
            raised.append(str(exc))

    # sequential threads: no actual deadlock is possible, yet the
    # sanitizer must still flag the inverted order -- that is the point
    t1 = threading.Thread(target=first)
    t1.start(); t1.join()
    t2 = threading.Thread(target=second)
    t2.start(); t2.join()
    assert len(raised) == 1
    assert "inversion" in raised[0]
    assert len(sanitizer.order_graph()) >= 1


def test_lockdep_consistent_order_and_rlock_reentry(sanitizer):
    a = threading.Lock()
    r = threading.RLock()
    with a:
        with r:
            with r:            # re-entry: no edge, no error
                pass
    with a:
        with r:
            pass               # same order again: fine
    g = sanitizer.order_graph()
    assert any(g.values())


def test_lockdep_self_deadlock_raises_instead_of_hanging(sanitizer):
    lk = threading.Lock()
    lk.acquire()
    with pytest.raises(lockdep.LockOrderError, match="self-deadlock"):
        lk.acquire()
    # try-acquire must keep its non-blocking semantics
    assert lk.acquire(blocking=False) is False
    lk.release()


def test_lockdep_condition_wait_keeps_bookkeeping(sanitizer):
    cv = threading.Condition()
    done = []

    def waiter():
        with cv:
            while not done:
                cv.wait(timeout=2)

    t = threading.Thread(target=waiter)
    t.start()
    with cv:
        done.append(1)
        cv.notify_all()
    t.join(timeout=5)
    assert not t.is_alive()


def test_lockdep_reset_forgets_history(sanitizer):
    a = threading.Lock()
    b = threading.Lock()
    with a:
        with b:
            pass
    sanitizer.reset()
    with b:
        with a:                # inverse of pre-reset order: no error
            pass
    assert sanitizer.order_graph() != {}


def test_lockdep_disable_restores_real_primitives():
    was = lockdep.enabled()
    if was:
        lockdep.disable()
    real = threading.Lock
    lockdep.enable()
    assert threading.Lock is not real
    lockdep.disable()
    assert threading.Lock is real
    if was:
        lockdep.enable()
