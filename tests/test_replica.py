"""Replication layer (repro/replica): segment shipping, read replicas,
watermark routing — hardened under injected faults and real kill -9.

The acceptance contract (ISSUE 8): every query a replica answers
bit-matches a from-scratch oracle at the answering replica's
watermark, under dropped/delayed/torn/bit-flipped fetches, under
kill -9 of replicas, and under kill -9 of the writer; killed replicas
rejoin by manifest-diff catch-up alone (never re-shipping history
they already hold).
"""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

import persist_harness as harness
from test_persist import _assert_bitequal, _child_env, _grid, _oracle
from repro.core import Query
from repro.replica import (FaultInjector, FaultRule, FaultyTransport,
                           InjectedFault, LocalDirTransport, QueryRouter,
                           ReadReplica, ReplicaDown, ReplicaSyncError,
                           SegmentPublisher, TransportError)
from repro.serving.frontend import OverloadError
from repro.serving.ingest import WatermarkError

W_HARNESS = os.path.join(os.path.dirname(__file__), "persist_harness.py")
R_HARNESS = os.path.join(os.path.dirname(__file__), "replica_harness.py")


def _stream_writer(tmp_path, *, units=None, swap_every=harness.SWAP_EVERY):
    """In-process durable writer + publisher over the fixed stream."""
    from repro.api import GraphSession
    s = GraphSession.open(str(tmp_path / "writer"), n_cap=harness.N_CAP,
                          segment_min_ops=harness.SEGMENT_MIN_OPS)
    pub = s.publish_to(str(tmp_path / "pub"))
    for i, unit in enumerate(units if units is not None
                             else harness.proposal_units()):
        s.ingest(unit)
        if (i + 1) % swap_every == 0:
            s.flush()
    s.flush()
    return s, pub


def _check_replica_exact(replica, oracle) -> None:
    w = replica.watermark
    assert w >= 1
    qs = _grid(1, w)
    _assert_bitequal(replica.evaluate_many(qs), oracle.evaluate_many(qs),
                     ctx=f"replica@{w}")


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------


def test_fault_injector_schedules():
    inj = FaultInjector(seed=3)
    inj.add("p", "raise", nth=2)
    inj.check("p")                       # 1st: clean
    with pytest.raises(InjectedFault):
        inj.check("p")                   # 2nd: fires
    inj.check("p")                       # one-shot: consumed
    assert inj.fired == [("p", "raise", 2)]

    inj.add("q", "drop", at=(7, 9))
    inj.check("q", value=5)
    with pytest.raises(TransportError):
        inj.check("q", value=7)
    with pytest.raises(TransportError):
        inj.check("q", value=9)
    inj.check("q", value=7)              # each value one-shot

    inj.add("r", "eio", every=3)
    hits = 0
    for _ in range(9):
        try:
            inj.check("r")
        except OSError:
            hits += 1
    assert hits == 3


def test_fault_injector_corruptions_deterministic():
    data = bytes(range(64))
    a = FaultInjector(seed=11)
    a.add("f", "bit_flip", every=1)
    b = FaultInjector(seed=11)
    b.add("f", "bit_flip", every=1)
    flips_a = [a.corrupt("f", data) for _ in range(5)]
    flips_b = [b.corrupt("f", data) for _ in range(5)]
    assert flips_a == flips_b            # seeded: schedules replay
    assert all(f != data and len(f) == len(data) for f in flips_a)

    torn = FaultInjector()
    torn.add("f", "torn", every=1, frac=0.25)
    assert torn.corrupt("f", data) == data[:16]

    slow = FaultInjector()
    slow.add("f", "delay", every=1, delay_s=5.0)
    t0 = time.perf_counter()
    with pytest.raises(TransportError, match="timeout"):
        slow.corrupt("f", data, timeout=0.01)
    assert time.perf_counter() - t0 < 1.0  # slept the timeout, not 5s


# ---------------------------------------------------------------------------
# shipping
# ---------------------------------------------------------------------------


def test_publisher_ships_manifest_diff(tmp_path):
    s, pub = _stream_writer(tmp_path)
    n_segments = len(s.store._segments)
    assert n_segments >= 2
    # each sealed segment crossed the wire exactly once
    assert sum(r.segments_shipped for r in pub.history) == n_segments
    assert pub.publish().segments_shipped == 0   # no change: no re-ship
    # the publish root is itself a valid store root at the watermark
    from repro.persist import open_store
    rec = open_store(str(tmp_path / "pub"), readonly=True)
    assert rec.store.t_cur == s.store.t_cur
    s.close()

    # a restarted writer's publisher resumes the diff, not the history
    pub2 = SegmentPublisher(str(tmp_path / "writer"), str(tmp_path / "pub"))
    assert pub2.publish().segments_shipped == 0


def test_local_transport_missing_file(tmp_path):
    t = LocalDirTransport(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        t.fetch("nope.bin")


# ---------------------------------------------------------------------------
# replica sync under faults
# ---------------------------------------------------------------------------


def test_replica_bitexact_and_incremental(tmp_path):
    from repro.api import GraphSession
    s = GraphSession.open(str(tmp_path / "writer"), n_cap=harness.N_CAP,
                          segment_min_ops=harness.SEGMENT_MIN_OPS)
    pub = s.publish_to(str(tmp_path / "pub"))
    replica = ReadReplica(pub.transport(), str(tmp_path / "rep"))
    oracle = _oracle("dense")

    for i, unit in enumerate(harness.proposal_units()):
        s.ingest(unit)
        if (i + 1) % harness.SWAP_EVERY == 0:
            s.flush()
            replica.sync()
            assert replica.watermark == s.watermark
            _check_replica_exact(replica, oracle)
    s.flush()
    rec = replica.sync()
    assert rec["mode"] in ("incremental", "rotate")
    _check_replica_exact(replica, oracle)
    assert replica.stats.full_rebuilds == 0
    # steady state: syncing with no writer activity moves nothing
    assert replica.sync()["mode"] == "noop"
    s.close()


def test_replica_sync_under_random_faults(tmp_path):
    """Drops, delays, torn transfers and bit flips on every fetch —
    the sync loop must converge and stay bit-exact regardless."""
    s, pub = _stream_writer(tmp_path)
    inj = FaultInjector(seed=23)
    inj.add("fetch", "drop", prob=0.25)
    inj.add("fetch", "torn", prob=0.2, frac=0.3)
    inj.add("fetch", "bit_flip", prob=0.2)
    replica = ReadReplica(FaultyTransport(pub.transport(), inj),
                          str(tmp_path / "rep"), seed=7,
                          backoff_base=0.001, backoff_max=0.01,
                          max_retries=10)
    for _ in range(20):                  # keep trying through the noise
        try:
            replica.sync()
        except ReplicaSyncError:
            continue
        if replica.watermark >= s.watermark:
            break
    assert replica.watermark == s.watermark
    assert inj.fired                     # the schedule actually bit
    _check_replica_exact(replica, _oracle("dense"))
    s.close()


def test_replica_quarantines_corrupt_segment(tmp_path):
    """A bit-flipped segment payload is caught by CRC verification
    BEFORE touching the mirror, quarantined, and re-fetched clean."""
    s, pub = _stream_writer(tmp_path)
    from repro.persist import manifest as mf
    seg0 = mf.segment_name(0)             # "segments/seg_000000.npy"
    inj = FaultInjector(seed=1)
    inj.add(f"fetch:{seg0}", "bit_flip", nth=1, offset=200)
    replica = ReadReplica(FaultyTransport(pub.transport(), inj),
                          str(tmp_path / "rep"), seed=2,
                          backoff_base=0.001)
    replica.sync()
    assert replica.stats.quarantined == 1
    qdir = os.path.join(str(tmp_path / "rep"), "quarantine")
    assert len(os.listdir(qdir)) == 1    # the corrupt payload, kept
    assert replica.stats.segments_fetched == len(s.store._segments)
    _check_replica_exact(replica, _oracle("dense"))
    s.close()


def test_replica_degrades_gracefully_then_recovers(tmp_path):
    """Transport down: sync fails after bounded retries, the replica
    keeps serving its old watermark; transport healed: it catches up."""
    from repro.api import GraphSession
    s = GraphSession.open(str(tmp_path / "writer"), n_cap=harness.N_CAP,
                          segment_min_ops=harness.SEGMENT_MIN_OPS)
    pub = s.publish_to(str(tmp_path / "pub"))
    units = harness.proposal_units()
    for unit in units[:6]:
        s.ingest(unit)
    s.flush()

    inj = FaultInjector(seed=4)
    replica = ReadReplica(FaultyTransport(pub.transport(), inj),
                          str(tmp_path / "rep"), seed=3, max_retries=3,
                          backoff_base=0.001, backoff_max=0.01)
    replica.sync()
    w_old = replica.watermark
    oracle = _oracle("dense")
    _check_replica_exact(replica, oracle)

    for unit in units[6:]:               # writer moves on
        s.ingest(unit)
    s.flush()
    inj.add("fetch", "drop", every=1)    # then the network dies
    with pytest.raises(ReplicaSyncError):
        replica.sync()
    assert replica.watermark == w_old    # still serving, just stale
    _check_replica_exact(replica, oracle)
    assert replica.stats.sync_failures == 1
    assert replica.stats.fetch_retries >= 3   # bounded backoff ran

    inj.clear("fetch")                   # network heals
    replica.sync()
    assert replica.watermark == s.watermark
    _check_replica_exact(replica, oracle)
    s.close()


def test_replica_fetch_timeout_is_bounded(tmp_path):
    s, pub = _stream_writer(tmp_path)
    inj = FaultInjector(seed=9)
    inj.add("fetch", "delay", every=1, delay_s=30.0)
    replica = ReadReplica(FaultyTransport(pub.transport(), inj),
                          str(tmp_path / "rep"), fetch_timeout=0.01,
                          max_retries=2, backoff_base=0.001)
    t0 = time.perf_counter()
    with pytest.raises(ReplicaSyncError):
        replica.sync()
    assert time.perf_counter() - t0 < 5.0   # never waits out the 30s
    s.close()


def test_replica_restart_resumes_from_mirror(tmp_path):
    """A replica restarted from its mirror serves immediately (no
    transport) and then rejoins by diff."""
    s, pub = _stream_writer(tmp_path)
    rep_root = str(tmp_path / "rep")
    r1 = ReadReplica(pub.transport(), rep_root)
    r1.sync()
    w = r1.watermark
    fetched = r1.stats.segments_fetched
    assert fetched >= 2
    del r1

    class _DeadTransport:
        def fetch(self, relpath, *, timeout=None):
            raise TransportError("source down")

    r2 = ReadReplica(_DeadTransport(), rep_root)   # writer unreachable
    assert r2.watermark == w             # serving from the mirror alone
    _check_replica_exact(r2, _oracle("dense"))

    r3 = ReadReplica(pub.transport(), rep_root, name="rejoin")
    assert r3.sync()["mode"] == "noop"    # mirror already current
    assert r3.stats.segments_fetched == 0          # diff-only rejoin
    assert r3.stats.full_rebuilds == 0
    assert r3.watermark == w
    _check_replica_exact(r3, _oracle("dense"))
    s.close()


def test_replica_hot_anchor_budget(tmp_path):
    """anchor_budget_bytes turns on replica-local materialization:
    anchors follow the replica's own traffic, under its own budget."""
    s, pub = _stream_writer(tmp_path)
    from repro.core.engine import _snapshot_bytes
    per = _snapshot_bytes(s.store.current)
    replica = ReadReplica(pub.transport(), str(tmp_path / "rep"),
                          anchor_budget_bytes=2 * per,
                          anchor_min_gap_ops=8)
    replica.sync()
    hot_t = max(2, replica.watermark // 2)
    qs = [Query("point", "global", "num_edges", t_k=hot_t)] * 50
    replica.evaluate_many(qs)            # histogram fills at hot_t
    replica.refresh_anchors()            # rebalance to local traffic
    anchors = list(replica.store.materialized.times)
    assert hot_t in anchors              # the hot time got its anchor
    assert len(anchors) <= 2             # never over local budget
    _check_replica_exact(replica, _oracle("dense"))
    s.close()


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------


class _StubReplica:
    def __init__(self, name, watermark, answer=1.0):
        self.name = name
        self.watermark = watermark
        self.answer = answer
        self.dead = False
        self.inflight = 0
        self.calls = 0

    def status(self):
        if self.dead:
            raise ConnectionError("dead")
        return {"name": self.name, "watermark": self.watermark,
                "inflight": self.inflight}

    def evaluate_many(self, queries, plan="auto", **kw):
        if self.dead:
            raise ConnectionError("dead")
        self.calls += 1
        return [self.answer] * len(queries)


def _q(t):
    return Query("point", "global", "num_edges", t_k=t)


def test_router_watermark_routing_and_failover():
    fresh = _StubReplica("fresh", watermark=20, answer=2.0)
    stale = _StubReplica("stale", watermark=10, answer=1.0)
    router = QueryRouter(heartbeat_timeout=60.0)
    router.register("fresh", fresh)
    router.register("stale", stale)

    # only the fresh replica covers t=15
    assert router.evaluate_many([_q(15)]) == [2.0]
    assert fresh.calls == 1 and stale.calls == 0
    # nobody covers t=25
    with pytest.raises(WatermarkError):
        router.evaluate_many([_q(25)])
    # fresh dies: routing t=15 to it fails over, but no one else
    # covers — the call surfaces WatermarkError and fresh is marked
    # down for everything after
    fresh.dead = True
    with pytest.raises(WatermarkError):
        router.evaluate_many([_q(15)])
    assert router.failovers == 1
    assert not [r for r in router.replicas()
                if r["name"] == "fresh"][0]["alive"]
    # t<=10 keeps flowing to the stale survivor
    assert router.evaluate_many([_q(9)]) == [1.0]
    # fresh restarts: the next heartbeat readmits it, no re-registration
    fresh.dead = False
    assert router.heartbeat() == {"fresh": True, "stale": True}
    assert router.evaluate_many([_q(15)]) == [2.0]
    # everything dead -> ReplicaDown
    fresh.dead = stale.dead = True
    router.heartbeat()
    with pytest.raises(ReplicaDown):
        router.evaluate_many([_q(5)])


def test_router_sheds_on_overload():
    r = _StubReplica("r", watermark=10)
    router = QueryRouter(max_inflight=2, heartbeat_timeout=60.0)
    router.register("r", r)
    r.inflight = 2                       # saturated (heartbeat view)
    router.heartbeat()
    with pytest.raises(OverloadError):
        router.evaluate_many([_q(5)])
    assert router.shed == 1
    r.inflight = 0
    router.heartbeat()
    assert router.evaluate_many([_q(5)]) == [1.0]


def test_router_over_live_replicas_bitexact(tmp_path):
    """Router + two real replicas at different watermarks: every
    answered query bit-matches the oracle at the ANSWERING replica's
    watermark (the acceptance clause)."""
    from repro.api import GraphSession
    s = GraphSession.open(str(tmp_path / "writer"), n_cap=harness.N_CAP,
                          segment_min_ops=harness.SEGMENT_MIN_OPS)
    pub = s.publish_to(str(tmp_path / "pub"))
    units = harness.proposal_units()
    for unit in units[:6]:
        s.ingest(unit)
    s.flush()
    r_stale = ReadReplica(pub.transport(), str(tmp_path / "r0"), name="r0")
    r_stale.sync()
    for unit in units[6:]:
        s.ingest(unit)
    s.flush()
    r_fresh = ReadReplica(pub.transport(), str(tmp_path / "r1"), name="r1")
    r_fresh.sync()
    assert r_stale.watermark < r_fresh.watermark

    router = GraphSession.open_router({"r0": r_stale, "r1": r_fresh})
    oracle = _oracle("dense")
    for t in range(1, r_fresh.watermark + 1):
        got = router.evaluate_many([_q(t)])
        ref = oracle.evaluate_many([_q(t)])
        _assert_bitequal(got, ref, ctx=f"routed t={t}")
    # the stale replica served what it covers (load spreading happened)
    assert r_stale.stats.queries_served > 0
    assert r_fresh.stats.queries_served > 0
    s.close()


# ---------------------------------------------------------------------------
# kill -9: replicas and the writer
# ---------------------------------------------------------------------------


def _run_replica_child(pub_root, rep_root, out, spec, nth, expect_kill):
    proc = subprocess.run(
        [sys.executable, R_HARNESS, pub_root, rep_root, out, spec,
         str(nth)],
        env=_child_env(), capture_output=True, text=True, timeout=600)
    if expect_kill:
        assert proc.returncode == -signal.SIGKILL, \
            (spec, proc.returncode, proc.stderr[-2000:])
    else:
        assert proc.returncode == 0, \
            (spec, proc.returncode, proc.stderr[-2000:])
        with open(out) as fh:
            return json.load(fh)


@pytest.mark.parametrize("spec,nth", [("after_sync", 1), ("mid_sync", 1)],
                         ids=["after-sync", "mid-sync"])
def test_kill9_replica_rejoins_by_diff(tmp_path, spec, nth):
    """kill -9 a replica (post-sync or mid-sync), publish more epochs,
    restart it: the rejoin fetches only the new segments and the final
    answers bit-match the oracle."""
    from repro.api import GraphSession
    s = GraphSession.open(str(tmp_path / "writer"), n_cap=harness.N_CAP,
                          segment_min_ops=harness.SEGMENT_MIN_OPS)
    pub_root = str(tmp_path / "pub")
    s.publish_to(pub_root)
    units = harness.proposal_units()
    for unit in units[:6]:
        s.ingest(unit)
    s.flush()
    n_seg_half = len(s.store._segments)

    rep_root, out = str(tmp_path / "rep"), str(tmp_path / "out.json")
    _run_replica_child(pub_root, rep_root, out, spec, nth,
                       expect_kill=True)

    for unit in units[6:]:               # writer moves on past the death
        s.ingest(unit)
    s.flush()
    n_seg_full = len(s.store._segments)
    assert n_seg_full > n_seg_half

    payload = _run_replica_child(pub_root, rep_root, out, "none", 0,
                                 expect_kill=False)
    assert payload["watermark"] == s.watermark
    oracle = _oracle("dense")
    qs = _grid(1, payload["watermark"])
    ref = [[float(x) for x in np.atleast_1d(a)]
           for a in oracle.evaluate_many(qs)]
    assert payload["answers"] == ref
    # rejoin by manifest diff ALONE: everything mirrored before the
    # kill is reused, only post-death segments cross the wire
    stats = payload["stats"]
    assert stats["full_rebuilds"] == 0
    if spec == "after_sync":
        assert stats["segments_reused"] >= n_seg_half
        assert stats["segments_fetched"] == n_seg_full - n_seg_half
    else:                                # mid-sync death: no manifest
        assert stats["segments_reused"] >= 1   # yet files were kept
    s.close()


def _spawn_writer(writer_root, pub_root, ms_per_unit=20):
    return subprocess.Popen(
        [sys.executable, W_HARNESS, writer_root, "dense", "none",
         str(ms_per_unit), pub_root],
        env=_child_env(), stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL)


def _wait_for_watermark(pub_root, t_min, timeout=300):
    from repro.persist import read_manifest
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        m = read_manifest(pub_root)
        if m is not None and m["t_sealed"] >= t_min:
            return
        time.sleep(0.05)
    raise AssertionError(f"publish root never reached t={t_min}")


def test_kill9_writer_replica_keeps_serving(tmp_path):
    """kill -9 the WRITER mid-stream: the replica keeps serving its
    watermark exactly; the restarted writer recovers, resumes
    publishing, and the replica catches up to the full stream."""
    writer_root = str(tmp_path / "writer")
    pub_root = str(tmp_path / "pub")
    final_t = harness.proposal_units()[-1][-1].t

    proc = _spawn_writer(writer_root, pub_root)
    try:
        _wait_for_watermark(pub_root, 3)
        proc.send_signal(signal.SIGKILL)   # a real, uncatchable death
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()

    replica = ReadReplica(LocalDirTransport(pub_root),
                          str(tmp_path / "rep"))
    replica.sync()
    w_dead = replica.watermark
    assert w_dead >= 3
    oracle = _oracle("dense")
    _check_replica_exact(replica, oracle)  # exact while the writer is dead
    replica.sync()                         # and syncing is a clean no-op

    proc = _spawn_writer(writer_root, pub_root, ms_per_unit=0)
    try:
        assert proc.wait(timeout=300) == 0
    finally:
        if proc.poll() is None:
            proc.kill()
    for _ in range(10):
        replica.sync()
        if replica.watermark >= final_t:
            break
    assert replica.watermark == final_t
    assert replica.watermark > w_dead
    _check_replica_exact(replica, oracle)
    assert replica.stats.full_rebuilds == 0   # diff catch-up, even here


def test_chaos_writer_kill_faulty_fetch_routed_queries(tmp_path):
    """The full chaos drill: a live writer child streams and publishes,
    two replicas poll through a fault-injecting transport, a router
    serves a query load the whole time, the writer is kill -9'd and
    restarted mid-run.  EVERY answered query must bit-match the
    from-scratch oracle (history <= any watermark is immutable, so the
    oracle is time-invariant) and the fleet must converge to the full
    stream."""
    writer_root = str(tmp_path / "writer")
    pub_root = str(tmp_path / "pub")
    final_t = harness.proposal_units()[-1][-1].t
    oracle = _oracle("dense")
    ref = {t: oracle.evaluate_many([_q(t)])[0] for t in range(1, final_t + 1)}

    replicas = []
    for i in range(2):
        inj = FaultInjector(seed=31 + i)
        inj.add("fetch", "drop", prob=0.1)
        inj.add("fetch", "bit_flip", prob=0.1)
        replicas.append(ReadReplica(
            FaultyTransport(LocalDirTransport(pub_root), inj),
            str(tmp_path / f"rep{i}"), name=f"r{i}", seed=i,
            backoff_base=0.001, backoff_max=0.01, max_retries=8))
    router = QueryRouter(heartbeat_timeout=60.0)
    for r in replicas:
        router.register(r.name, r)

    answered = 0
    proc = _spawn_writer(writer_root, pub_root)
    try:
        _wait_for_watermark(pub_root, 3)
        killed = False
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline:
            for r in replicas:
                try:
                    r.sync()
                except ReplicaSyncError:
                    pass                 # injected noise; keep serving
            router.heartbeat()
            top = max(r.watermark for r in replicas)
            if top >= 1:                 # probe the full served range
                for t in range(1, top + 1):
                    got = router.evaluate_many([_q(t)])[0]
                    assert np.array_equal(np.asarray(got),
                                          np.asarray(ref[t])), t
                    answered += 1
            if not killed and top >= 3:
                proc.send_signal(signal.SIGKILL)
                proc.wait(timeout=60)
                proc = _spawn_writer(writer_root, pub_root, ms_per_unit=0)
                killed = True
            if killed and proc.poll() == 0 and top >= final_t:
                break
        assert killed
        assert proc.wait(timeout=300) == 0
    finally:
        if proc.poll() is None:
            proc.kill()

    for r in replicas:
        for _ in range(10):
            try:
                r.sync()
            except ReplicaSyncError:
                continue
            if r.watermark >= final_t:
                break
        assert r.watermark == final_t
        _check_replica_exact(r, oracle)
    assert answered > 0
    assert router.queries_routed == answered
