"""Incremental time-sweep (`evolve`) queries + the merged-delta tree.

Acceptance contracts of the sweep executor (kernels/evolve_sweep):

* ``store.evolve(measure, t_lo, t_hi, stride)`` bit-matches B
  independent point queries over the same sample times — dense AND
  edge layouts, stride ≥ 1, windows crossing segment / anchor / epoch
  boundaries (property test with a seeded fallback).
* tree-covered ``window_delta`` (merged-delta interior nodes) feeds
  reconstructions that bit-match leaf-covered ones, with op counts
  never above the leaf cover.
* the Pallas tiled sweep kernel agrees with the scan executor.
* serving integration: sweeps land in the workload histogram
  (decayed per-sample weights) and coalesce/cache in the frontend.
"""
import numpy as np
import pytest

from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE, REM_NODE
from repro.core.plans import Query
from repro.core.store import Op, TemporalGraphStore

N = 12

SWEEPABLE = [("degree", "node"), ("num_nodes", "global"),
             ("num_edges", "global"), ("density", "global"),
             ("avg_degree", "global"), ("degree_distribution", "global")]


def _item(x):
    return np.asarray(x).item()


def _churn_chunks(rng, n_chunks=4, per_chunk=(6, 18)):
    mix = [ADD_NODE, ADD_NODE, ADD_EDGE, ADD_EDGE, ADD_EDGE, REM_EDGE,
           REM_NODE]
    chunks, t = [], 0
    for _ in range(n_chunks):
        t += 1
        chunk = []
        for _ in range(int(rng.integers(*per_chunk))):
            t += int(rng.integers(0, 2))
            kind = mix[int(rng.integers(0, len(mix)))]
            u = int(rng.integers(0, N))
            v = int(rng.integers(0, N))
            chunk.append(Op(kind, u,
                            v if kind in (ADD_EDGE, REM_EDGE) else u, t))
        chunks.append(chunk)
    return chunks


def _sweep_store(chunks, layout):
    """Freeze between chunks so the log really fragments into sealed
    segments (and the merged tree builds over them): every sweep then
    crosses segment and epoch boundaries."""
    s = TemporalGraphStore(n_cap=N, layout=layout, segment_min_ops=1)
    for chunk in chunks:
        s.ingest(chunk)
        s.advance_to(max(o.t for o in chunk))
        s.freeze_serving_state()
    return s


def _check_evolve_matches_points(s, t_lo, t_hi, stride, measure, scope, v):
    got = np.asarray(s.evolve(measure, t_lo, t_hi, stride=stride, v=v,
                              scope=scope))
    ts = list(range(int(t_lo), int(t_hi) + 1, int(stride)))
    ref = np.asarray(s.evaluate_many(
        [Query("point", scope, measure, t_k=t, v=v) for t in ts]))
    assert got.shape[0] == len(ts)
    assert got.dtype == ref.dtype, (measure, got.dtype, ref.dtype)
    assert np.array_equal(got, ref), (measure, t_lo, t_hi, stride, got,
                                      ref)


def _check_sweep_parity(chunks, layout, probe_seed=0):
    s = _sweep_store(chunks, layout)
    t_cur = s.t_cur
    rng = np.random.default_rng(probe_seed)
    for measure, scope in SWEEPABLE:
        v = int(rng.integers(0, N)) if scope == "node" else None
        # full history, a strided interior window, and a window pinned
        # at t=0 (crosses every seal + the anchor sits past t_hi)
        probes = [(0, t_cur, 1), (1, max(1, t_cur - 1), 3),
                  (0, min(5, t_cur), 2)]
        for t_lo, t_hi, stride in probes:
            _check_evolve_matches_points(s, t_lo, t_hi, stride, measure,
                                         scope, v)


# ---------------------------------------------------------------------------
# Sweep-vs-point bit-parity (property + seeded fallback)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def chunk_streams(draw):
        mix = [ADD_NODE, ADD_NODE, ADD_EDGE, ADD_EDGE, ADD_EDGE,
               REM_EDGE, REM_NODE]
        n_chunks = draw(st.integers(min_value=2, max_value=4))
        t, chunks = 0, []
        for _ in range(n_chunks):
            t += draw(st.integers(min_value=1, max_value=2))
            n_ops = draw(st.integers(min_value=2, max_value=12))
            chunk = []
            for _ in range(n_ops):
                t += draw(st.integers(min_value=0, max_value=1))
                kind = draw(st.sampled_from(mix))
                u = draw(st.integers(min_value=0, max_value=N - 1))
                v = draw(st.integers(min_value=0, max_value=N - 1))
                chunk.append(Op(kind, u,
                                v if kind in (ADD_EDGE, REM_EDGE) else u,
                                t))
            chunks.append(chunk)
        return chunks

    @given(chunk_streams(), st.sampled_from(["dense", "edge"]),
           st.sampled_from([1, 2, 3]))
    @settings(max_examples=10, deadline=None)
    def test_property_evolve_bitmatches_point_queries(chunks, layout,
                                                      stride):
        s = _sweep_store(chunks, layout)
        _check_evolve_matches_points(s, 0, s.t_cur, stride, "degree",
                                     "node", 3)
        _check_evolve_matches_points(s, 0, s.t_cur, stride, "num_edges",
                                     "global", None)

except ImportError:
    @pytest.mark.parametrize("layout", ["dense", "edge"])
    def test_property_evolve_bitmatches_point_queries(layout):
        """Seeded-random stand-in when hypothesis is unavailable."""
        for seed in range(4):
            rng = np.random.default_rng(seed)
            s = _sweep_store(_churn_chunks(rng, n_chunks=3), layout)
            stride = 1 + seed % 3
            _check_evolve_matches_points(s, 0, s.t_cur, stride, "degree",
                                         "node", 3)
            _check_evolve_matches_points(s, 0, s.t_cur, stride,
                                         "num_edges", "global", None)


@pytest.mark.parametrize("layout", ["dense", "edge"])
def test_evolve_all_measures_seeded(layout):
    """Deterministic instance over every sweepable measure (always
    runs, with or without hypothesis)."""
    rng = np.random.default_rng(42)
    _check_sweep_parity(_churn_chunks(rng, n_chunks=4), layout,
                        probe_seed=7)


def test_evolve_fallback_measure_matches_points():
    """A measure outside SWEEP_MEASURES transparently degrades to B
    point queries — same values, no sweep program."""
    rng = np.random.default_rng(5)
    s = _sweep_store(_churn_chunks(rng, n_chunks=3), "dense")
    got = np.asarray(s.evolve("triangles", 1, min(6, s.t_cur)))
    ref = np.asarray(s.evaluate_many(
        [Query("point", "global", "triangles", t_k=t)
         for t in range(1, min(6, s.t_cur) + 1)]))
    assert np.array_equal(got, ref)


def test_evolve_groups_share_one_program():
    """Sweeps sharing (measure, stride, anchor) coalesce into one
    engine group; mixed stride or measure splits them."""
    rng = np.random.default_rng(8)
    s = _sweep_store(_churn_chunks(rng, n_chunks=3), "dense")
    eng = s.engine()
    qs = [Query("evolve", "global", "num_edges", t_k=1, t_l=s.t_cur,
                stride=1),
          Query("evolve", "global", "num_edges", t_k=2, t_l=s.t_cur,
                stride=1),
          Query("evolve", "global", "num_edges", t_k=1, t_l=s.t_cur,
                stride=2)]
    res = eng.evaluate_many(qs)
    evolve_groups = [row for row in eng.last_group_stats
                     if row[0].kind == "evolve"]
    assert len(evolve_groups) == 2            # stride splits, times don't
    assert sorted(r[1] for r in evolve_groups) == [1, 2]
    for q, r in zip(qs, res):
        ts = list(range(q.t_k, q.t_l + 1, q.stride))
        assert np.asarray(r).shape[0] == len(ts)


# ---------------------------------------------------------------------------
# Merged-delta tree: tree-covered windows bit-match leaf-covered ones
# ---------------------------------------------------------------------------


def _long_store(layout="dense", n_chunks=12):
    rng = np.random.default_rng(13)
    return _sweep_store(_churn_chunks(rng, n_chunks=n_chunks,
                                      per_chunk=(8, 16)), layout)


def test_merged_tree_cover_is_never_larger():
    s = _long_store()
    view = s.delta_view()
    assert view.merged, "long sealed history must build interior nodes"
    t_cur = s.t_cur
    for t_lo, t_hi in [(0, t_cur), (0, t_cur // 2), (t_cur // 4, t_cur),
                       (3, t_cur - 3)]:
        leaf = view.window_cover(t_lo, t_hi)
        tree = view.window_cover(t_lo, t_hi, merged=True)
        assert sum(p.n_ops for p in tree) <= sum(p.n_ops for p in leaf)
        assert len(tree) <= len(leaf)
    # on the full history the collapse must strictly win (the churn mix
    # guarantees superseded ops)
    full_leaf = view.window_cover(0, t_cur)
    full_tree = view.window_cover(0, t_cur, merged=True)
    assert sum(p.n_ops for p in full_tree) < sum(p.n_ops
                                                 for p in full_leaf)


@pytest.mark.parametrize("layout", ["dense", "edge"])
def test_merged_window_reconstruction_bitmatches_leaf(layout):
    """Reconstructing through a tree-covered window delta gives the
    same bits as through the leaf-covered one, forward and backward."""
    from repro.core.reconstruct import reconstruct_dense, reconstruct_edge
    s = _long_store(layout)
    view = s.delta_view()
    t_cur = s.t_cur
    anchor = s.current if layout == "dense" else s.current_edge_snapshot()
    rec = reconstruct_dense if layout == "dense" else reconstruct_edge
    for t in range(0, t_cur + 1, max(1, t_cur // 9)):
        d_leaf = view.window_delta(min(t, t_cur), t_cur)
        d_tree = view.window_delta(min(t, t_cur), t_cur, merged=True)
        a = rec(anchor, d_leaf, t_cur, t)
        b = rec(anchor, d_tree, t_cur, t)
        if layout == "edge":
            a, b = a.to_dense(), b.to_dense()
        assert np.array_equal(np.asarray(a.adj), np.asarray(b.adj)), t
        assert np.array_equal(np.asarray(a.nodes), np.asarray(b.nodes)), t


def test_merged_nodes_participate_in_residency():
    """Interior nodes count against (and are restored by) the same
    device-residency budget as leaf segments."""
    s = _long_store()
    view = s.delta_view()
    # touch every merged node so each holds a device array
    view.window_delta(0, s.t_cur, merged=True)
    for node in view.merged.values():
        node.delta  # noqa: B018 — property access builds the device log
    total = view.device_bytes()
    assert any(n.is_resident for n in view.merged.values())
    # a zero budget spills everything except the pinned hot tail —
    # merged nodes are LRU citizens, none may survive
    view.ensure_device(0)
    hot = sum(seg.device_bytes() for seg in view.segments[-2:])
    assert view.device_bytes() == hot < total
    assert not any(n.is_resident for n in view.merged.values())
    # queries after the spill transparently rebuild what they need
    view.window_delta(0, s.t_cur, merged=True)
    assert view.device_bytes() > hot


# ---------------------------------------------------------------------------
# Pallas tiled sweep kernel vs the scan executor
# ---------------------------------------------------------------------------


def test_pallas_sweep_kernel_matches_scan():
    from repro.core.reconstruct import reconstruct_dense
    from repro.kernels.evolve_sweep import sweep_degree_series
    s = _long_store(n_chunks=6)
    view = s.delta_view()
    t_cur = s.t_cur
    d = view.window_delta(0, t_cur)
    t_lo, stride, nb = 1, 2, 8
    g0 = reconstruct_dense(s.current, d, t_cur, t_lo)
    series, overflow = sweep_degree_series(
        g0.degrees(), d, t_lo, t_lo + (nb - 1) * stride, stride, nb,
        tile=4, cap=1024)
    assert not bool(overflow)
    for b in range(nb):
        t = min(t_lo + b * stride, t_cur)
        ref = reconstruct_dense(s.current, d, t_cur, t).degrees()
        assert np.array_equal(np.asarray(series[b]), np.asarray(ref)), b


# ---------------------------------------------------------------------------
# Serving integration: workload histogram + frontend coalescing
# ---------------------------------------------------------------------------


def test_workload_records_swept_times():
    from repro.serving.policy import WorkloadStats
    stats = WorkloadStats()
    stats.record_queries([Query("evolve", "global", "num_edges", t_k=4,
                                t_l=11, stride=2)])
    hist = stats.histogram()
    assert set(hist) == {4, 6, 8, 10}
    # one sweep carries one query's mass, spread over its samples
    assert all(abs(w - 0.25) < 1e-9 for w in hist.values())
    assert abs(stats.total - 1.0) < 1e-9
    stats.record_queries([Query("point", "global", "num_edges", t_k=6)])
    assert abs(stats.histogram()[6] - 1.25) < 1e-9


def test_frontend_sweep_coalesce_and_cache():
    from repro.serving import LiveGraphStore
    from repro.serving.frontend import MicroBatchFrontend
    rng = np.random.default_rng(21)
    chunks = _churn_chunks(rng, n_chunks=3)
    live = LiveGraphStore(n_cap=N)
    for chunk in chunks:
        live.append(chunk)
        live.swap()
    fe = MicroBatchFrontend(live, max_batch=8)
    t_hi = live.t_served
    f1 = fe.submit_sweep("num_edges", 0, t_hi, stride=1)
    f2 = fe.submit_sweep("num_edges", 0, t_hi, stride=1)   # dupe
    f3 = fe.submit_sweep("num_edges", 0, t_hi, stride=2)   # distinct
    fe.flush()
    r1, r2, r3 = f1.result(), f2.result(), f3.result()
    assert np.array_equal(r1, r2)
    assert fe.stats.coalesced_dupes == 1
    assert len(r3) == t_hi // 2 + 1
    ref = np.asarray(live.evaluate_many(
        [Query("point", "global", "num_edges", t_k=t)
         for t in range(0, t_hi + 1)]))
    assert np.array_equal(np.asarray(r1), ref)
    # second submit of the same sweep inside the epoch: exact-cache hit
    before = fe.stats.cache_hits
    f4 = fe.submit_sweep("num_edges", 0, t_hi, stride=1)
    assert fe.stats.cache_hits == before + 1
    assert np.array_equal(f4.result(), r1)
