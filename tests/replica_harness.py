"""Deterministic replica child for the kill -9 replica chaos tests.

Run as::

    python tests/replica_harness.py PUBLISH_ROOT LOCAL_ROOT OUT_JSON \
        KILL_SPEC NTH

The child opens a ``ReadReplica`` of PUBLISH_ROOT mirrored at
LOCAL_ROOT (a restart reopens the same mirror — that is the point),
syncs until it has absorbed everything the writer published, answers
the standard query grid at its own watermark, and writes answers +
lifetime stats to OUT_JSON (atomically), exiting 0.

Kill specs make the death genuine (SIGKILL from inside, never an
exception path):

* ``none``          — run to completion.
* ``after_sync``    — die right after the NTH successful sync: the
  mirror is a complete checkpoint; the restart must rejoin by
  manifest *diff* alone (``segments_reused`` counts its old files).
* ``mid_sync``      — die inside sync NTH, after segment files hit
  the mirror but BEFORE the local manifest rename: the mirror must
  still be a valid (older) store root on restart.

Exit codes: 0 done, 3 the kill spec never fired.
"""
import json
import os
import signal
import sys


def _kill():
    os.kill(os.getpid(), signal.SIGKILL)


def main(argv) -> int:
    publish_root, local_root, out_json = argv[0], argv[1], argv[2]
    spec = argv[3] if len(argv) > 3 else "none"
    nth = int(argv[4]) if len(argv) > 4 else 1

    import numpy as np

    from repro.persist import manifest as mf
    from repro.replica import LocalDirTransport, ReadReplica

    replica = ReadReplica(LocalDirTransport(publish_root), local_root,
                          name="child", seed=5)
    if spec == "mid_sync":
        # fire between the mirrored WAL write and the local manifest
        # rename of the NTH sync: counts manifest writes into the
        # local root only
        orig = mf.write_manifest
        state = {"n": 0}

        def hooked(root, manifest):
            if os.path.abspath(root) == os.path.abspath(local_root):
                state["n"] += 1
                if state["n"] == nth:
                    _kill()
            return orig(root, manifest)

        mf.write_manifest = hooked

    # sync until the mirror has caught the publish root's watermark
    target = None
    for _ in range(2000):
        pub = mf.read_manifest(publish_root)
        if pub is not None:
            target = int(pub["t_sealed"])
        try:
            replica.sync()
        except Exception:
            continue
        if spec == "after_sync" and replica.stats.syncs >= nth:
            _kill()
        if target is not None and replica.watermark >= target:
            break

    from test_persist import _grid
    qs = _grid(1, max(replica.watermark, 1))
    answers = [[float(x) for x in np.atleast_1d(a)]
               for a in replica.evaluate_many(qs)]
    payload = {
        "watermark": replica.watermark,
        "answers": answers,
        "stats": replica.status()["stats"],
    }
    tmp = out_json + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, out_json)
    return 0 if spec == "none" else 3    # a kill spec must have fired


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
