"""Attention implementations agree: xla (masked sdpa), xla_flash
(scan/online-softmax), pallas kernel (interpret) — fwd and grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduced
from repro.configs import get_config
from repro.models import api

rng = np.random.default_rng(11)


@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x7b"])
def test_xla_flash_matches_xla(arch):
    cfg = reduced(get_config(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 40))
                       .astype(np.int32))
    batch = {"tokens": toks, "labels": toks}
    a = api.forward(params, batch, cfg, impl="xla")
    b = api.forward(params, batch, cfg, impl="xla_flash")
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4
    ga = jax.grad(lambda p: api.loss_fn(p, batch, cfg, impl="xla"))(
        params)
    gb = jax.grad(lambda p: api.loss_fn(p, batch, cfg,
                                        impl="xla_flash"))(params)
    gerr = max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)))
    assert gerr < 1e-3


def test_sdpa_flash_blocking_invariance():
    """Different KV block sizes give identical results."""
    from repro.models.attention import _sdpa, _mask, _sdpa_flash_xla
    b, sq, hq, hkv, hd = 2, 37, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, sq, hq, hd)),
                    dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, hkv, hd)),
                    dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, hkv, hd)),
                    dtype=jnp.float32)
    pos = jnp.arange(sq, dtype=jnp.int32)
    ref = _sdpa(q, k, v, _mask(pos, pos, True, 8), hd ** -0.5)
    for blk in (4, 16, 64):
        out = _sdpa_flash_xla(q, k, v, pos, pos, True, 8, hd ** -0.5,
                              block=blk)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5, blk


def test_pallas_kernel_in_model_forward():
    """impl='pallas' (interpret mode) matches the XLA path end-to-end
    in a full model forward."""
    cfg = reduced(get_config("smollm-360m"), n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 32))
                       .astype(np.int32))
    batch = {"tokens": toks, "labels": toks}
    a = api.forward(params, batch, cfg, impl="xla")
    b = api.forward(params, batch, cfg, impl="pallas")
    assert float(jnp.max(jnp.abs(a - b))) < 1e-3
