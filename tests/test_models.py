"""Per-architecture smoke tests on reduced configs: one forward/train
step on CPU, output shapes, no NaNs — plus the strongest cache check:
prefill + decode must reproduce the full teacher-forced forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduced
from repro.configs import ARCHS, get_config
from repro.models import api

B, S = 2, 32
rng = np.random.default_rng(7)


def make_batch(cfg):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model)).astype(np.float32))
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch))
            params = api.init_params(jax.random.PRNGKey(0), cfg,
                                     jnp.float32)
            cache[arch] = (cfg, params, make_batch(cfg))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", list(ARCHS))
def test_forward_shapes_and_finite(arch_setup, arch):
    cfg, params, batch = arch_setup(arch)
    logits = api.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list(ARCHS))
def test_train_step_no_nans(arch_setup, arch):
    from repro.config import ShardingConfig, TrainConfig
    from repro.runtime import TrainState, init_train_state, make_train_step
    cfg, _, batch = arch_setup(arch)
    tcfg = TrainConfig(global_batch=B, seq_len=S, param_dtype="float32",
                       total_steps=10, warmup_steps=2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = make_train_step(cfg, tcfg, ShardingConfig())
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.step) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", list(ARCHS))
def test_prefill_decode_matches_forward(arch_setup, arch):
    cfg, params, batch = arch_setup(arch)
    n_pre = S - 4
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :n_pre]
    cap = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits_pre, caches = api.prefill(params, pre, cfg, cache_cap=cap)
    full = api.forward(params, batch, cfg)
    errs = [float(jnp.max(jnp.abs(logits_pre - full[:, n_pre - 1])))]
    for i in range(4):
        pos = jnp.int32(n_pre + i +
                        (cfg.n_patches if cfg.family == "vlm" else 0))
        tok = batch["tokens"][:, n_pre + i:n_pre + i + 1]
        logits, caches = api.decode_step(params, tok, pos, caches, cfg)
        if n_pre + i < S - 1:
            errs.append(float(jnp.max(jnp.abs(logits
                                              - full[:, n_pre + i]))))
    assert max(errs) < 2e-3, errs


def test_swa_ring_buffer_decode():
    """Sliding-window cache: decode beyond the window must match a
    full-cache run restricted by the window mask (mixtral family)."""
    cfg = reduced(get_config("mixtral-8x7b"), window=16, max_seq=512)
    params = api.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 48))
                       .astype(np.int32))
    batch = {"tokens": toks, "labels": toks}
    full = api.forward(params, batch, cfg)
    n_pre = 40
    # ring cache sized by window (init_cache caps at cfg.window)
    logits, caches = api.prefill(params, {"tokens": toks[:, :n_pre]},
                                 cfg, cache_cap=48)
    errs = [float(jnp.max(jnp.abs(logits - full[:, n_pre - 1])))]
    for i in range(48 - n_pre - 1):
        tok = toks[:, n_pre + i:n_pre + i + 1]
        logits, caches = api.decode_step(params, tok,
                                         jnp.int32(n_pre + i), caches,
                                         cfg)
        errs.append(float(jnp.max(jnp.abs(logits - full[:, n_pre + i]))))
    assert max(errs) < 2e-3, errs


def test_moe_routing_load_and_flops():
    """Sparse dispatch: all top-k weight mass lands somewhere (no drops
    at generous capacity) and per-token FLOPs estimate is top_k-scaled."""
    from repro.models.moe import apply_moe, capacity, init_moe
    cfg = reduced(get_config("mixtral-8x7b"))
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model))
                    .astype(np.float32))
    y = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert capacity(cfg, 32) >= 32 * cfg.top_k // cfg.n_experts


def test_moe_capacity_drops_tokens():
    """At capacity_factor ≪ 1 tokens must drop (output diverges from a
    generous-capacity run) — exercises the overflow path."""
    import dataclasses
    cfg = reduced(get_config("mixtral-8x7b"))
    tight = dataclasses.replace(cfg, capacity_factor=0.1)
    from repro.models.moe import apply_moe, init_moe
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model))
                    .astype(np.float32))
    y_full = apply_moe(p, x, cfg)
    y_tight = apply_moe(p, x, tight)
    assert float(jnp.max(jnp.abs(y_full - y_tight))) > 1e-4
    assert bool(jnp.all(jnp.isfinite(y_tight)))
