"""Plan equivalence (paper Table 2): every applicable plan returns the
same answer, with and without indexes, against the brute-force oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plans import Query


def _ts(store, frac):
    return max(1, int(store.t_cur * frac))


@pytest.mark.parametrize("v", [0, 3, 17, 40])
@pytest.mark.parametrize("frac", [0.2, 0.5, 0.9])
def test_point_degree_all_plans(small_history, v, frac):
    store, bf = small_history
    t = _ts(store, frac)
    q = Query("point", "node", "degree", t_k=t, v=v)
    expect = bf.degree(v, t)
    assert int(store.query(q, plan="two_phase")) == expect
    assert int(store.query(q, plan="two_phase", partial_rows=True)) == \
        expect
    assert int(store.query(q, plan="hybrid")) == expect
    assert int(store.query(q, plan="hybrid", indexed=True)) == expect


@pytest.mark.parametrize("v", [1, 9, 33])
def test_diff_degree_all_plans(small_history, v):
    store, bf = small_history
    t_k, t_l = _ts(store, 0.3), _ts(store, 0.8)
    q = Query("diff", "node", "degree", t_k=t_k, t_l=t_l, v=v)
    expect = abs(bf.degree(v, t_l) - bf.degree(v, t_k))
    assert int(store.query(q, plan="two_phase")) == expect
    assert int(store.query(q, plan="delta_only")) == expect
    assert int(store.query(q, plan="delta_only", indexed=True)) == expect
    assert int(store.query(q, plan="hybrid")) == expect


@pytest.mark.parametrize("v", [2, 21])
@pytest.mark.parametrize("agg", ["mean", "min", "max"])
def test_agg_degree_all_plans(small_history, v, agg):
    store, bf = small_history
    t_k = _ts(store, 0.4)
    t_l = min(t_k + 7, store.t_cur)
    q = Query("agg", "node", "degree", t_k=t_k, t_l=t_l, v=v, agg=agg)
    series = bf.degree_series(v, t_k, t_l)
    expect = {"mean": np.mean, "min": np.min, "max": np.max}[agg](series)
    got_two = float(store.query(q, plan="two_phase"))
    got_hyb = float(store.query(q, plan="hybrid"))
    assert abs(got_two - expect) < 1e-5
    assert abs(got_hyb - expect) < 1e-5


def test_global_queries_two_phase(small_history):
    store, bf = small_history
    t = _ts(store, 0.6)
    q_edges = Query("point", "global", "num_edges", t_k=t)
    assert int(store.query(q_edges)) == bf.num_edges(t)
    q_nodes = Query("point", "global", "num_nodes", t_k=t)
    assert int(store.query(q_nodes)) == bf.num_nodes(t)
    # differential global
    t2 = _ts(store, 0.9)
    q_d = Query("diff", "global", "num_edges", t_k=t, t_l=t2)
    assert int(store.query(q_d)) == abs(bf.num_edges(t2) - bf.num_edges(t))


def test_plan_applicability_matrix(small_history):
    store, _ = small_history
    q = Query("point", "global", "num_edges", t_k=1)
    with pytest.raises(ValueError):
        store.query(q, plan="delta_only")


def test_materialized_selection(small_history):
    store, bf = small_history
    # materialize a few snapshots by hand
    for frac in (0.25, 0.5, 0.75):
        t = _ts(store, frac)
        g = store.snapshot_at(t, use_materialized=False)
        store.materialized.add(t, g)
    for frac in (0.3, 0.6, 0.95):
        t = _ts(store, frac)
        for sel in ("time", "ops"):
            g = store.snapshot_at(t, use_materialized=True, selection=sel)
            assert np.array_equal(np.asarray(g.adj), bf.adj(t)), (t, sel)


def test_sequential_two_phase(small_history):
    store, bf = small_history
    t = _ts(store, 0.5)
    q = Query("point", "node", "degree", t_k=t, v=5)
    assert int(store.query(q, plan="two_phase", sequential=True)) == \
        bf.degree(5, t)


def test_windowed_snapshot_matches(small_history):
    """Temporal-index windowed reconstruction == full-log masked
    reconstruction (the §Perf windowed-materialization path)."""
    import numpy as np
    store, bf = small_history
    g = store.snapshot_at(store.t_cur // 2, use_materialized=False)
    store.materialized.add(store.t_cur // 2, g)
    for frac in (0.2, 0.55, 0.8):
        t = max(1, int(store.t_cur * frac))
        a = store.snapshot_at(t, windowed=False)
        b = store.snapshot_at(t, windowed=True)
        assert np.array_equal(np.asarray(a.adj), np.asarray(b.adj)), t
        assert np.array_equal(np.asarray(a.adj), bf.adj(t)), t
