"""Distributed engine + dry-run machinery on 8 forced host devices.

Device count is locked at first jax init, so these run in a
subprocess with XLA_FLAGS set (tests themselves keep 1 device).  The
flag is inherited from the environment when it already forces a host
device count (the CI multidevice lane exports it), so the workflow's
XLA_FLAGS is what the subprocesses actually run under."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multidevice

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _xla_flags() -> str:
    for var in ("XLA_FLAGS", "REPRO_CI_XLA_FLAGS"):
        flags = os.environ.get(var, "")
        if "xla_force_host_platform_device_count" in flags:
            return flags
    return "--xla_force_host_platform_device_count=8"


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = _xla_flags()
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_distributed_graph_engine():
    out = _run(open(os.path.join(ROOT, "scripts",
                                 "smoke_dist.py")).read())
    assert "distributed smoke OK" in out


# ---------------------------------------------------------------------------
# Sharded evaluate_many: bit-parity with the single-device executor
# ---------------------------------------------------------------------------

_PARITY_PRELUDE = """
import numpy as np, jax
from repro.core.generate import EvolutionParams, build_store
from repro.core.plans import Query
from repro.sharding.graph import graph_mesh

store = build_store(96, EvolutionParams(m_attach=3, lam_extra=1.0,
                                        lam_remove=1.5,
                                        p_remove_node=0.03), seed=11)
tc = store.t_cur
mesh = graph_mesh()
assert len(jax.devices()) == 8, jax.devices()
eng = store.place_on_mesh(mesh)

def vals(rs):
    return [np.asarray(r).item() for r in rs]
"""


def test_sharded_evaluate_many_bit_parity_all_plans():
    """Forced {two_phase, delta_only, hybrid} groups, every query kind,
    node + global scopes: the sharded result must equal the
    single-device result bit for bit, and the sharded modes must
    actually engage (no silent fallback)."""
    code = _PARITY_PRELUDE + """
qs = [
    Query("point", "node", "degree", t_k=tc // 3, v=5),
    Query("diff", "node", "degree", t_k=tc // 4, t_l=3 * tc // 4, v=9),
    Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
          agg="mean"),
    Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
          agg="min"),
    Query("point", "global", "num_edges", t_k=tc // 2),
    Query("point", "global", "num_nodes", t_k=tc // 2),
    Query("point", "global", "density", t_k=tc // 2),
    Query("diff", "global", "num_edges", t_k=tc // 4, t_l=3 * tc // 4),
    Query("agg", "global", "num_edges", t_k=tc // 2, t_l=tc // 2 + 4,
          agg="max"),
    Query("point", "node", "neighborhood2", t_k=tc // 3, v=5),
] * 3
# the engine is mesh-bound, so references must pin shard="never" to
# really exercise the single-device path; layout="dense" pins the
# row-sharded path (auto would route slot-decomposable groups to the
# edge layout's "slots" mode, covered by its own parity test)
ref = vals(eng.evaluate_many(qs, plan="two_phase", layout="dense",
                             shard="never"))
assert all(m is None for *_, m in eng.last_group_stats)
got = vals(eng.evaluate_many(qs, plan="two_phase", layout="dense",
                             mesh=mesh, shard="force"))
assert got == ref, [p for p in zip(got, ref) if p[0] != p[1]]
modes = {m for *_, m in eng.last_group_stats}
assert "rows" in modes and None not in modes, eng.last_group_stats

deg = [q for q in qs if q.scope == "node" and q.measure == "degree"]
diffs = [q for q in deg if q.kind == "diff"]
for plan, sub in (("hybrid", deg), ("delta_only", diffs)):
    ref = vals(eng.evaluate_many(sub, plan=plan, shard="never"))
    got = vals(eng.evaluate_many(sub, plan=plan, mesh=mesh, shard="force"))
    assert got == ref, (plan, list(zip(got, ref)))
    assert all(m == "batch" for *_, m in eng.last_group_stats), \\
        eng.last_group_stats

ref = vals(eng.evaluate_many(qs, shard="never"))
got = vals(eng.evaluate_many(qs, mesh=mesh, shard="force"))
assert got == ref, [p for p in zip(got, ref) if p[0] != p[1]]
print("sharded parity OK")
"""
    assert "sharded parity OK" in _run(code)


def test_sharded_variants_and_anchors_bit_parity():
    """Indexed / windowed / materialized-anchor groups keep bit-parity
    under sharding, and a large auto-planned batch shards on its own
    (the planner's dispatch cost term crosses the threshold)."""
    code = _PARITY_PRELUDE + """
t_mid = tc // 2
store.materialized.add(t_mid, store.snapshot_at(t_mid,
                                                use_materialized=False))
store._engine_cache = None
eng = store.engine(indexed=True, mesh=mesh)
rng = np.random.default_rng(3)
big = []
for i in range(192):
    v = int(rng.integers(0, 90))
    t1 = int(rng.integers(1, tc))
    t2 = min(tc, t1 + int(rng.integers(0, 6)))
    kind = ("point", "diff", "agg")[i % 3]
    big.append(Query(kind, "node", "degree", t_k=t1,
                     t_l=None if kind == "point" else t2, v=v))
ref = vals(eng.evaluate_many(big, shard="never"))
assert all(m is None for *_, m in eng.last_group_stats)
got = vals(eng.evaluate_many(big, mesh=mesh))
assert got == ref, [p for p in zip(got, ref) if p[0] != p[1]]
assert any(m is not None for *_, m in eng.last_group_stats), \\
    eng.last_group_stats

for kw in (dict(plan="two_phase", windowed=True),
           dict(plan="hybrid", indexed=True),
           dict(plan="delta_only", indexed=True)):
    sub = [q for q in big[:48]
           if q.kind == "diff" or kw.get("plan") != "delta_only"]
    ref = vals(eng.evaluate_many(sub, shard="never", **kw))
    got = vals(eng.evaluate_many(sub, mesh=mesh, shard="force", **kw))
    assert got == ref, (kw, [p for p in zip(got, ref) if p[0] != p[1]])

# small groups stay single-device under the auto cost term
eng.evaluate_many(big[:3], mesh=mesh)
assert all(m is None for *_, m in eng.last_group_stats), \\
    eng.last_group_stats
print("sharded variants OK")
"""
    assert "sharded variants OK" in _run(code)


def test_slot_sharded_edge_layout_bit_parity():
    """Edge-layout two-phase groups sharded over the SLOT axis (psum
    integer partials) must bit-match both the single-device edge path
    and the dense path, for every kind × slot-decomposable measure;
    batch-axis sharding of edge hybrid/delta-only groups too."""
    code = _PARITY_PRELUDE + """
qs = [
    Query("point", "node", "degree", t_k=tc // 3, v=5),
    Query("diff", "node", "degree", t_k=tc // 4, t_l=3 * tc // 4, v=9),
    Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
          agg="mean"),
    Query("point", "global", "num_edges", t_k=tc // 2),
    Query("point", "global", "num_nodes", t_k=tc // 2),
    Query("point", "global", "density", t_k=tc // 2),
    Query("point", "global", "avg_degree", t_k=tc // 2),
    Query("diff", "global", "num_edges", t_k=tc // 4, t_l=3 * tc // 4),
    Query("agg", "global", "num_edges", t_k=tc // 2, t_l=tc // 2 + 4,
          agg="max"),
] * 3
dense = vals(eng.evaluate_many(qs, plan="two_phase", layout="dense",
                               shard="never"))
ref = vals(eng.evaluate_many(qs, plan="two_phase", layout="edge",
                             shard="never"))
assert ref == dense, [p for p in zip(ref, dense) if p[0] != p[1]]
assert all(m is None for *_, m in eng.last_group_stats)
got = vals(eng.evaluate_many(qs, plan="two_phase", layout="edge",
                             mesh=mesh, shard="force"))
assert got == ref, [p for p in zip(got, ref) if p[0] != p[1]]
modes = {m for *_, m in eng.last_group_stats}
assert modes == {"slots"}, eng.last_group_stats
assert all(k.layout == "edge" for k, *_ in eng.last_group_stats)

deg = [q for q in qs if q.scope == "node" and q.measure == "degree"]
for plan, sub in (("hybrid", deg),
                  ("delta_only", [q for q in deg if q.kind == "diff"])):
    ref = vals(eng.evaluate_many(sub, plan=plan, layout="edge",
                                 shard="never"))
    got = vals(eng.evaluate_many(sub, plan=plan, layout="edge",
                                 mesh=mesh, shard="force"))
    assert got == ref, (plan, list(zip(got, ref)))
    assert all(m == "batch" for *_, m in eng.last_group_stats), \\
        eng.last_group_stats
print("slot-sharded parity OK")
"""
    assert "slot-sharded parity OK" in _run(code)


def test_sharded_evolve_sweep_bit_parity():
    """Evolve (time-sweep) groups on 8 devices: the slot-sharded sweep
    (integer-partial psum of the start state) and the batch-sharded
    dense sweep must both bit-match the single-device sweep, which in
    turn must bit-match B independent point queries."""
    code = _PARITY_PRELUDE + """
qs = [
    Query("evolve", "node", "degree", t_k=2, t_l=tc, v=5, stride=1),
    Query("evolve", "global", "num_edges", t_k=2, t_l=tc, stride=1),
    Query("evolve", "global", "density", t_k=3, t_l=tc - 1, stride=2),
    Query("evolve", "global", "avg_degree", t_k=2, t_l=tc, stride=1),
    Query("evolve", "global", "degree_distribution", t_k=2, t_l=tc,
          stride=3),
] * 2
ref = eng.evaluate_many(qs, layout="edge", shard="never")
for q, r in zip(qs[:5], ref[:5]):
    ts = list(range(q.t_k, q.t_l + 1, q.stride))
    pts = eng.evaluate_many(
        [Query("point", q.scope, q.measure, t_k=t, v=q.v) for t in ts],
        layout="edge", shard="never")
    assert np.array_equal(np.asarray(r),
                          np.stack([np.asarray(p) for p in pts])), q
got = eng.evaluate_many(qs, layout="edge", mesh=mesh, shard="force")
for q, a, b in zip(qs, got, ref):
    assert np.array_equal(np.asarray(a), np.asarray(b)), q
assert {m for *_, m in eng.last_group_stats} == {"slots"}, \\
    eng.last_group_stats
gotd = eng.evaluate_many(qs, layout="dense", mesh=mesh, shard="force")
for q, a, b in zip(qs, gotd, ref):
    assert np.array_equal(np.asarray(a), np.asarray(b)), q
assert {m for *_, m in eng.last_group_stats} == {"batch"}, \\
    eng.last_group_stats
print("sweep sharded parity OK")
"""
    assert "sweep sharded parity OK" in _run(code)


def test_live_serving_sharded_bit_parity():
    """Serving acceptance (PR 4): with ingest interleaved, every query
    at t ≤ t_served on a mesh-bound LiveGraphStore (sharded groups
    engaged) bit-matches a from-scratch single-device store built from
    the ops absorbed so far — at every watermark, across layouts."""
    code = """
import numpy as np, jax
from repro.core.generate import EvolutionParams, generate_ops
from repro.core.plans import Query
from repro.core.store import TemporalGraphStore
from repro.sharding.graph import graph_mesh
from repro.serving import LiveGraphStore

assert len(jax.devices()) == 8, jax.devices()
ops = generate_ops(96, EvolutionParams(m_attach=3, lam_extra=1.0,
                                       lam_remove=1.5,
                                       p_remove_node=0.03), seed=11)
t_max = ops[-1].t
cuts, lo = [], 0
for frac in (3, 2):
    cuts.append(next(i for i, o in enumerate(ops) if o.t > t_max // frac))
cuts.append(len(ops))
mesh = graph_mesh()
live = LiveGraphStore(n_cap=96, mesh=mesh)

def vals(rs):
    return [np.asarray(r).tolist() for r in rs]

rng = np.random.default_rng(0)
shard_modes = set()
for cut in cuts:
    live.append(ops[lo:cut]); lo = cut
    live.swap()
    w = live.t_served
    qs = []
    for i in range(24):
        t1 = int(rng.integers(1, w)); v = int(rng.integers(0, 96))
        t2 = min(w, t1 + int(rng.integers(0, 6)))
        qs += [Query("point", "node", "degree", t_k=t1, v=v),
               Query("diff", "node", "degree", t_k=t1, t_l=t2, v=v),
               Query("point", "global", "num_edges", t_k=t1),
               Query("point", "global", "degree_distribution", t_k=t1)]
    got = vals(live.evaluate_many(qs, shard="force"))
    shard_modes |= {m for *_, m in live.engine.last_group_stats}
    oracle = TemporalGraphStore(n_cap=96)
    oracle.ingest(ops[:cut]); oracle.advance_to(w)
    ref = vals(oracle.evaluate_many(qs, shard="never"))
    assert got == ref, [p for p in zip(got, ref) if p[0] != p[1]]
assert None not in shard_modes and shard_modes, shard_modes
print("live serving sharded parity OK", sorted(str(m) for m in shard_modes))
"""
    assert "live serving sharded parity OK" in _run(code)


def test_segmented_vs_monolithic_sharded_bit_parity():
    """Segmented-log acceptance (PR 5): a fragmented segmented store
    (multiple sealed segments, per-group window deltas) serving through
    forced-sharded multi-device groups must bit-match a monolithic
    (segmented=False) single-device store over the same op stream —
    dense row-sharded, edge slot-sharded, and batch-sharded
    hybrid/delta-only groups all engaged."""
    code = """
import numpy as np, jax
from repro.core.generate import EvolutionParams, generate_ops
from repro.core.plans import Query
from repro.core.store import TemporalGraphStore
from repro.sharding.graph import graph_mesh

assert len(jax.devices()) == 8, jax.devices()
ops = generate_ops(96, EvolutionParams(m_attach=3, lam_extra=1.0,
                                       lam_remove=1.5,
                                       p_remove_node=0.03), seed=11)
t_max = max(o.t for o in ops)
cuts = [i * len(ops) // 4 for i in (1, 2, 3)] + [len(ops)]
seg = TemporalGraphStore(n_cap=96, segment_min_ops=8)
mono = TemporalGraphStore(n_cap=96, segmented=False)
lo = 0
for cut in cuts:
    # a cut may split a time unit: close only fully-ingested units
    # (later ops must stay strictly past t_cur)
    t_adv = (t_max if cut == len(ops)
             else max(o.t for o in ops[:cut]) - 1)
    for s in (seg, mono):
        s.ingest(ops[lo:cut])
        s.advance_to(max(t_adv, s.t_cur))
    seg.freeze_serving_state()      # seal the epoch boundary
    lo = cut
assert len(seg.delta_view().segments) >= 3, seg.delta_view().segments
tc = seg.t_cur
assert tc == mono.t_cur == t_max
mesh = graph_mesh()
eng = seg.place_on_mesh(mesh)

def vals(rs):
    return [np.asarray(r).tolist() for r in rs]

qs = [
    Query("point", "node", "degree", t_k=tc // 3, v=5),
    Query("diff", "node", "degree", t_k=tc // 4, t_l=3 * tc // 4, v=9),
    Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
          agg="mean"),
    Query("point", "global", "num_edges", t_k=tc // 2),
    Query("point", "global", "num_nodes", t_k=tc // 2),
    Query("point", "global", "density", t_k=tc // 2),
    Query("diff", "global", "num_edges", t_k=tc // 4, t_l=3 * tc // 4),
    Query("agg", "global", "num_edges", t_k=tc // 2, t_l=tc // 2 + 4,
          agg="max"),
] * 3
modes = set()
for kw in (dict(plan="two_phase", layout="dense"),
           dict(plan="two_phase", layout="edge"),
           dict()):
    ref = vals(mono.evaluate_many(qs, shard="never", **kw))
    got = vals(eng.evaluate_many(qs, mesh=mesh, shard="force", **kw))
    assert got == ref, (kw, [p for p in zip(got, ref) if p[0] != p[1]])
    modes |= {m for *_, m in eng.last_group_stats}
deg = [q for q in qs if q.scope == "node" and q.measure == "degree"]
for plan, sub in (("hybrid", deg),
                  ("delta_only", [q for q in deg if q.kind == "diff"])):
    ref = vals(mono.evaluate_many(sub, plan=plan, shard="never"))
    got = vals(eng.evaluate_many(sub, plan=plan, mesh=mesh,
                                 shard="force"))
    assert got == ref, (plan, list(zip(got, ref)))
    modes |= {m for *_, m in eng.last_group_stats}
assert {"rows", "slots", "batch"} <= modes, modes
print("segmented sharded parity OK", sorted(str(m) for m in modes))
"""
    assert "segmented sharded parity OK" in _run(code)


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    """Lower+compile a reduced arch on a (4,2) mesh: validates the
    sharding-spec builders and collective parsing end to end."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import SHAPES, ShapeConfig, TrainConfig, ShardingConfig, reduced
from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import batch_sharding, state_sharding, cache_sharding
from repro.launch.roofline import collective_bytes
from repro.models import api
from repro.runtime.steps import make_train_step, make_decode_step, init_train_state
from repro.sharding import mesh_context

mesh = make_test_mesh(4, 2)
for arch in ("smollm-360m", "mixtral-8x7b", "mamba2-130m"):
    cfg = reduced(get_config(arch))
    tcfg = TrainConfig(global_batch=8, seq_len=64)
    shape = ShapeConfig("t", 64, 8, "train")
    step = make_train_step(cfg, tcfg, ShardingConfig())
    state_shapes = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg))
    batch_shapes = api.input_specs(cfg, shape)
    in_sh = (state_sharding(state_shapes, mesh), batch_sharding(batch_shapes, mesh))
    with mesh_context(mesh):
        lowered = jax.jit(step, in_shardings=in_sh).lower(state_shapes, batch_shapes)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax <= 0.4 returns [dict]
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    assert cost.get("flops", 0) > 0, arch
    assert coll["counts"]["all-reduce"] + coll["counts"]["all-gather"] + coll["counts"]["reduce-scatter"] > 0, (arch, coll)
    # decode too
    dshape = ShapeConfig("d", 64, 8, "decode")
    dstep = make_decode_step(cfg)
    params_shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    cache_shapes = jax.eval_shape(lambda: api.init_decode_caches(cfg, 8, 64))
    io = api.input_specs(cfg, dshape)
    in_sh = (state_sharding(params_shapes, mesh), cache_sharding(cache_shapes, mesh),
             batch_sharding({"token": io["token"]}, mesh)["token"], NamedSharding(mesh, P()))
    with mesh_context(mesh):
        jax.jit(dstep, in_shardings=in_sh).lower(
            params_shapes, cache_shapes, io["token"], io["pos"]).compile()
    print("ok", arch)
print("dryrun small mesh OK")
"""
    out = _run(code)
    assert "dryrun small mesh OK" in out


@pytest.mark.slow
def test_shard_map_moe_parity():
    """The shard_map MoE (local dispatch + EP compute + psum combine)
    must match the dense single-device path bit-for-nearly-bit."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.config import TrainConfig, ShardingConfig, reduced
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import batch_sharding, state_sharding
from repro.runtime.steps import make_train_step, init_train_state
from repro.sharding import mesh_context

for arch in ("mixtral-8x7b", "kimi-k2-1t-a32b"):
    cfg = reduced(get_config(arch))
    tcfg = TrainConfig(global_batch=8, seq_len=32, lr=1e-3, param_dtype="float32")
    data = SyntheticLM(cfg, 8, 32, seed=0)
    batch = data.batch_at(0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = make_train_step(cfg, tcfg, ShardingConfig())
    s1, m1 = jax.jit(step)(state, batch)
    mesh = make_test_mesh(4, 2)
    in_sh = (state_sharding(jax.eval_shape(lambda: state), mesh),
             batch_sharding(jax.eval_shape(lambda: batch), mesh))
    with mesh_context(mesh):
        s2, m2 = jax.jit(step, in_shardings=in_sh)(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4
    dmax = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
               for a, b in zip(jax.tree.leaves(s1.params),
                               jax.tree.leaves(s2.params)))
    assert dmax < 2e-4, (arch, dmax)
print("moe parity OK")
"""
    out = _run(code)
    assert "moe parity OK" in out


@pytest.mark.slow
def test_distributed_train_step_matches_single_device():
    """The jitted train step on a (4,2) mesh must produce the same loss
    and parameter update as the same step on 1 device (SPMD is a
    numerics-preserving transform modulo reduction order)."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.config import TrainConfig, ShardingConfig, reduced
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import batch_sharding, state_sharding
from repro.runtime.steps import make_train_step, init_train_state
from repro.sharding import mesh_context

cfg = reduced(get_config("smollm-360m"), n_layers=1, d_model=64, n_heads=4,
              n_kv_heads=2, head_dim=16, d_ff=128, vocab=128)
tcfg = TrainConfig(global_batch=8, seq_len=32, lr=1e-3, param_dtype="float32")
data = SyntheticLM(cfg, 8, 32, seed=0)
batch = data.batch_at(0)
state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
step = make_train_step(cfg, tcfg, ShardingConfig())
# single device
s1, m1 = jax.jit(step)(state, batch)
# mesh
mesh = make_test_mesh(4, 2)
in_sh = (state_sharding(jax.eval_shape(lambda: state), mesh),
         batch_sharding(jax.eval_shape(lambda: batch), mesh))
with mesh_context(mesh):
    s2, m2 = jax.jit(step, in_shardings=in_sh)(state, batch)
l1, l2 = float(m1["loss"]), float(m2["loss"])
assert abs(l1 - l2) < 1e-4, (l1, l2)
for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
    d = float(jnp.max(jnp.abs(a - jax.device_get(b))))
    assert d < 1e-4, d
print("distributed step parity OK", l1, l2)
"""
    out = _run(code)
    assert "distributed step parity OK" in out
