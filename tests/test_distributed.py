"""Distributed engine + dry-run machinery on 8 forced host devices.

Device count is locked at first jax init, so these run in a
subprocess with XLA_FLAGS set (tests themselves keep 1 device)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_distributed_graph_engine():
    out = _run(open(os.path.join(ROOT, "scripts",
                                 "smoke_dist.py")).read())
    assert "distributed smoke OK" in out


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    """Lower+compile a reduced arch on a (4,2) mesh: validates the
    sharding-spec builders and collective parsing end to end."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import SHAPES, ShapeConfig, TrainConfig, ShardingConfig, reduced
from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import batch_sharding, state_sharding, cache_sharding
from repro.launch.roofline import collective_bytes
from repro.models import api
from repro.runtime.steps import make_train_step, make_decode_step, init_train_state
from repro.sharding import mesh_context

mesh = make_test_mesh(4, 2)
for arch in ("smollm-360m", "mixtral-8x7b", "mamba2-130m"):
    cfg = reduced(get_config(arch))
    tcfg = TrainConfig(global_batch=8, seq_len=64)
    shape = ShapeConfig("t", 64, 8, "train")
    step = make_train_step(cfg, tcfg, ShardingConfig())
    state_shapes = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg))
    batch_shapes = api.input_specs(cfg, shape)
    in_sh = (state_sharding(state_shapes, mesh), batch_sharding(batch_shapes, mesh))
    with mesh_context(mesh):
        lowered = jax.jit(step, in_shardings=in_sh).lower(state_shapes, batch_shapes)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    assert cost.get("flops", 0) > 0, arch
    assert coll["counts"]["all-reduce"] + coll["counts"]["all-gather"] + coll["counts"]["reduce-scatter"] > 0, (arch, coll)
    # decode too
    dshape = ShapeConfig("d", 64, 8, "decode")
    dstep = make_decode_step(cfg)
    params_shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    cache_shapes = jax.eval_shape(lambda: api.init_decode_caches(cfg, 8, 64))
    io = api.input_specs(cfg, dshape)
    in_sh = (state_sharding(params_shapes, mesh), cache_sharding(cache_shapes, mesh),
             batch_sharding({"token": io["token"]}, mesh)["token"], NamedSharding(mesh, P()))
    with mesh_context(mesh):
        jax.jit(dstep, in_shardings=in_sh).lower(
            params_shapes, cache_shapes, io["token"], io["pos"]).compile()
    print("ok", arch)
print("dryrun small mesh OK")
"""
    out = _run(code)
    assert "dryrun small mesh OK" in out


@pytest.mark.slow
def test_shard_map_moe_parity():
    """The shard_map MoE (local dispatch + EP compute + psum combine)
    must match the dense single-device path bit-for-nearly-bit."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.config import TrainConfig, ShardingConfig, reduced
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import batch_sharding, state_sharding
from repro.runtime.steps import make_train_step, init_train_state
from repro.sharding import mesh_context

for arch in ("mixtral-8x7b", "kimi-k2-1t-a32b"):
    cfg = reduced(get_config(arch))
    tcfg = TrainConfig(global_batch=8, seq_len=32, lr=1e-3, param_dtype="float32")
    data = SyntheticLM(cfg, 8, 32, seed=0)
    batch = data.batch_at(0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = make_train_step(cfg, tcfg, ShardingConfig())
    s1, m1 = jax.jit(step)(state, batch)
    mesh = make_test_mesh(4, 2)
    in_sh = (state_sharding(jax.eval_shape(lambda: state), mesh),
             batch_sharding(jax.eval_shape(lambda: batch), mesh))
    with mesh_context(mesh):
        s2, m2 = jax.jit(step, in_shardings=in_sh)(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4
    dmax = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
               for a, b in zip(jax.tree.leaves(s1.params),
                               jax.tree.leaves(s2.params)))
    assert dmax < 2e-4, (arch, dmax)
print("moe parity OK")
"""
    out = _run(code)
    assert "moe parity OK" in out


@pytest.mark.slow
def test_distributed_train_step_matches_single_device():
    """The jitted train step on a (4,2) mesh must produce the same loss
    and parameter update as the same step on 1 device (SPMD is a
    numerics-preserving transform modulo reduction order)."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.config import TrainConfig, ShardingConfig, reduced
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import batch_sharding, state_sharding
from repro.runtime.steps import make_train_step, init_train_state
from repro.sharding import mesh_context

cfg = reduced(get_config("smollm-360m"), n_layers=1, d_model=64, n_heads=4,
              n_kv_heads=2, head_dim=16, d_ff=128, vocab=128)
tcfg = TrainConfig(global_batch=8, seq_len=32, lr=1e-3, param_dtype="float32")
data = SyntheticLM(cfg, 8, 32, seed=0)
batch = data.batch_at(0)
state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
step = make_train_step(cfg, tcfg, ShardingConfig())
# single device
s1, m1 = jax.jit(step)(state, batch)
# mesh
mesh = make_test_mesh(4, 2)
in_sh = (state_sharding(jax.eval_shape(lambda: state), mesh),
         batch_sharding(jax.eval_shape(lambda: batch), mesh))
with mesh_context(mesh):
    s2, m2 = jax.jit(step, in_shardings=in_sh)(state, batch)
l1, l2 = float(m1["loss"]), float(m2["loss"])
assert abs(l1 - l2) < 1e-4, (l1, l2)
for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
    d = float(jnp.max(jnp.abs(a - jax.device_get(b))))
    assert d < 1e-4, d
print("distributed step parity OK", l1, l2)
"""
    out = _run(code)
    assert "distributed step parity OK" in out
