"""End-to-end behaviour tests for the paper's system: ingest an
evolving social graph, serve every query class of Table 1 against the
brute-force oracle, with materialization + Algorithm 3 incremental
updates in the loop."""
import jax.numpy as jnp
import numpy as np

from repro.core import MaterializationPolicy, Op, TemporalGraphStore
from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE
from repro.core.generate import EvolutionParams, generate_ops
from repro.core.plans import Query
from reference import BruteForce


def test_incremental_update_loop_algorithm3():
    """Ingest in per-time-unit batches (Algorithm 3), materializing via
    the op-count policy; every historical degree query stays correct."""
    params = EvolutionParams(m_attach=2, lam_extra=0.5, lam_remove=0.8,
                             events_per_unit=4)
    ops = generate_ops(50, params, seed=21)
    t_max = max(o.t for o in ops)
    store = TemporalGraphStore(
        n_cap=64, policy=MaterializationPolicy(kind="opcount",
                                               op_budget=40))
    # feed ops one time unit at a time
    by_t = {}
    for o in ops:
        by_t.setdefault(o.t, []).append(o)
    for t in range(1, t_max + 1):
        store.ingest(by_t.get(t, []))
        store.advance_to(t)
    assert store.t_cur == t_max
    assert len(store.materialized.times) >= 2  # policy fired

    acc = [Op(int(o), int(u), int(v), int(tt)) for o, u, v, tt in
           zip(store._op, store._u, store._v, store._t)]
    bf = BruteForce(acc, 64, t_max)
    for t in range(0, t_max + 1, max(t_max // 9, 1)):
        g = store.snapshot_at(t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t
        g2 = store.snapshot_at(t, use_materialized=False)
        assert np.array_equal(np.asarray(g2.adj), bf.adj(t)), t


def test_full_query_matrix_end_to_end(small_history):
    store, bf = small_history
    tc = store.t_cur
    checks = 0
    for v in (0, 7, 23):
        for (tk, tl) in ((tc // 4, tc // 2), (tc // 2, 3 * tc // 4)):
            q = Query("point", "node", "degree", t_k=tk, v=v)
            for plan in ("two_phase", "hybrid"):
                assert int(store.query(q, plan=plan)) == bf.degree(v, tk)
                checks += 1
            q = Query("diff", "node", "degree", t_k=tk, t_l=tl, v=v)
            for plan in ("two_phase", "delta_only", "hybrid"):
                assert int(store.query(q, plan=plan)) == \
                    abs(bf.degree(v, tl) - bf.degree(v, tk))
                checks += 1
            q = Query("agg", "node", "degree", t_k=tk,
                      t_l=min(tk + 5, tc), v=v, agg="max")
            expect = max(bf.degree_series(v, tk, min(tk + 5, tc)))
            for plan in ("two_phase", "hybrid"):
                assert int(store.query(q, plan=plan)) == expect
                checks += 1
    assert checks >= 42


def test_global_measures_on_reconstruction(small_history):
    from repro.core import queries as Q
    store, bf = small_history
    t = store.t_cur // 2
    g = store.snapshot_at(t)
    nodes, edges = bf.snapshots[t]
    assert int(Q.num_nodes(g)) == len(nodes)
    assert int(Q.num_edges(g)) == len(edges)
    # component count vs union-find reference
    parent = {n: n for n in nodes}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for (u, v) in edges:
        parent[find(u)] = find(v)
    n_comp = len({find(n) for n in nodes})
    assert int(Q.num_components(g)) == n_comp
    # triangles vs brute force
    adj = bf.adj(t)
    tri = 0
    n = adj.shape[0]
    for i in range(n):
        for j in range(i + 1, n):
            if adj[i, j]:
                tri += int((adj[i] & adj[j])[j + 1:].sum())
    assert int(Q.triangle_count(g)) == tri


def test_degree_distribution_and_pagerank(small_history):
    from repro.core import queries as Q
    store, bf = small_history
    t = store.t_cur // 2
    g = store.snapshot_at(t)
    adj = bf.adj(t)
    hist = np.bincount(adj.sum(1)[bf.node_mask(t)], minlength=21)[:21]
    got = np.asarray(Q.degree_distribution(g, 20))
    assert np.array_equal(got, hist)
    pr = np.asarray(Q.pagerank(g))
    assert abs(float(pr.sum()) - 1.0) < 1e-3  # stochastic vector
    # higher-degree nodes should not have lower rank than isolated ones
    assert pr[np.argmax(adj.sum(1))] > pr[~bf.node_mask(t)].max() \
        if (~bf.node_mask(t)).any() else True


def test_diameter_bfs(small_history):
    from repro.core import queries as Q
    store, bf = small_history
    t = store.t_cur
    g = store.current
    adj = bf.adj(t)
    mask = bf.node_mask(t)
    # reference BFS diameter (largest finite eccentricity)
    import collections
    best = 0
    nodes = np.nonzero(mask)[0]
    for s in nodes:
        dist = {int(s): 0}
        dq = collections.deque([int(s)])
        while dq:
            u = dq.popleft()
            for w in np.nonzero(adj[u])[0]:
                if int(w) not in dist:
                    dist[int(w)] = dist[u] + 1
                    dq.append(int(w))
        best = max(best, max(dist.values()))
    assert int(Q.diameter(g)) == best
