"""Segmented delta log (core/segments.py): seal boundaries, window
selection, residency spill/reload, cross-epoch sharing — and the
tentpole acceptance contract: every query against a segmented store
bit-matches the same query against a monolithic (segmented=False)
store over the same op stream, dense and edge layouts, across
interleaved ingest/advance/materialize/query sequences.
"""
import numpy as np
import pytest

from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE, REM_NODE
from repro.core.materialize import MaterializationPolicy
from repro.core.plans import Query
from repro.core.store import Op, TemporalGraphStore

N = 12


def _item(x):
    return np.asarray(x).item()


def _assert_bitequal(got, ref, ctx):
    assert len(got) == len(ref), ctx
    for i, (a, b) in enumerate(zip(got, ref)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (ctx, i, a, b)


def _chunked_store(chunks, *, layout="dense", policy=None,
                   segment_min_ops=4, **kw):
    """Ingest chunk-by-chunk with a freeze (the epoch-swap seal hook)
    between chunks, so the log really fragments into segments."""
    s = TemporalGraphStore(n_cap=N, layout=layout, policy=policy,
                           segment_min_ops=segment_min_ops, **kw)
    for chunk in chunks:
        s.ingest(chunk)
        s.advance_to(max(o.t for o in chunk))
        s.freeze_serving_state()
    return s


def _churn_chunks(rng, n_chunks=4, per_chunk=(6, 18)):
    """Time-ordered proposal chunks (the store rejects illegal
    transitions identically on every store, so raw proposals drive
    segmented and monolithic stores to the same accepted log)."""
    mix = [ADD_NODE, ADD_NODE, ADD_EDGE, ADD_EDGE, ADD_EDGE, REM_EDGE,
           REM_NODE]
    chunks, t = [], 0
    for _ in range(n_chunks):
        t += 1
        chunk = []
        for _ in range(int(rng.integers(*per_chunk))):
            t += int(rng.integers(0, 2))
            kind = mix[int(rng.integers(0, len(mix)))]
            u = int(rng.integers(0, N))
            v = int(rng.integers(0, N))
            chunk.append(Op(kind, u,
                            v if kind in (ADD_EDGE, REM_EDGE) else u, t))
        chunks.append(chunk)
    return chunks


# ---------------------------------------------------------------------------
# Segment mechanics
# ---------------------------------------------------------------------------


def test_seal_boundaries_are_time_disjoint():
    rng = np.random.default_rng(0)
    s = _chunked_store(_churn_chunks(rng, n_chunks=5), segment_min_ops=1)
    view = s.delta_view()
    assert len(view.segments) >= 3
    for a, b in zip(view.segments, view.segments[1:]):
        assert a.t_max < b.t_min          # strictly time-disjoint
    assert view.n_ops == s.log_len == s.stats()["total_ops"]
    # the open tail is empty right after a freeze: every op is sealed
    assert not s._op_l


def test_window_ops_and_window_delta_match_monolith():
    rng = np.random.default_rng(1)
    chunks = _churn_chunks(rng, n_chunks=5)
    s = _chunked_store(chunks, segment_min_ops=1)
    view = s.delta_view()
    t_all = s.op_times_host()
    tc = s.t_cur
    for lo in range(0, tc + 1, max(tc // 6, 1)):
        for hi in (lo, lo + 2, tc):
            n_ref = int(np.searchsorted(t_all, hi, "right")
                        - np.searchsorted(t_all, lo, "right"))
            assert view.window_ops(lo, hi) == n_ref, (lo, hi)
            d = view.window_delta(lo, hi)
            tw = np.asarray(d.t)[: int(d.n_ops)]
            in_win = ((tw > lo) & (tw <= hi)).sum()
            assert in_win == n_ref, (lo, hi)
            # in-window ops appear in log order (the LWW tie-break)
            assert (np.diff(tw) >= 0).all()


def test_node_ops_matches_node_index():
    rng = np.random.default_rng(2)
    s = _chunked_store(_churn_chunks(rng), segment_min_ops=1)
    view = s.delta_view()
    ptr = np.asarray(s.node_index().row_ptr)
    for v in range(N):
        assert view.node_ops(v) == int(ptr[v + 1] - ptr[v]), v


def test_seal_past_open_unit_rejected():
    """Sealing past t_cur would let a later (legal) ingest land BEHIND
    the sealed segment, breaking segment time-disjointness."""
    s = TemporalGraphStore(n_cap=N, segment_min_ops=1)
    s.ingest([Op(ADD_NODE, 0, 0, 1), Op(ADD_NODE, 1, 1, 5)])  # future op
    s.advance_to(2)
    with pytest.raises(ValueError, match="open"):
        s.seal_tail(5, force=True)
    assert s.seal_tail(2, force=True) == 1   # the closed unit seals fine


def test_residency_spill_and_reload_on_demand():
    rng = np.random.default_rng(3)
    chunks = _churn_chunks(rng, n_chunks=6)
    s = _chunked_store(chunks, segment_min_ops=1)
    view = s.delta_view()
    assert all(seg.is_resident for seg in view.segments)  # no budget
    one = view.segments[0].device_bytes()
    s.segment_device_budget = 2 * one
    s.freeze_serving_state()
    view = s.delta_view()
    resident = [seg for seg in view.segments if seg.is_resident]
    assert len(resident) < len(view.segments)      # cold ones spilled
    assert view.segments[-1].is_resident           # hot tail kept
    # a spill releases EVERY device reference: no cached window may
    # still pin a spilled segment's arrays
    spilled = {seg.uid for seg in view.segments if not seg.is_resident}
    for key in view._cache:
        if key[0] != "empty":
            assert not any(key[0] <= u <= key[1] for u in spilled), key
    # spilled history still answers exactly (reload on demand)
    ref = TemporalGraphStore(n_cap=N, segmented=False)
    ref.ingest([o for c in chunks for o in c])
    ref.advance_to(s.t_cur)
    qs = [Query("point", "global", "num_edges", t_k=t)
          for t in range(1, s.t_cur + 1, 2)]
    _assert_bitequal(s.evaluate_many(qs), ref.evaluate_many(qs), "spill")
    assert any(seg.is_resident for seg in view.segments[:-1])  # reloaded


def test_successive_freezes_share_sealed_device_arrays():
    rng = np.random.default_rng(4)
    chunks = _churn_chunks(rng, n_chunks=4)
    s = TemporalGraphStore(n_cap=N, segment_min_ops=1)
    engines = []
    for chunk in chunks:
        s.ingest(chunk)
        s.advance_to(max(o.t for o in chunk))
        engines.append(s.freeze_serving_state())
        s._engine_cache = None      # force a fresh engine per "epoch"
    v_old, v_new = engines[-2].view, engines[-1].view
    assert len(v_new.segments) == len(v_old.segments) + 1
    for a, b in zip(v_old.segments, v_new.segments):
        assert a is b                        # shared by reference
        assert a.delta is b.delta            # including device arrays


def test_monolithic_flag_disables_segmentation():
    rng = np.random.default_rng(5)
    chunks = _churn_chunks(rng)
    s = _chunked_store(chunks, segmented=False)
    assert not s._segments
    with pytest.raises(ValueError, match="segment"):
        s.delta_view()
    assert int(s.delta().n_ops) == s.stats()["total_ops"]


# ---------------------------------------------------------------------------
# Segmented vs monolithic bit-parity (the tentpole contract)
# ---------------------------------------------------------------------------


def _probe_queries(rng, t_cur, layout):
    qs = []
    for _ in range(8):
        t1 = int(rng.integers(0, t_cur + 1))
        t2 = min(t_cur, t1 + int(rng.integers(0, 5)))
        v = int(rng.integers(0, N))
        qs += [Query("point", "node", "degree", t_k=t1, v=v),
               Query("diff", "node", "degree", t_k=t1, t_l=t2, v=v),
               Query("agg", "node", "degree", t_k=t1, t_l=t2, v=v,
                     agg="mean"),
               Query("point", "global", "num_edges", t_k=t1),
               Query("point", "global", "density", t_k=t2),
               Query("point", "global", "degree_distribution", t_k=t1)]
    return qs


def _check_segmented_vs_monolithic(chunks, layout, probe_seed=0):
    """Drive a segmented and a monolithic store through the same
    interleaved ingest/advance/materialize(policy)/freeze sequence;
    after every round, engine results — auto-planned AND forced
    two-phase (anchor windows) — must bit-match."""
    def policy():
        return (MaterializationPolicy(kind="opcount", op_budget=10)
                if layout == "dense" else None)

    seg = TemporalGraphStore(n_cap=N, layout=layout, policy=policy(),
                             segment_min_ops=2)
    mono = TemporalGraphStore(n_cap=N, layout=layout, policy=policy(),
                              segmented=False)
    rng = np.random.default_rng(probe_seed)
    for chunk in chunks:
        t_hi = max(o.t for o in chunk)
        for s in (seg, mono):
            assert s.ingest(chunk) >= 0
            s.advance_to(t_hi)
        seg.freeze_serving_state()       # the epoch-swap seal boundary
        assert seg.materialized.times == mono.materialized.times
        qs = _probe_queries(rng, seg.t_cur, layout)
        _assert_bitequal(seg.evaluate_many(qs), mono.evaluate_many(qs),
                         (layout, "auto", seg.t_cur))
        _assert_bitequal(seg.evaluate_many(qs, plan="two_phase"),
                         mono.evaluate_many(qs, plan="two_phase"),
                         (layout, "two_phase", seg.t_cur))
        # windowed snapshot reconstruction goes through the segment
        # window too
        t_mid = seg.t_cur // 2
        a = seg.snapshot_at(t_mid, windowed=True)
        b = mono.snapshot_at(t_mid, windowed=True)
        if layout == "edge":
            a, b = a.to_dense(), b.to_dense()
        assert np.array_equal(np.asarray(a.adj), np.asarray(b.adj))
        assert np.array_equal(np.asarray(a.nodes), np.asarray(b.nodes))
    if seg.segmented:
        assert len(seg.delta_view().segments) >= 2  # really fragmented


try:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def chunk_streams(draw):
        mix = [ADD_NODE, ADD_NODE, ADD_EDGE, ADD_EDGE, ADD_EDGE,
               REM_EDGE, REM_NODE]
        n_chunks = draw(st.integers(min_value=2, max_value=4))
        t, chunks = 0, []
        for _ in range(n_chunks):
            t += draw(st.integers(min_value=1, max_value=2))
            n_ops = draw(st.integers(min_value=2, max_value=12))
            chunk = []
            for _ in range(n_ops):
                t += draw(st.integers(min_value=0, max_value=1))
                kind = draw(st.sampled_from(mix))
                u = draw(st.integers(min_value=0, max_value=N - 1))
                v = draw(st.integers(min_value=0, max_value=N - 1))
                chunk.append(Op(kind, u,
                                v if kind in (ADD_EDGE, REM_EDGE) else u,
                                t))
            chunks.append(chunk)
        return chunks

    @given(chunk_streams(), st.sampled_from(["dense", "edge"]))
    @settings(max_examples=15, deadline=None)
    def test_property_segmented_vs_monolithic_bitequal(chunks, layout):
        _check_segmented_vs_monolithic(chunks, layout)

except ImportError:
    @pytest.mark.parametrize("layout", ["dense", "edge"])
    def test_property_segmented_vs_monolithic_bitequal(layout):
        """Seeded-random stand-in for the hypothesis property when
        hypothesis is unavailable (same generator shape, 6 cases)."""
        for seed in range(6):
            rng = np.random.default_rng(seed)
            _check_segmented_vs_monolithic(
                _churn_chunks(rng, n_chunks=3), layout, probe_seed=seed)


@pytest.mark.parametrize("layout", ["dense", "edge"])
def test_segmented_vs_monolithic_seeded(layout):
    """Deterministic instance of the parity property (always runs,
    with or without hypothesis) on a longer stream."""
    rng = np.random.default_rng(42)
    _check_segmented_vs_monolithic(_churn_chunks(rng, n_chunks=5),
                                   layout, probe_seed=7)
