"""Property-based tests (hypothesis) on the system's invariants.

Strategy: random legal op histories → the store must satisfy
completeness, plan equivalence, partial-reconstruction equivalence and
edge-layout equivalence for arbitrary query times/nodes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (reconstruct_dense, reconstruct_edge,
                        reconstruct_sequential)
from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE, REM_NODE
from repro.core.plans import Query
from repro.core.store import Op, TemporalGraphStore

N = 12  # node universe — small keeps hypothesis fast on 1 CPU


@st.composite
def histories(draw):
    """A legal random history: ops are proposals; the store rejects
    illegal transitions, so any sequence is admissible input."""
    n_ops = draw(st.integers(min_value=4, max_value=60))
    ops = []
    t = 1
    for _ in range(n_ops):
        t += draw(st.integers(min_value=0, max_value=2))
        kind = draw(st.sampled_from([ADD_NODE, ADD_NODE, ADD_EDGE,
                                     ADD_EDGE, ADD_EDGE, REM_EDGE,
                                     REM_NODE]))
        u = draw(st.integers(min_value=0, max_value=N - 1))
        v = draw(st.integers(min_value=0, max_value=N - 1))
        ops.append(Op(kind, u, v if kind in (ADD_EDGE, REM_EDGE) else u,
                      t))
    return ops


def _build(ops):
    store = TemporalGraphStore(n_cap=N)
    t_max = max(o.t for o in ops)
    store.ingest(ops)
    store.advance_to(t_max)
    return store


@given(histories(), st.integers(min_value=0, max_value=100))
@settings(max_examples=25, deadline=None)
def test_sequential_equals_vectorized_equals_edges(ops, t_raw):
    store = _build(ops)
    t = t_raw % (store.t_cur + 1)
    d = store.delta()
    a = reconstruct_dense(store.current, d, store.t_cur, t)
    b = reconstruct_sequential(store.current, d, store.t_cur, t)
    assert bool(jnp.all(a.adj == b.adj) & jnp.all(a.nodes == b.nodes))
    eg = store.edge_graph()
    e = reconstruct_edge(eg, d, store.t_cur, t)
    assert bool(jnp.all(e.to_dense().adj == a.adj))
    assert bool(jnp.all(e.nodes == a.nodes))


@given(histories(), st.integers(min_value=0, max_value=N - 1),
       st.integers(min_value=0, max_value=100),
       st.integers(min_value=0, max_value=100))
@settings(max_examples=25, deadline=None)
def test_plans_agree(ops, v, ta_raw, tb_raw):
    store = _build(ops)
    t_k = min(ta_raw, tb_raw) % (store.t_cur + 1)
    t_l = max(t_k, max(ta_raw, tb_raw) % (store.t_cur + 1))
    q_point = Query("point", "node", "degree", t_k=t_k, v=v)
    r_two = int(store.query(q_point, plan="two_phase"))
    assert int(store.query(q_point, plan="hybrid")) == r_two
    assert int(store.query(q_point, plan="hybrid", indexed=True)) == r_two
    assert int(store.query(q_point, plan="two_phase",
                           partial_rows=True)) == r_two

    q_diff = Query("diff", "node", "degree", t_k=t_k, t_l=t_l, v=v)
    d_two = int(store.query(q_diff, plan="two_phase"))
    assert int(store.query(q_diff, plan="delta_only")) == d_two
    assert int(store.query(q_diff, plan="delta_only", indexed=True)) == \
        d_two


@given(histories())
@settings(max_examples=15, deadline=None)
def test_roundtrip_back_then_forward(ops):
    """BackRec then ForRec returns the current snapshot (invertibility,
    Definition 5)."""
    store = _build(ops)
    d = store.delta()
    t = store.t_cur // 2
    back = reconstruct_dense(store.current, d, store.t_cur, t)
    forth = reconstruct_dense(back, d, t, store.t_cur)
    assert bool(jnp.all(forth.adj == store.current.adj))
    assert bool(jnp.all(forth.nodes == store.current.nodes))


@given(histories())
@settings(max_examples=10, deadline=None)
def test_store_consistency(ops):
    """Current snapshot is structurally valid (symmetric adjacency,
    edges only between live nodes)."""
    store = _build(ops)
    assert bool(store.current.validate())


@given(histories(), st.integers(min_value=0, max_value=100),
       st.integers(min_value=0, max_value=N - 1))
@settings(max_examples=20, deadline=None)
def test_dense_edge_layout_query_parity(ops, t_raw, v):
    """Random legal delta + random query → bit-identical results under
    forced dense and forced edge execution, for every edge-supported
    measure and every query kind (the edge-slot tentpole contract)."""
    store = _build(ops)
    eng = store.engine()
    t_k = t_raw % (store.t_cur + 1)
    t_l = min(store.t_cur, t_k + (t_raw % 5))
    qs = [Query("point", "node", "degree", t_k=t_k, v=v),
          Query("diff", "node", "degree", t_k=t_k, t_l=t_l, v=v),
          Query("agg", "node", "degree", t_k=t_k, t_l=t_l, v=v,
                agg="mean"),
          Query("point", "global", "num_edges", t_k=t_k),
          Query("point", "global", "num_nodes", t_k=t_k),
          Query("point", "global", "density", t_k=t_k),
          Query("point", "global", "avg_degree", t_k=t_k),
          Query("diff", "global", "num_edges", t_k=t_k, t_l=t_l)]
    dense = [np.asarray(r).item()
             for r in eng.evaluate_many(qs, layout="dense")]
    edge = [np.asarray(r).item()
            for r in eng.evaluate_many(qs, layout="edge")]
    assert edge == dense
