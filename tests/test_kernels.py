"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(kernels run in interpret mode on CPU; TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.generate import EvolutionParams, build_store
from repro.core.reconstruct import reconstruct_dense


@pytest.fixture(scope="module")
def kstore():
    return build_store(
        90, EvolutionParams(m_attach=3, lam_extra=1.0, lam_remove=1.5,
                            p_remove_node=0.02), seed=5, n_cap=128)


class TestDeltaApply:
    @pytest.mark.parametrize("tile", [32, 64, 128])
    def test_backward_sweep(self, kstore, tile):
        from repro.kernels.delta_apply import delta_apply, delta_apply_ref
        d = kstore.delta()
        for tq in [0, kstore.t_cur // 2, kstore.t_cur]:
            g, ovf = delta_apply(kstore.current, d, kstore.t_cur, tq,
                                 tile=tile, cap=2048)
            ref = delta_apply_ref(kstore.current, d, kstore.t_cur, tq)
            assert not bool(ovf)
            assert bool(jnp.all(g.adj == ref.adj)), (tile, tq)
            assert bool(jnp.all(g.nodes == ref.nodes)), (tile, tq)

    def test_forward(self, kstore):
        from repro.kernels.delta_apply import delta_apply, delta_apply_ref
        d = kstore.delta()
        t_a = 5
        anchor = delta_apply_ref(kstore.current, d, kstore.t_cur, t_a)
        g, ovf = delta_apply(anchor, d, t_a, kstore.t_cur, tile=64,
                             cap=2048)
        assert not bool(ovf)
        assert bool(jnp.all(g.adj == kstore.current.adj))

    def test_matches_core(self, kstore):
        from repro.kernels.delta_apply import delta_apply
        d = kstore.delta()
        tq = kstore.t_cur // 3
        g, _ = delta_apply(kstore.current, d, kstore.t_cur, tq, tile=64,
                           cap=2048)
        rr = reconstruct_dense(kstore.current, d, kstore.t_cur, tq)
        assert bool(jnp.all(g.adj == rr.adj))

    def test_overflow_flag(self, kstore):
        from repro.kernels.delta_apply import delta_apply
        d = kstore.delta()
        _, ovf = delta_apply(kstore.current, d, kstore.t_cur, 0, tile=128,
                             cap=8)
        assert bool(ovf)

    @pytest.mark.parametrize("n_shards", [2, 4])
    def test_row_blocks_concatenate_to_full(self, kstore, n_shards):
        """Shard-safe bucketing: reconstructing each row block
        independently (its own tile padding, global columns) and
        concatenating equals the full reconstruction — the contract the
        row-sharded mesh relies on."""
        from repro.kernels.delta_apply.ops import delta_apply_row_block
        d = kstore.delta()
        n = kstore.n_cap
        rb = n // n_shards
        for tq in [0, kstore.t_cur // 2]:
            ref = reconstruct_dense(kstore.current, d, kstore.t_cur, tq)
            nodes, adjs = [], []
            for row0 in range(0, n, rb):
                nb, ab, ovf = delta_apply_row_block(
                    kstore.current.nodes[row0:row0 + rb],
                    kstore.current.adj[row0:row0 + rb], d, kstore.t_cur,
                    tq, row0, tile=32, cap=2048)
                assert not bool(ovf)
                nodes.append(nb)
                adjs.append(ab)
            assert bool(jnp.all(jnp.concatenate(adjs) == ref.adj))
            assert bool(jnp.all(jnp.concatenate(nodes) == ref.nodes))

    def test_row_block_pad_band_excludes_next_shard(self, kstore):
        """A block whose row count is not a tile multiple pads up to
        the tile — ops owned by the NEXT shard must not leak into the
        pad band (they would burn cap slots and raise a spurious
        overflow), and a non-uniform split must still stitch exactly."""
        from repro.core.delta import delta_from_numpy
        from repro.kernels.delta_apply.ops import (delta_apply_row_block,
                                                   bucket_ops)
        # crafted log: 30 edge ops all touching row 50, which belongs
        # to the SECOND shard of a (0..48, 48..128) split; shard 1's
        # pad band covers rows 48..63 and must stay empty
        k = 30
        ops = np.full(k, 2, np.int32)                       # ADD_EDGE
        us = np.full(k, 50, np.int32)
        vs = np.arange(64, 64 + k, dtype=np.int32)
        d50 = delta_from_numpy(ops, us, vs, np.zeros(k, np.int32),
                               np.arange(1, k + 1, dtype=np.int32))
        blocks, ovf = bucket_ops(d50, 128, 0, k, 32, 8, True,
                                 n_rows=64, row0=0, n_valid_rows=48)
        assert not bool(ovf)
        assert int(jnp.sum(blocks[..., 3])) == 0   # nothing bucketed
        # and the real-store non-uniform split stitches bit-exactly
        d = kstore.delta()
        tq = kstore.t_cur // 2
        ref = reconstruct_dense(kstore.current, d, kstore.t_cur, tq)
        nodes, adjs = [], []
        for row0, rcount in ((0, 48), (48, 80)):
            nb, ab, ovf = delta_apply_row_block(
                kstore.current.nodes[row0:row0 + rcount],
                kstore.current.adj[row0:row0 + rcount], d, kstore.t_cur,
                tq, row0, tile=32, cap=2048)
            assert not bool(ovf), (row0, rcount)
            nodes.append(nb)
            adjs.append(ab)
        assert bool(jnp.all(jnp.concatenate(adjs) == ref.adj))
        assert bool(jnp.all(jnp.concatenate(nodes) == ref.nodes))


class TestEdgeDeltaApply:
    """Slot-space LWW kernel: oracle parity, direction sweep, the
    reconstruct_edge cross-check, overflow, and slot-block shard
    safety (the contract the slot-sharded mesh relies on)."""

    @pytest.mark.parametrize("tile", [32, 64, 128])
    def test_backward_sweep(self, kstore, tile):
        from repro.kernels.edge_delta_apply import (edge_delta_apply,
                                                    edge_delta_apply_ref)
        d = kstore.delta()
        cur = kstore.current_edge_snapshot()
        for tq in [0, kstore.t_cur // 2, kstore.t_cur]:
            g, ovf = edge_delta_apply(cur, d, kstore.t_cur, tq,
                                      tile=tile, cap=2048)
            ref = edge_delta_apply_ref(cur, d, kstore.t_cur, tq)
            assert not bool(ovf)
            assert bool(jnp.all(g.emask == ref.emask)), (tile, tq)
            assert bool(jnp.all(g.nodes == ref.nodes)), (tile, tq)

    def test_forward(self, kstore):
        from repro.kernels.edge_delta_apply import (edge_delta_apply,
                                                    edge_delta_apply_ref)
        d = kstore.delta()
        cur = kstore.current_edge_snapshot()
        t_a = 5
        anchor = edge_delta_apply_ref(cur, d, kstore.t_cur, t_a)
        g, ovf = edge_delta_apply(anchor, d, t_a, kstore.t_cur, tile=64,
                                  cap=2048)
        assert not bool(ovf)
        assert bool(jnp.all(g.emask == cur.emask))

    def test_matches_core_and_dense(self, kstore):
        """Kernel == reconstruct_edge, and its dense projection ==
        reconstruct_dense — the layout-equivalence triangle."""
        from repro.core.reconstruct import reconstruct_edge
        from repro.kernels.edge_delta_apply import edge_delta_apply
        d = kstore.delta()
        cur = kstore.current_edge_snapshot()
        tq = kstore.t_cur // 3
        g, _ = edge_delta_apply(cur, d, kstore.t_cur, tq, tile=64,
                                cap=2048)
        rr = reconstruct_edge(cur, d, kstore.t_cur, tq)
        assert bool(jnp.all(g.emask == rr.emask))
        dense = reconstruct_dense(kstore.current, d, kstore.t_cur, tq)
        assert bool(jnp.all(g.to_dense().adj == dense.adj))
        assert bool(jnp.all(g.nodes == dense.nodes))

    def test_overflow_flag(self, kstore):
        from repro.kernels.edge_delta_apply import edge_delta_apply
        d = kstore.delta()
        cur = kstore.current_edge_snapshot()
        _, ovf = edge_delta_apply(cur, d, kstore.t_cur, 0, tile=512,
                                  cap=8)
        assert bool(ovf)

    @pytest.mark.parametrize("n_shards", [2, 4])
    def test_slot_blocks_concatenate_to_full(self, kstore, n_shards):
        from repro.core.reconstruct import reconstruct_edge
        from repro.kernels.edge_delta_apply import (
            edge_delta_apply_slot_block)
        d = kstore.delta()
        cur = kstore.current_edge_snapshot()
        e = cur.e_cap
        w = e // n_shards
        for tq in [0, kstore.t_cur // 2]:
            ref = reconstruct_edge(cur, d, kstore.t_cur, tq)
            masks = []
            for slot0 in range(0, e, w):
                nb, em, ovf = edge_delta_apply_slot_block(
                    cur.nodes, cur.emask[slot0:slot0 + w], d,
                    kstore.t_cur, tq, slot0, tile=32, cap=2048)
                assert not bool(ovf)
                masks.append(em)
                assert bool(jnp.all(nb == ref.nodes))
            assert bool(jnp.all(jnp.concatenate(masks) == ref.emask)), \
                (n_shards, tq)

    def test_slot_block_pad_band_excludes_next_shard(self, kstore):
        """A block whose slot count is not a tile multiple pads up to
        the tile — ops owned by the NEXT shard must not leak into the
        pad band, and a non-uniform split must still stitch exactly."""
        from repro.core.delta import delta_from_numpy
        from repro.core.reconstruct import reconstruct_edge
        from repro.kernels.edge_delta_apply import (
            bucket_slot_ops, edge_delta_apply_slot_block)
        # crafted log: 30 edge ops all on slot 50, which belongs to the
        # SECOND shard of a (0..48, 48..e) split; shard 1's pad band
        # covers slots 48..63 and must stay empty
        k = 30
        ops = np.full(k, 2, np.int32)                       # ADD_EDGE
        us = np.zeros(k, np.int32)
        vs = np.arange(1, k + 1, dtype=np.int32)
        d50 = delta_from_numpy(ops, us, vs, np.full(k, 50, np.int32),
                               np.arange(1, k + 1, dtype=np.int32))
        blocks, ovf = bucket_slot_ops(d50, 64, 0, k, 32, 8, True,
                                      slot0=0, n_valid_slots=48)
        assert not bool(ovf)
        assert int(jnp.sum(blocks[..., 2])) == 0   # nothing bucketed
        # and the real-store non-uniform split stitches bit-exactly
        d = kstore.delta()
        cur = kstore.current_edge_snapshot()
        tq = kstore.t_cur // 2
        ref = reconstruct_edge(cur, d, kstore.t_cur, tq)
        masks = []
        for slot0, scount in ((0, 48), (48, cur.e_cap - 48)):
            _, em, ovf = edge_delta_apply_slot_block(
                cur.nodes, cur.emask[slot0:slot0 + scount], d,
                kstore.t_cur, tq, slot0, tile=32, cap=2048)
            assert not bool(ovf), (slot0, scount)
            masks.append(em)
        assert bool(jnp.all(jnp.concatenate(masks) == ref.emask))


class TestDegreeSeries:
    @pytest.mark.parametrize("tile,buckets", [(32, 8), (64, 16), (128, 5)])
    def test_sweep(self, kstore, tile, buckets):
        from repro.kernels.degree_series import (degree_series_kernel,
                                                 degree_series_ref)
        d = kstore.delta()
        tk = kstore.t_cur // 3
        out, ovf = degree_series_kernel(kstore.current, d, tk, buckets,
                                        tile=tile, cap=4096)
        assert not bool(ovf)
        ref = degree_series_ref(kstore.current, d, tk, kstore.t_cur,
                                buckets)
        assert bool(jnp.all(out == ref)), (tile, buckets)

    def test_node_blocks_concatenate_to_full(self, kstore):
        """Shard-safe event bucketing: per-node-block series stitched
        along the node axis equal the full-kernel series."""
        from repro.kernels.degree_series import degree_series_kernel
        from repro.kernels.degree_series.ops import degree_series_rows
        d = kstore.delta()
        tk = kstore.t_cur // 3
        buckets = 8
        full, ovf = degree_series_kernel(kstore.current, d, tk, buckets,
                                         tile=32, cap=4096)
        assert not bool(ovf)
        deg = kstore.current.degrees()
        n = kstore.n_cap
        parts = []
        for row0 in range(0, n, n // 4):
            s, ovf = degree_series_rows(deg[row0:row0 + n // 4], d, tk,
                                        buckets, row0=row0, tile=32,
                                        cap=4096)
            assert not bool(ovf)
            parts.append(s)
        assert bool(jnp.all(jnp.concatenate(parts, axis=1) == full))


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,hq,hkv,sq,skv,d,causal,window,bq,bk",
        [(2, 4, 2, 64, 64, 32, True, None, 32, 32),
         (1, 4, 1, 64, 64, 16, True, 24, 16, 16),
         (1, 2, 2, 40, 72, 32, False, None, 16, 32),
         (1, 1, 1, 100, 100, 8, True, 16, 32, 32)])
    def test_sweep(self, dtype, b, hq, hkv, sq, skv, d, causal, window,
                   bq, bk):
        from repro.kernels.flash_attention import (attention_ref,
                                                   flash_attention)
        rng = np.random.default_rng(42)
        q = jnp.asarray(rng.standard_normal((b, hq, sq, d)),
                        dtype=dtype)
        k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)),
                        dtype=dtype)
        v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)),
                        dtype=dtype)
        out = flash_attention(q, k, v, causal, window, None, bq, bk, True)
        ref = attention_ref(q, k, v, causal=causal, window=window,
                            scale=d ** -0.5)
        tol = 3e-5 if dtype == jnp.float32 else 3e-2
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err < tol

    def test_grad_matches_reference(self):
        from repro.kernels.flash_attention import (attention_ref,
                                                   flash_attention)
        rng = np.random.default_rng(1)
        q, k, v = (jnp.asarray(rng.standard_normal((1, 2, 32, 16)),
                               dtype=jnp.float32) for _ in range(3))

        def l_kernel(q, k, v):
            return jnp.sum(
                flash_attention(q, k, v, True, None, None, 16, 16,
                                True) ** 2)

        def l_ref(q, k, v):
            return jnp.sum(attention_ref(q, k, v, causal=True,
                                         scale=16 ** -0.5) ** 2)

        g1 = jax.grad(l_kernel, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(l_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-4


class TestSSDScan:
    @pytest.mark.parametrize(
        "b,s,h,p,n,chunk",
        [(2, 64, 3, 8, 16, 16), (1, 100, 2, 16, 8, 32),
         (2, 128, 4, 32, 64, 128), (1, 48, 1, 64, 128, 16)])
    def test_sweep(self, b, s, h, p, n, chunk):
        from repro.kernels.ssd_scan import ssd_ref, ssd_scan
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal((b, s, h, p)),
                        dtype=jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)),
                         dtype=jnp.float32)
        a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), dtype=jnp.float32)
        B = jnp.asarray(rng.standard_normal((b, s, n)),
                        dtype=jnp.float32)
        C = jnp.asarray(rng.standard_normal((b, s, n)),
                        dtype=jnp.float32)
        y = ssd_scan(x, dt, a, B, C, chunk=chunk)
        ref = ssd_ref(x, dt, a, B, C)
        assert float(jnp.max(jnp.abs(y - ref))) < 5e-5

    def test_matches_model_ssd(self):
        """Kernel == the model stack's chunked-XLA SSD."""
        from repro.kernels.ssd_scan import ssd_scan
        from repro.models.ssm import ssd_chunked
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.standard_normal((2, 64, 3, 8)),
                        dtype=jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (2, 64, 3)),
                         dtype=jnp.float32)
        a = jnp.asarray(-rng.uniform(0.5, 2.0, (3,)), dtype=jnp.float32)
        B = jnp.asarray(rng.standard_normal((2, 64, 16)),
                        dtype=jnp.float32)
        C = jnp.asarray(rng.standard_normal((2, 64, 16)),
                        dtype=jnp.float32)
        y1 = ssd_scan(x, dt, a, B, C, chunk=16)
        y2, _ = ssd_chunked(x, dt, a, B, C, 16)
        assert float(jnp.max(jnp.abs(y1 - y2))) < 5e-5
