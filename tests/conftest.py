import os
import sys

# Tests must see ONE device (the dry-run sets 512 only inside its own
# process). Make sure no flag leaks in from the environment — but stash
# it so the multidevice subprocess tests can inherit the CI lane's
# forced device count (see tests/test_distributed.py).
_flags = os.environ.pop("XLA_FLAGS", None)
if _flags:
    os.environ.setdefault("REPRO_CI_XLA_FLAGS", _flags)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np
import pytest

from repro.core.generate import EvolutionParams, build_store, generate_ops
from repro.core.store import TemporalGraphStore


def pytest_addoption(parser):
    parser.addoption(
        "--lockdep", action="store_true", default=False,
        help="enable the runtime lock-order sanitizer "
             "(repro.analysis.lockdep) for every test")


def _lockdep_requested(config):
    return (config.getoption("--lockdep")
            or os.environ.get("GRAPHLINT_LOCKDEP") == "1")


@pytest.fixture(autouse=True)
def _lockdep_sanitizer(request):
    """Opt-in lock-order sanitizer: ``pytest --lockdep`` (or
    GRAPHLINT_LOCKDEP=1) patches threading.Lock/RLock so any
    AB/BA lock-order inversion raises LockOrderError deterministically
    instead of deadlocking intermittently.  The order graph resets per
    test so one test's ordering can't poison another's."""
    if not _lockdep_requested(request.config):
        yield
        return
    from repro.analysis import lockdep
    if lockdep.enabled():  # a test drives enable/disable itself
        yield
        return
    lockdep.enable()
    try:
        yield
    finally:
        lockdep.disable()


@pytest.fixture(scope="session")
def small_history():
    """A small evolving graph + its brute-force oracle."""
    from reference import BruteForce
    params = EvolutionParams(m_attach=3, lam_extra=1.0, lam_remove=1.5,
                             p_remove_node=0.03, events_per_unit=6)
    ops = generate_ops(80, params, seed=11)
    n_cap = 96
    store = TemporalGraphStore(n_cap=n_cap)
    t_max = max(o.t for o in ops)
    store.ingest(ops)
    store.advance_to(t_max)
    # oracle replays the *accepted* log (store may auto-insert remEdge
    # before remNode; replay from the store's own arrays)
    from repro.core.store import Op
    acc = [Op(int(o), int(u), int(v), int(t)) for o, u, v, t in
           zip(store._op, store._u, store._v, store._t)]
    bf = BruteForce(acc, n_cap, t_max)
    return store, bf
