"""Delta invariants: Definitions 2–5, Lemma 1, Theorem 1."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ADD_EDGE, ADD_NODE, NOP, REM_EDGE, REM_NODE, Delta,
                        delta_from_numpy, empty_delta,
                        minimal_delta_between, reconstruct_dense,
                        reconstruct_sequential, slice_delta)
from repro.core.graph import DenseGraph, dense_from_numpy


def test_invert_is_involution(small_history):
    store, _ = small_history
    d = store.delta()
    dd = d.invert().invert()
    assert bool(jnp.all(dd.op == d.op))


def test_invert_swaps_add_rem():
    d = delta_from_numpy([ADD_NODE, REM_NODE, ADD_EDGE, REM_EDGE],
                         [0, 1, 2, 3], [0, 1, 3, 4], [0, 1, 0, 1],
                         [1, 2, 3, 4])
    inv = d.invert()
    assert inv.op.tolist()[:4] == [REM_NODE, ADD_NODE, REM_EDGE, ADD_EDGE]


def test_window_mask_half_open():
    d = delta_from_numpy([ADD_NODE] * 4, [0, 1, 2, 3], [0, 1, 2, 3],
                         [0, 1, 2, 3], [1, 2, 3, 4])
    m = np.asarray(d.window_mask(1, 3))
    assert m.tolist()[:4] == [False, True, True, False]


def test_padding_is_inert(small_history):
    store, bf = small_history
    d_tight = store.delta()
    d_padded = store.delta(capacity=d_tight.capacity * 2)
    t = store.t_cur // 2
    a = reconstruct_dense(store.current, d_tight, store.t_cur, t)
    b = reconstruct_dense(store.current, d_padded, store.t_cur, t)
    assert bool(jnp.all(a.adj == b.adj) & jnp.all(a.nodes == b.nodes))


def test_completeness_every_time_unit(small_history):
    """Definition 4: Δ[t0,t'] ∘ SG_t0 = SG_t' for every t'."""
    store, bf = small_history
    d = store.delta()
    empty = DenseGraph(nodes=jnp.zeros((store.n_cap,), bool),
                       adj=jnp.zeros((store.n_cap, store.n_cap), bool))
    for t in range(0, store.t_cur + 1, max(store.t_cur // 7, 1)):
        g = reconstruct_dense(empty, d, 0, t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t
        assert np.array_equal(np.asarray(g.nodes), bf.node_mask(t)), t


def test_backward_reconstruction_theorem1(small_history):
    """Theorem 1: current snapshot + invertible delta suffice."""
    store, bf = small_history
    d = store.delta()
    for t in range(0, store.t_cur + 1, max(store.t_cur // 7, 1)):
        g = reconstruct_dense(store.current, d, store.t_cur, t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t
        assert np.array_equal(np.asarray(g.nodes), bf.node_mask(t)), t


def test_forward_from_any_anchor(small_history):
    store, bf = small_history
    d = store.delta()
    t_a = store.t_cur // 3
    anchor = reconstruct_dense(store.current, d, store.t_cur, t_a)
    for t in [t_a + 1, store.t_cur // 2, store.t_cur]:
        if t < t_a:
            continue
        g = reconstruct_dense(anchor, d, t_a, t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t


def test_minimal_delta_lemma1(small_history):
    """Lemma 1: the minimal delta between two snapshots, applied to the
    first, yields the second — and contains no redundant ops."""
    store, bf = small_history
    t_a, t_b = store.t_cur // 4, 3 * store.t_cur // 4
    ma, aa = bf.node_mask(t_a), bf.adj(t_a)
    mb, ab = bf.node_mask(t_b), bf.adj(t_b)
    op, u, v, t = minimal_delta_between(ma, aa, mb, ab, t_b)
    # apply by hand
    nodes = ma.copy()
    adj = aa.copy()
    for o, uu, vv in zip(op, u, v):
        if o == ADD_NODE:
            assert not nodes[uu]  # minimality: genuine transition
            nodes[uu] = True
        elif o == REM_NODE:
            assert nodes[uu]
            nodes[uu] = False
            adj[uu, :] = adj[:, uu] = False
        elif o == ADD_EDGE:
            assert not adj[uu, vv]
            adj[uu, vv] = adj[vv, uu] = True
        else:
            assert adj[uu, vv]
            adj[uu, vv] = adj[vv, uu] = False
    assert np.array_equal(nodes, mb)
    assert np.array_equal(adj, ab)


def test_slice_delta(small_history):
    store, _ = small_history
    d = store.delta()
    lo, hi = store.t_cur // 4, store.t_cur // 2
    s = slice_delta(d, lo, hi)
    t = np.asarray(s.t)[: int(s.n_ops)]
    assert ((t > lo) & (t <= hi)).all()


def test_sequential_matches_vectorized(small_history):
    store, _ = small_history
    d = store.delta()
    for t in range(0, store.t_cur + 1, max(store.t_cur // 5, 1)):
        a = reconstruct_dense(store.current, d, store.t_cur, t)
        b = reconstruct_sequential(store.current, d, store.t_cur, t)
        assert bool(jnp.all(a.adj == b.adj)), t
        assert bool(jnp.all(a.nodes == b.nodes)), t


# ---------------------------------------------------------------------------
# Store time-unit boundary regressions (PR 5)
# ---------------------------------------------------------------------------


def test_ingest_rejects_ops_at_closed_time_units():
    """Ops at t == t_cur used to be accepted into the log (only
    t < t_cur was rejected), but advance_to's half-open reconstruction
    window (t_cur, t_next] never applied them — the host mirror and
    edge registry silently diverged from the device current snapshot.
    The store now rejects them up front, the same immutable-history
    contract LiveGraphStore enforces at the swap boundary."""
    from repro.core.store import Op, TemporalGraphStore
    s = TemporalGraphStore(n_cap=8)
    s.ingest([Op(ADD_NODE, 0, 0, 1), Op(ADD_NODE, 1, 1, 1)])
    s.advance_to(2)
    with pytest.raises(ValueError, match="immutable"):
        s.ingest([Op(ADD_EDGE, 0, 1, 2)])   # t == t_cur: closed unit
    with pytest.raises(ValueError, match="immutable"):
        s.ingest([Op(ADD_EDGE, 0, 1, 1)])   # t < t_cur still rejected
    # the rejected ops never reached the log; state stays consistent
    assert s.stats()["total_ops"] == 2
    s.ingest([Op(ADD_EDGE, 0, 1, 3)])
    s.advance_to(3)
    assert int(s.current.num_edges()) == 1
    assert s.stats()["live_edges"] == 1
    # intra-batch time ordering is enforced too (every binary search —
    # temporal index, seal cuts, advance counting — assumes sorted t)
    with pytest.raises(ValueError, match="time-ordered"):
        s.ingest([Op(ADD_NODE, 5, 5, 7), Op(ADD_NODE, 6, 6, 5)])
    # ...and the accepted prefix of a failed batch is still visible:
    # caches must invalidate even on a mid-batch raise
    assert s.stats()["total_ops"] == 4
    assert int(s.delta().n_ops) == 4 and s.op_times_host()[-1] == 7


def test_advance_counts_only_ops_of_closed_units():
    """advance_to used to count every op with t > t_cur as "new", so
    future-dated ops were re-counted by every later advance —
    _ops_since_mat drifted and the op-count materialization policy
    fired early.  Only ops in (t_cur, t_next] may count."""
    from repro.core.store import Op, TemporalGraphStore
    s = TemporalGraphStore(n_cap=8)
    s.ingest([Op(ADD_NODE, i, i, 1) for i in range(4)]
             + [Op(ADD_EDGE, 0, 1, 2)]
             + [Op(ADD_EDGE, 1, 2, 9), Op(ADD_EDGE, 2, 3, 9)])  # future
    s.advance_to(2)         # closes units 1..2: 5 ops
    assert s._ops_since_mat == 5
    s.advance_to(5)         # closes 3..5: no ops — t=9 must NOT recount
    assert s._ops_since_mat == 5
    s.advance_to(9)         # the two t=9 ops finally close
    assert s._ops_since_mat == 7
    assert int(s.current.num_edges()) == 3


def test_delta_capacity_below_n_ops_raises():
    """store.delta(capacity < n_ops) used to compute a negative pad and
    crash deep inside np.full with a cryptic error; it now raises a
    ValueError up front, mirroring delta_from_numpy."""
    from repro.core.store import Op, TemporalGraphStore
    for segmented in (True, False):
        s = TemporalGraphStore(n_cap=8, segmented=segmented)
        s.ingest([Op(ADD_NODE, i, i, 1) for i in range(6)])
        with pytest.raises(ValueError, match="capacity"):
            s.delta(capacity=4)
        d = s.delta(capacity=8)
        assert d.capacity == 8 and int(d.n_ops) == 6


def test_host_array_caches_invalidate_on_append():
    """The _op/_u/_v/_slot/_t properties and op_times_host re-converted
    the whole python list per access (O(M) each — 4 conversions per
    stats() call); they are now cached alongside _delta_cache and
    invalidated on append."""
    from repro.core.store import Op, TemporalGraphStore
    s = TemporalGraphStore(n_cap=8)
    s.ingest([Op(ADD_NODE, i, i, 1) for i in range(4)])
    a = s.op_times_host()
    assert s.op_times_host() is a and s._t is a  # cached, no re-convert
    assert s._op is s._op
    s.ingest([Op(ADD_EDGE, 0, 1, 2)])
    b = s.op_times_host()
    assert b is not a and b.shape[0] == a.shape[0] + 1


def test_gather_window_suffix_clamp_regression(small_history):
    """gather_window used to let dynamic_slice clamp an out-of-range
    start (i0 + window_cap > capacity) back toward 0, silently swapping
    in-window ops for pre-window ones — exactly the suffix windows that
    two-phase groups anchored at the current snapshot slice.  The
    gathered window must reconstruct identically to the full log for
    every anchor-side window and capacity."""
    from repro.core import reconstruct_dense
    from repro.core.index import count_window_ops, gather_window
    store, _ = small_history
    d = store.delta()
    tc = store.t_cur
    for t in range(0, tc + 1, max(tc // 7, 1)):
        n_win = int(count_window_ops(d, t, tc))
        for cap in {max(64, n_win), d.capacity // 2, d.capacity}:
            if cap < n_win or cap > d.capacity:
                continue
            w = gather_window(d, t, tc, cap)
            tw = np.asarray(w.t)[: int(w.n_ops)]
            assert int(w.n_ops) == n_win
            assert ((tw > t) & (tw <= tc)).all(), (t, cap)
            a = reconstruct_dense(store.current, w, tc, t)
            b = reconstruct_dense(store.current, d, tc, t)
            assert bool(jnp.all(a.adj == b.adj)), (t, cap)
            assert bool(jnp.all(a.nodes == b.nodes)), (t, cap)
