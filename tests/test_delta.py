"""Delta invariants: Definitions 2–5, Lemma 1, Theorem 1."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ADD_EDGE, ADD_NODE, NOP, REM_EDGE, REM_NODE, Delta,
                        delta_from_numpy, empty_delta,
                        minimal_delta_between, reconstruct_dense,
                        reconstruct_sequential, slice_delta)
from repro.core.graph import DenseGraph, dense_from_numpy


def test_invert_is_involution(small_history):
    store, _ = small_history
    d = store.delta()
    dd = d.invert().invert()
    assert bool(jnp.all(dd.op == d.op))


def test_invert_swaps_add_rem():
    d = delta_from_numpy([ADD_NODE, REM_NODE, ADD_EDGE, REM_EDGE],
                         [0, 1, 2, 3], [0, 1, 3, 4], [0, 1, 0, 1],
                         [1, 2, 3, 4])
    inv = d.invert()
    assert inv.op.tolist()[:4] == [REM_NODE, ADD_NODE, REM_EDGE, ADD_EDGE]


def test_window_mask_half_open():
    d = delta_from_numpy([ADD_NODE] * 4, [0, 1, 2, 3], [0, 1, 2, 3],
                         [0, 1, 2, 3], [1, 2, 3, 4])
    m = np.asarray(d.window_mask(1, 3))
    assert m.tolist()[:4] == [False, True, True, False]


def test_padding_is_inert(small_history):
    store, bf = small_history
    d_tight = store.delta()
    d_padded = store.delta(capacity=d_tight.capacity * 2)
    t = store.t_cur // 2
    a = reconstruct_dense(store.current, d_tight, store.t_cur, t)
    b = reconstruct_dense(store.current, d_padded, store.t_cur, t)
    assert bool(jnp.all(a.adj == b.adj) & jnp.all(a.nodes == b.nodes))


def test_completeness_every_time_unit(small_history):
    """Definition 4: Δ[t0,t'] ∘ SG_t0 = SG_t' for every t'."""
    store, bf = small_history
    d = store.delta()
    empty = DenseGraph(nodes=jnp.zeros((store.n_cap,), bool),
                       adj=jnp.zeros((store.n_cap, store.n_cap), bool))
    for t in range(0, store.t_cur + 1, max(store.t_cur // 7, 1)):
        g = reconstruct_dense(empty, d, 0, t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t
        assert np.array_equal(np.asarray(g.nodes), bf.node_mask(t)), t


def test_backward_reconstruction_theorem1(small_history):
    """Theorem 1: current snapshot + invertible delta suffice."""
    store, bf = small_history
    d = store.delta()
    for t in range(0, store.t_cur + 1, max(store.t_cur // 7, 1)):
        g = reconstruct_dense(store.current, d, store.t_cur, t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t
        assert np.array_equal(np.asarray(g.nodes), bf.node_mask(t)), t


def test_forward_from_any_anchor(small_history):
    store, bf = small_history
    d = store.delta()
    t_a = store.t_cur // 3
    anchor = reconstruct_dense(store.current, d, store.t_cur, t_a)
    for t in [t_a + 1, store.t_cur // 2, store.t_cur]:
        if t < t_a:
            continue
        g = reconstruct_dense(anchor, d, t_a, t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t


def test_minimal_delta_lemma1(small_history):
    """Lemma 1: the minimal delta between two snapshots, applied to the
    first, yields the second — and contains no redundant ops."""
    store, bf = small_history
    t_a, t_b = store.t_cur // 4, 3 * store.t_cur // 4
    ma, aa = bf.node_mask(t_a), bf.adj(t_a)
    mb, ab = bf.node_mask(t_b), bf.adj(t_b)
    op, u, v, t = minimal_delta_between(ma, aa, mb, ab, t_b)
    # apply by hand
    nodes = ma.copy()
    adj = aa.copy()
    for o, uu, vv in zip(op, u, v):
        if o == ADD_NODE:
            assert not nodes[uu]  # minimality: genuine transition
            nodes[uu] = True
        elif o == REM_NODE:
            assert nodes[uu]
            nodes[uu] = False
            adj[uu, :] = adj[:, uu] = False
        elif o == ADD_EDGE:
            assert not adj[uu, vv]
            adj[uu, vv] = adj[vv, uu] = True
        else:
            assert adj[uu, vv]
            adj[uu, vv] = adj[vv, uu] = False
    assert np.array_equal(nodes, mb)
    assert np.array_equal(adj, ab)


def test_slice_delta(small_history):
    store, _ = small_history
    d = store.delta()
    lo, hi = store.t_cur // 4, store.t_cur // 2
    s = slice_delta(d, lo, hi)
    t = np.asarray(s.t)[: int(s.n_ops)]
    assert ((t > lo) & (t <= hi)).all()


def test_sequential_matches_vectorized(small_history):
    store, _ = small_history
    d = store.delta()
    for t in range(0, store.t_cur + 1, max(store.t_cur // 5, 1)):
        a = reconstruct_dense(store.current, d, store.t_cur, t)
        b = reconstruct_sequential(store.current, d, store.t_cur, t)
        assert bool(jnp.all(a.adj == b.adj)), t
        assert bool(jnp.all(a.nodes == b.nodes)), t


def test_gather_window_suffix_clamp_regression(small_history):
    """gather_window used to let dynamic_slice clamp an out-of-range
    start (i0 + window_cap > capacity) back toward 0, silently swapping
    in-window ops for pre-window ones — exactly the suffix windows that
    two-phase groups anchored at the current snapshot slice.  The
    gathered window must reconstruct identically to the full log for
    every anchor-side window and capacity."""
    from repro.core import reconstruct_dense
    from repro.core.index import count_window_ops, gather_window
    store, _ = small_history
    d = store.delta()
    tc = store.t_cur
    for t in range(0, tc + 1, max(tc // 7, 1)):
        n_win = int(count_window_ops(d, t, tc))
        for cap in {max(64, n_win), d.capacity // 2, d.capacity}:
            if cap < n_win or cap > d.capacity:
                continue
            w = gather_window(d, t, tc, cap)
            tw = np.asarray(w.t)[: int(w.n_ops)]
            assert int(w.n_ops) == n_win
            assert ((tw > t) & (tw <= tc)).all(), (t, cap)
            a = reconstruct_dense(store.current, w, tc, t)
            b = reconstruct_dense(store.current, d, tc, t)
            assert bool(jnp.all(a.adj == b.adj)), (t, cap)
            assert bool(jnp.all(a.nodes == b.nodes)), (t, cap)
