"""Optimizer: AdamW vs a numpy reference; state dtypes; compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.optim import adamw_init, adamw_update, lr_schedule
from repro.optim.adamw import QTensor
from repro.optim.compress import compress_with_feedback, int8_decompress


def _np_adamw(params, grads, m, v, step, cfg, lr):
    gnorm = np.sqrt(sum((g ** 2).sum() for g in grads))
    clip = min(1.0, cfg.grad_clip / (gnorm + 1e-9))
    out_p, out_m, out_v = [], [], []
    bc1 = 1 - cfg.b1 ** step
    bc2 = 1 - cfg.b2 ** step
    for p, g, mm, vv in zip(params, grads, m, v):
        g = g * clip
        mm = cfg.b1 * mm + (1 - cfg.b1) * g
        vv = cfg.b2 * vv + (1 - cfg.b2) * g * g
        upd = (mm / bc1) / (np.sqrt(vv / bc2) + cfg.eps)
        p = p - lr * (upd + cfg.weight_decay * p)
        out_p.append(p)
        out_m.append(mm)
        out_v.append(vv)
    return out_p, out_m, out_v


def test_adamw_matches_numpy():
    rng = np.random.default_rng(0)
    cfg = TrainConfig(lr=1e-2, weight_decay=0.01)
    params = {"a": jnp.asarray(rng.standard_normal((4, 5)),
                               dtype=jnp.float32),
              "b": jnp.asarray(rng.standard_normal((3,)),
                               dtype=jnp.float32)}
    state = adamw_init(params, cfg)
    np_p = [np.asarray(params["a"]), np.asarray(params["b"])]
    np_m = [np.zeros_like(x) for x in np_p]
    np_v = [np.zeros_like(x) for x in np_p]
    for step in range(1, 5):
        grads = {"a": jnp.asarray(rng.standard_normal((4, 5)),
                                  dtype=jnp.float32),
                 "b": jnp.asarray(rng.standard_normal((3,)),
                                  dtype=jnp.float32)}
        params, state, _ = adamw_update(grads, state, params, cfg,
                                        jnp.float32(1e-2))
        np_p, np_m, np_v = _np_adamw(
            np_p, [np.asarray(grads["a"]), np.asarray(grads["b"])],
            np_m, np_v, step, cfg, 1e-2)
        assert np.allclose(np.asarray(params["a"]), np_p[0], atol=1e-5)
        assert np.allclose(np.asarray(params["b"]), np_p[1], atol=1e-5)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_state_dtypes_reduce_loss(dtype):
    """A toy regression must converge under every opt-state dtype."""
    rng = np.random.default_rng(1)
    w_true = rng.standard_normal((8, 1)).astype(np.float32)
    X = rng.standard_normal((64, 8)).astype(np.float32)
    y = X @ w_true
    cfg = TrainConfig(lr=5e-2, weight_decay=0.0, opt_state_dtype=dtype,
                      grad_clip=10.0)
    params = {"w": jnp.zeros((8, 1), jnp.float32)}
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.mean((jnp.asarray(X) @ p["w"] - jnp.asarray(y)) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg,
                                        jnp.float32(5e-2))
    l1 = float(loss(params))
    assert l1 < 0.2 * l0, (dtype, l0, l1)


def test_qtensor_roundtrip_bounded():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((32, 16)), dtype=jnp.float32)
    q = QTensor.quantize(x)
    err = float(jnp.max(jnp.abs(q.dequantize() - x)))
    assert err <= float(q.scale) * 0.5 + 1e-7


def test_schedule_warmup_and_decay():
    cfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(jnp.int32(0), cfg)) == 0.0
    assert abs(float(lr_schedule(jnp.int32(10), cfg)) - 1e-3) < 1e-9
    assert float(lr_schedule(jnp.int32(100), cfg)) < 1e-6


def test_error_feedback_unbiased():
    """Accumulated compressed grads converge to accumulated true grads
    (error feedback keeps the long-run bias at one quantization step)."""
    rng = np.random.default_rng(3)
    err = jnp.zeros((64,), jnp.float32)
    total_true = np.zeros((64,), np.float32)
    total_sent = np.zeros((64,), np.float32)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal((64,)), dtype=jnp.float32)
        q, scale, err = compress_with_feedback(g, err)
        total_true += np.asarray(g)
        total_sent += np.asarray(int8_decompress(q, scale))
    resid = np.abs(total_true - total_sent).max()
    # residual = |current error carry| ≤ one quantization bucket
    assert resid <= float(jnp.max(jnp.abs(err))) + 1e-5
