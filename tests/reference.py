"""Brute-force numpy reference for the temporal graph store: replays
the op log into per-time-unit adjacency sets.  The oracle every plan is
checked against."""
from __future__ import annotations

import numpy as np

from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE, REM_NODE


class BruteForce:
    def __init__(self, ops, n_cap: int, t_max: int):
        """ops: list of core.store.Op (time-ordered)."""
        self.n_cap = n_cap
        self.t_max = t_max
        self.snapshots = {}
        nodes = set()
        edges = set()
        by_t = {}
        for o in ops:
            by_t.setdefault(o.t, []).append(o)
        for t in range(0, t_max + 1):
            for o in by_t.get(t, []):
                if o.op == ADD_NODE:
                    nodes.add(o.u)
                elif o.op == REM_NODE:
                    nodes.discard(o.u)
                    edges = {e for e in edges if o.u not in e}
                elif o.op == ADD_EDGE:
                    edges.add((min(o.u, o.v), max(o.u, o.v)))
                elif o.op == REM_EDGE:
                    edges.discard((min(o.u, o.v), max(o.u, o.v)))
            self.snapshots[t] = (frozenset(nodes), frozenset(edges))

    def adj(self, t: int) -> np.ndarray:
        _, edges = self.snapshots[t]
        a = np.zeros((self.n_cap, self.n_cap), bool)
        for (u, v) in edges:
            a[u, v] = a[v, u] = True
        return a

    def node_mask(self, t: int) -> np.ndarray:
        nodes, _ = self.snapshots[t]
        m = np.zeros((self.n_cap,), bool)
        for n in nodes:
            m[n] = True
        return m

    def degree(self, v: int, t: int) -> int:
        return int(self.adj(t)[v].sum())

    def num_edges(self, t: int) -> int:
        return len(self.snapshots[t][1])

    def num_nodes(self, t: int) -> int:
        return len(self.snapshots[t][0])

    def degree_series(self, v: int, t_k: int, t_l: int) -> list[int]:
        return [self.degree(v, t) for t in range(t_k, t_l + 1)]
