"""Durability layer (repro/persist): WAL framing + torn-tail repair,
checkpoint/recovery roundtrips, and genuine kill -9 crash recovery via
a subprocess child (tests/persist_harness.py).

The acceptance contract (ISSUE 7): kill -9 mid-ingest or mid-swap,
reopen, and every query at t ≤ the recovered watermark bit-matches a
from-scratch store built from the same proposal stream — for dense and
edge layouts.  The recovered watermark itself must cover everything the
dead process acknowledged.
"""
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

import persist_harness as harness
from repro.core import Op, Query, TemporalGraphStore
from repro.core.delta import ADD_EDGE, ADD_NODE
from repro.persist import (WriteAheadLog, open_store, read_manifest,
                           read_records, scan, wal_name)
from repro.persist import wal as walmod

HARNESS = os.path.join(os.path.dirname(__file__), "persist_harness.py")
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _child_env():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)           # one device, like the fast lane
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _oracle(layout: str) -> TemporalGraphStore:
    """From-scratch store over the full proposal stream (the store's
    deterministic legality filtering reproduces the accepted log)."""
    ops = [o for unit in harness.proposal_units() for o in unit]
    s = TemporalGraphStore(n_cap=harness.N_CAP, layout=layout)
    s.ingest(ops)
    s.advance_to(max(o.t for o in ops))
    return s


def _grid(t_lo: int, t_hi: int) -> list[Query]:
    """A query mix over every time unit in [t_lo, t_hi]: global counts,
    node degrees, a diff range, and the vector-valued distribution."""
    qs: list[Query] = []
    for t in range(t_lo, t_hi + 1):
        qs.append(Query("point", "global", "num_edges", t_k=t))
        qs.append(Query("point", "global", "num_nodes", t_k=t))
        for v in (0, 3, 7):
            qs.append(Query("point", "node", "degree", t_k=t, v=v))
        if t > t_lo:
            qs.append(Query("diff", "node", "degree", t_k=t_lo, t_l=t, v=1))
    qs.append(Query("point", "global", "degree_distribution", t_k=t_hi))
    return qs


def _assert_bitequal(got, ref, ctx=""):
    assert len(got) == len(ref)
    for i, (g, r) in enumerate(zip(got, ref)):
        assert np.array_equal(np.asarray(g), np.asarray(r)), \
            (ctx, i, np.asarray(g), np.asarray(r))


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------


def test_wal_roundtrip_all_record_types(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    ops = [Op(ADD_NODE, 0, 0, 1), Op(ADD_NODE, 1, 1, 1),
           Op(ADD_EDGE, 0, 1, 2)]
    wal.log_ops(ops)
    wal.log_pending(ops[:1])
    wal.log_advance(7)
    wal.log_seal(5, 12, True)
    wal.log_drain(3, 9)
    cols = {c: np.arange(4, dtype=np.int32) for c in
            ("op", "u", "v", "slot", "t")}
    wal.append(walmod.encode_tail(9, 2, 5, cols))
    wal.close()

    recs = list(read_records(path))
    types = [r[0] for r in recs]
    assert types == [walmod.REC_OPS, walmod.REC_PENDING,
                     walmod.REC_ADVANCE, walmod.REC_SEAL,
                     walmod.REC_DRAIN, walmod.REC_TAIL]
    np.testing.assert_array_equal(
        recs[0][1]["rows"], [(o.op, o.u, o.v, o.t) for o in ops])
    assert recs[2][1]["t"] == 7
    assert recs[3][1] == {"t": 5, "k": 12, "force": True}
    assert recs[4][1] == {"n": 3, "target": 9}
    tail = recs[5][1]
    assert (tail["t_cur"], tail["ops_since_mat"],
            tail["t_last_mat"]) == (9, 2, 5)
    for c in ("op", "u", "v", "slot", "t"):
        np.testing.assert_array_equal(tail["cols"][c], cols[c])


def test_wal_torn_tail_is_truncated(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.log_advance(1)
    wal.log_advance(2)
    wal.close()
    with open(path, "ab") as fh:         # torn record: header + no body
        fh.write(b"\x40\x00\x00\x00\xde\xad\xbe\xef\x01\x02")
    payloads, valid = scan(path)
    assert len(payloads) == 2
    assert valid < os.path.getsize(path)
    # repair truncates, and appends extend a clean log
    wal = WriteAheadLog(path, repair=True)
    assert os.path.getsize(path) == valid
    wal.log_advance(3)
    wal.close()
    assert [r[1]["t"] for r in read_records(path)] == [1, 2, 3]


def test_wal_corrupt_crc_stops_replay(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.log_advance(1)
    wal.log_advance(2)
    wal.close()
    size = os.path.getsize(path)
    with open(path, "r+b") as fh:        # flip a byte inside record 2
        fh.seek(size - 1)
        b = fh.read(1)
        fh.seek(size - 1)
        fh.write(bytes([b[0] ^ 0xFF]))
    recs = list(read_records(path))
    assert [r[1]["t"] for r in recs] == [1]


# ---------------------------------------------------------------------------
# Checkpoint / recovery roundtrips (no crash)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["dense", "edge"])
def test_flush_close_reopen_bitexact(tmp_path, layout):
    root = str(tmp_path / "g")
    units = harness.proposal_units()
    rec = open_store(root, n_cap=harness.N_CAP, layout=layout,
                     segment_min_ops=8)
    store = rec.store
    for unit in units:
        store.ingest(unit)
        store.advance_to(unit[-1].t)
    store.seal_tail(store.t_cur)
    store.close()

    rec2 = open_store(root)
    assert rec2.pending == []
    got = rec2.store
    assert got.t_cur == store.t_cur
    assert len(got._segments) == len(store._segments)
    # sealed history comes back mmap-backed: reads page in on demand
    assert any(isinstance(np.asarray(s.op).base, np.memmap)
               for s in got._segments)
    oracle = _oracle(layout)
    qs = _grid(1, got.t_cur)
    _assert_bitequal(got.evaluate_many(qs), oracle.evaluate_many(qs),
                     ctx=layout)


def test_reopen_without_close_replays_wal(tmp_path):
    """No checkpoint at all — the fsync'd WAL alone must rebuild."""
    root = str(tmp_path / "g")
    units = harness.proposal_units()
    store = open_store(root, n_cap=harness.N_CAP, segment_min_ops=8).store
    for unit in units[:6]:
        store.ingest(unit)
        store.advance_to(unit[-1].t)
    store.seal_tail(store.t_cur)         # sealed segment + open tail
    for unit in units[6:8]:
        store.ingest(unit)
        store.advance_to(unit[-1].t)
    # ... process dies here (no flush/close)
    got = open_store(root, verify=True).store
    assert got.t_cur == store.t_cur
    oracle = _oracle("dense")
    qs = _grid(1, got.t_cur)
    _assert_bitequal(got.evaluate_many(qs), oracle.evaluate_many(qs))


def test_checkpoint_rotates_wal(tmp_path):
    root = str(tmp_path / "g")
    store = open_store(root, n_cap=16).store
    store.ingest([Op(ADD_NODE, 0, 0, 1), Op(ADD_NODE, 1, 1, 2)])
    store.advance_to(2)
    assert read_manifest(root)["wal_seq"] == 1
    store.flush()
    m = read_manifest(root)
    assert m["wal_seq"] == 2
    assert not os.path.exists(os.path.join(root, wal_name(1)))
    # post-rotation WAL replays nothing but the base record
    recs = list(read_records(os.path.join(root, wal_name(2))))
    assert [r[0] for r in recs] == [walmod.REC_TAIL]
    assert recs[0][1]["t_cur"] == 2


def test_open_config_guards(tmp_path):
    root = str(tmp_path / "g")
    with pytest.raises(ValueError, match="no manifest"):
        open_store(root)                 # fresh root needs n_cap
    store = open_store(root, n_cap=16, layout="dense").store
    store.close()
    with pytest.raises(ValueError, match="n_cap"):
        open_store(root, n_cap=32)
    with pytest.raises(ValueError, match="layout"):
        open_store(root, layout="edge")
    assert open_store(root, n_cap=16).store.n_cap == 16


def test_verify_detects_segment_corruption(tmp_path):
    root = str(tmp_path / "g")
    store = open_store(root, n_cap=16, segment_min_ops=1).store
    store.ingest([Op(ADD_NODE, i, i, i + 1) for i in range(4)])
    store.advance_to(4)
    store.seal_tail(4)
    store.close()
    seg_file = os.path.join(root, read_manifest(root)["segments"][0]["file"])
    bad = {c: np.zeros(2, np.int32) for c in ("op", "u", "v", "slot", "t")}
    from repro.persist import save_segment_file
    save_segment_file(seg_file, bad)
    # the content CRC (always enforced on the read path) trips before
    # verify's row-count cross-check ever runs
    with pytest.raises(ValueError, match="crc32 mismatch"):
        open_store(root, verify=True)
    with pytest.raises(ValueError, match="crc32 mismatch"):
        open_store(root)                 # caught without verify= too


def test_segment_crc_catches_bitflip_on_mmap_read(tmp_path):
    """A single flipped byte inside a sealed segment's data region is
    caught by the manifest CRC32 stamp on the default (mmap) read path
    — no ``verify=True`` needed, silently wrong history is never
    served."""
    from repro.persist.manifest import (SegmentCorruptError,
                                        segment_file_crc)
    root = str(tmp_path / "g")
    store = open_store(root, n_cap=16, segment_min_ops=1).store
    store.ingest([Op(ADD_NODE, i, i, i + 1) for i in range(6)])
    store.advance_to(6)
    store.seal_tail(6)
    store.close()
    entry = read_manifest(root)["segments"][0]
    seg_file = os.path.join(root, entry["file"])
    assert segment_file_crc(seg_file) == entry["crc32"]
    size = os.path.getsize(seg_file)
    with open(seg_file, "r+b") as fh:    # flip one byte past the header
        fh.seek(size - 3)
        b = fh.read(1)
        fh.seek(size - 3)
        fh.write(bytes([b[0] ^ 0x10]))
    assert segment_file_crc(seg_file) != entry["crc32"]
    with pytest.raises(SegmentCorruptError, match="crc32 mismatch"):
        open_store(root)
    with pytest.raises(SegmentCorruptError):
        open_store(root, readonly=True)


# ---------------------------------------------------------------------------
# Exhaustive torn-tail fuzz: truncations and bit flips
# ---------------------------------------------------------------------------


def _fuzz_wal_bytes(tmp_path) -> bytes:
    """A WAL holding one record of every type (realistic shapes)."""
    path = str(tmp_path / "fuzz.log")
    wal = WriteAheadLog(path)
    cols = {c: np.arange(3, dtype=np.int32)
            for c in ("op", "u", "v", "slot", "t")}
    wal.append(walmod.encode_tail(2, 1, 1, cols))
    ops = [Op(ADD_NODE, 0, 0, 3), Op(ADD_NODE, 1, 1, 3),
           Op(ADD_EDGE, 0, 1, 3)]
    wal.log_ops(ops)
    wal.log_pending(ops[:1])
    wal.log_advance(3)
    wal.log_seal(3, 6, False)
    wal.log_drain(1, 4)
    wal.close()
    with open(path, "rb") as fh:
        return fh.read()


def _frame_spans(buf: bytes) -> list[tuple[int, int]]:
    """(start, end) byte span of every intact frame, in order."""
    spans, off = [], len(walmod.MAGIC)
    for _payload, end in walmod.iter_frames(buf):
        spans.append((off, end))
        off = end
    return spans


def test_wal_truncation_fuzz_every_byte(tmp_path):
    """Replay of a log truncated at EVERY byte offset yields exactly
    the records whose frames fit whole below the cut — the exact-prefix
    contract a crash at an arbitrary write boundary relies on."""
    buf = _fuzz_wal_bytes(tmp_path)
    spans = _frame_spans(buf)
    assert len(spans) == 6               # one frame per record type
    whole = [bytes(p) for p, _ in walmod.iter_frames(buf)]
    for cut in range(len(buf) + 1):
        payloads, valid = walmod.scan_bytes(buf[:cut])
        n_fit = sum(1 for _s, e in spans if e <= cut)
        assert [bytes(p) for p in payloads] == whole[:n_fit], cut
        if n_fit:
            assert valid == spans[n_fit - 1][1]
        else:
            # nothing intact: the valid offset is just past the magic
            # (or 0 when even the magic is cut short)
            assert valid == (len(walmod.MAGIC)
                             if cut >= len(walmod.MAGIC) else 0), cut
        # every surviving payload still decodes
        for p in payloads:
            walmod.decode(p)


def test_wal_bitflip_fuzz_every_frame_region(tmp_path):
    """One flipped byte in any region of frame k — length field, CRC
    field, first/middle/last payload byte — terminates replay exactly
    at frame k: everything before survives verbatim, nothing at or past
    the flip is ever returned."""
    buf = _fuzz_wal_bytes(tmp_path)
    spans = _frame_spans(buf)
    whole = [bytes(p) for p, _ in walmod.iter_frames(buf)]
    hsz = walmod._HEADER.size
    for k, (start, end) in enumerate(spans):
        body = start + hsz
        regions = {"len_lo": start, "len_hi": start + 3,
                   "crc_lo": start + 4, "crc_hi": start + 7,
                   "payload_first": body,
                   "payload_mid": (body + end - 1) // 2,
                   "payload_last": end - 1}
        for label, pos in regions.items():
            for mask in (0x01, 0x80):
                mut = bytearray(buf)
                mut[pos] ^= mask
                payloads, valid = walmod.scan_bytes(bytes(mut))
                assert [bytes(p) for p in payloads] == whole[:k], \
                    (k, label, mask)
                assert valid == (spans[k - 1][1] if k else
                                 len(walmod.MAGIC)), (k, label, mask)
    # a mangled magic makes the whole buffer inert, not misread
    mut = bytearray(buf)
    mut[0] ^= 0x01
    assert walmod.scan_bytes(bytes(mut)) == ([], 0)


def test_store_recovers_exact_prefix_at_every_wal_cut(tmp_path):
    """Store-level torn-tail sweep: truncate a live root's WAL at every
    frame boundary (plus a mid-frame cut per frame) and reopen.  Every
    cut at or past the base record must recover a store whose history
    is an exact prefix — bit-identical to the full-stream oracle at
    every t ≤ its recovered t_cur; cuts inside the base record must
    refuse loudly (torn base), never come up with partial state."""
    import shutil
    root = str(tmp_path / "g")
    units = harness.proposal_units()
    store = open_store(root, n_cap=harness.N_CAP, segment_min_ops=8).store
    for unit in units[:5]:
        store.ingest(unit)
        store.advance_to(unit[-1].t)
    store.flush()                        # rotation: WAL = base + suffix
    for unit in units[5:8]:
        store.ingest(unit)
        store.advance_to(unit[-1].t)
    # ... process dies here (no close): the WAL is all that is new
    wal_rel = wal_name(read_manifest(root)["wal_seq"])
    with open(os.path.join(root, wal_rel), "rb") as fh:
        buf = fh.read()
    spans = _frame_spans(buf)
    assert len(spans) >= 5               # base + the streamed suffix
    oracle = _oracle("dense")
    t_full = store.t_cur

    cuts = [len(walmod.MAGIC)]           # magic only: no base record
    cuts += [(s + e) // 2 for s, e in spans]     # torn mid-frame
    cuts += [e for _s, e in spans]       # every frame boundary
    t_seen = -1
    for cut in sorted(set(cuts)):
        work = str(tmp_path / f"cut_{cut}")
        shutil.copytree(root, work)
        with open(os.path.join(work, wal_rel), "r+b") as fh:
            fh.truncate(cut)
        if cut < spans[0][1]:            # base record torn
            with pytest.raises(RuntimeError, match="torn base"):
                open_store(work)
            continue
        got = open_store(work).store
        assert got.t_cur <= t_full
        assert got.t_cur >= t_seen       # longer prefix, never regress
        t_seen = got.t_cur
        if got.t_cur >= 1:
            qs = _grid(1, got.t_cur)
            _assert_bitequal(got.evaluate_many(qs),
                             oracle.evaluate_many(qs), ctx=f"cut={cut}")
        got.close()
    assert t_seen == t_full              # the full cut IS the live state


# ---------------------------------------------------------------------------
# Offline integrity checker (scripts/fsck_graph.py)
# ---------------------------------------------------------------------------


FSCK = os.path.join(os.path.dirname(__file__), "..", "scripts",
                    "fsck_graph.py")


def _fsck(root, *flags):
    return subprocess.run([sys.executable, FSCK, str(root), *flags],
                          env=_child_env(), capture_output=True,
                          text=True, timeout=300)


def test_fsck_clean_corrupt_and_torn(tmp_path):
    root = str(tmp_path / "g")
    store = open_store(root, n_cap=harness.N_CAP, segment_min_ops=8).store
    units = harness.proposal_units()
    for unit in units[:6]:
        store.ingest(unit)
        store.advance_to(unit[-1].t)
    store.seal_tail(store.t_cur)         # at least one sealed segment
    store.flush()
    for unit in units[6:8]:
        store.ingest(unit)
        store.advance_to(unit[-1].t)

    r = _fsck(root, "--deep")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "deep recovery ok" in r.stdout

    # a torn WAL tail is crash residue: reported, never an error
    wal_path = os.path.join(root, wal_name(read_manifest(root)["wal_seq"]))
    with open(wal_path, "ab") as fh:
        fh.write(b"\x20\x00\x00\x00partial")
    r = _fsck(root)
    assert r.returncode == 0 and "torn tail" in r.stdout

    # segment corruption: per-file FAIL line + nonzero exit
    entry = read_manifest(root)["segments"][0]
    seg_path = os.path.join(root, entry["file"])
    with open(seg_path, "r+b") as fh:
        fh.seek(os.path.getsize(seg_path) - 5)
        b = fh.read(1)
        fh.seek(os.path.getsize(seg_path) - 5)
        fh.write(bytes([b[0] ^ 0x04]))
    r = _fsck(root)
    assert r.returncode == 1
    assert f"FAIL  {entry['file']}" in r.stdout
    assert "crc32 mismatch" in r.stdout

    # not a store root at all
    assert _fsck(str(tmp_path / "nowhere")).returncode == 2


# ---------------------------------------------------------------------------
# kill -9 crash recovery (subprocess)
# ---------------------------------------------------------------------------


def _run_child(root: str, layout: str, spec: str, nth: int) -> None:
    proc = subprocess.run(
        [sys.executable, HARNESS, root, layout, spec, str(nth)],
        env=_child_env(), capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGKILL, \
        (spec, proc.returncode, proc.stdout[-2000:], proc.stderr[-2000:])


def _check_recovery(root: str, layout: str) -> None:
    """Reopen a killed root and hold it to the recovery contract."""
    acked_units, acked_swaps = [], []
    with open(os.path.join(root, "acks.log")) as fh:
        for line in fh:
            kind, *rest = line.split()
            if kind == "unit":
                acked_units.append(int(rest[1]))
            else:
                acked_swaps.append(int(rest[0]))

    from repro.api import GraphSession
    oracle = _oracle(layout)
    with GraphSession.open(root) as s:
        # 1. the recovered watermark covers every watermark the dead
        #    process ever served (monotone recovery)
        w = s.watermark
        assert w >= max(acked_swaps, default=0)
        # 2. below it, bit-equality with the from-scratch oracle
        if w >= 1:
            qs = _grid(1, w)
            _assert_bitequal(s.store.evaluate_many(qs),
                             oracle.evaluate_many(qs), ctx=("pre", layout))
        # 3. the WAL'd pending buffer survived too: absorbing it must
        #    reach (at least) the last acknowledged append...
        s.flush()
        w2 = s.watermark
        assert w2 >= max(acked_units, default=0)
        # ...and stay exact
        if w2 > w:
            qs = _grid(max(1, w), w2)
            _assert_bitequal(s.store.evaluate_many(qs),
                             oracle.evaluate_many(qs), ctx=("post", layout))


KILL_CASES = [
    ("dense", "append_wal_pre", 8),
    ("dense", "append_wal_post", 8),
    ("dense", "drain_logged", 2),
    ("dense", "mid_checkpoint", 3),
    ("dense", "post_checkpoint", 2),
    ("dense", "seal_logged", 2),
    ("edge", "append_wal_post", 8),
    ("edge", "drain_logged", 2),
]


@pytest.mark.parametrize("layout,spec,nth", KILL_CASES,
                         ids=[f"{lo}-{sp}" for lo, sp, _ in KILL_CASES])
def test_kill9_recovery_bitexact(tmp_path, layout, spec, nth):
    root = str(tmp_path / "g")
    _run_child(root, layout, spec, nth)
    _check_recovery(root, layout)
    # the root stays reusable: a fresh session can keep appending
    from repro.api import GraphSession
    with GraphSession.open(root) as s:
        t = s.t_cur + 1
        assert s.ingest([Op(ADD_NODE, harness.N_CAP - 1,
                            harness.N_CAP - 1, t)]) == 1
        assert s.query("num_nodes", t=t) >= 1
