"""Live serving subsystem (repro/serving): watermark semantics, epoch
swaps, double-buffer isolation, the micro-batching frontend's exact
result cache, and workload-driven materialization.

The serving acceptance contract: with ingest interleaved, every query
at ``t ≤ t_served`` bit-matches the same query on a from-scratch store
built from the full op log, across layouts (the multi-device variant
lives in tests/test_distributed.py).
"""
import time

import numpy as np
import pytest

from repro.core import Op, Query, TemporalGraphStore
from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE, REM_NODE
from repro.core.generate import EvolutionParams, generate_ops
from repro.serving import (LiveGraphStore, MicroBatchFrontend,
                           OverloadError, PeriodicMaterializationPolicy,
                           WatermarkError, WorkloadMaterializationPolicy,
                           WorkloadStats)

N_CAP = 64


def _item(x):
    return np.asarray(x).item()


def _gen_ops(n_nodes=48, seed=7):
    return generate_ops(n_nodes, EvolutionParams(
        m_attach=3, lam_extra=1.0, lam_remove=1.0, p_remove_node=0.02,
        events_per_unit=6), seed=seed)


def _cut_at_time(ops, t_mid):
    """Split a time-ordered op list at a time-unit boundary ≥ t_mid."""
    for i, o in enumerate(ops):
        if o.t > t_mid:
            return i
    return len(ops)


def _oracle(proposals, n_cap=N_CAP, layout="dense"):
    """From-scratch store over the same proposal stream (the store
    rejects illegal transitions deterministically, so feeding the raw
    proposals reproduces the accepted log exactly)."""
    s = TemporalGraphStore(n_cap=n_cap, layout=layout)
    s.ingest(proposals)
    s.advance_to(max(o.t for o in proposals))
    return s


def _mixed_queries(tc, rng, n=12, with_distribution=True):
    qs = []
    for i in range(n):
        t1 = int(rng.integers(1, max(2, tc)))
        t2 = min(tc, t1 + int(rng.integers(0, 6)))
        v = int(rng.integers(0, N_CAP))
        kind = i % 4
        if kind == 0:
            qs.append(Query("point", "node", "degree", t_k=t1, v=v))
        elif kind == 1:
            qs.append(Query("diff", "node", "degree", t_k=t1, t_l=t2, v=v))
        elif kind == 2:
            qs.append(Query("point", "global", "num_edges", t_k=t1))
        elif with_distribution:
            qs.append(Query("point", "global", "degree_distribution",
                            t_k=t1))
        else:
            qs.append(Query("point", "global", "num_nodes", t_k=t1))
    return qs


def _assert_bitequal(got, ref, ctx=""):
    for i, (g, r) in enumerate(zip(got, ref)):
        assert np.array_equal(np.asarray(g), np.asarray(r)), \
            (ctx, i, np.asarray(g), np.asarray(r))


# ---------------------------------------------------------------------------
# Watermark semantics
# ---------------------------------------------------------------------------


def test_watermark_raise_block_serve():
    live = LiveGraphStore(n_cap=8)
    live.append([Op(ADD_NODE, 0, 0, 1), Op(ADD_NODE, 1, 1, 1),
                 Op(ADD_EDGE, 0, 1, 2)])
    q = Query("point", "node", "degree", t_k=2, v=0)
    assert live.t_served == 0 and live.pending_ops == 3
    with pytest.raises(WatermarkError):
        live.query(q)
    # "serve" answers from the frozen (empty) epoch — best effort
    assert _item(live.query(q, stale="serve")) == 0
    # "block" swaps first, then answers exactly
    assert _item(live.query(q, stale="block")) == 1
    assert live.t_served == 2 and live.pending_ops == 0
    # within-watermark queries never trip the check again
    assert _item(live.query(q)) == 1
    # the future stays unservable even after a swap empties pending
    with pytest.raises(WatermarkError):
        live.query(Query("point", "node", "degree", t_k=99, v=0),
                   stale="block")


def test_append_enforces_order_and_immutability():
    live = LiveGraphStore(n_cap=8)
    live.append([Op(ADD_NODE, 0, 0, 3)])
    with pytest.raises(ValueError, match="time-ordered"):
        live.append([Op(ADD_NODE, 1, 1, 2)])
    live.swap()
    assert live.t_served == 3
    # served history is immutable: ops at or before the watermark fail
    with pytest.raises(ValueError, match="immutable"):
        live.append([Op(ADD_NODE, 2, 2, 3)])
    assert live.append([Op(ADD_NODE, 2, 2, 4)]) == 1


def test_swap_records_and_ingest_lag():
    live = LiveGraphStore(n_cap=8)
    live.append([Op(ADD_NODE, 0, 0, 1), Op(ADD_NODE, 0, 0, 2)])  # dup
    lag = live.ingest_lag()
    assert lag["pending_ops"] == 2 and lag["t_behind"] == 2
    rec = live.swap()
    assert rec.ops_absorbed == 1 and rec.ops_rejected == 1
    assert rec.t_served == 2 and rec.seconds >= 0
    assert live.ingest_lag() == {"pending_ops": 0, "t_behind": 0,
                                 "epoch": 1}
    assert live.generation == 1 and live.swap_history == [rec]


# ---------------------------------------------------------------------------
# Double-buffering: the frozen epoch is immune to concurrent ingest
# ---------------------------------------------------------------------------


def test_frozen_epoch_isolated_from_pending_writes():
    ops = _gen_ops()
    cut = _cut_at_time(ops, ops[-1].t // 2)
    live = LiveGraphStore(n_cap=N_CAP)
    live.append(ops[:cut])
    live.swap()
    eng0 = live.engine
    w0 = live.t_served
    rng = np.random.default_rng(0)
    qs = _mixed_queries(w0, rng)
    ref = live.evaluate_many(qs)
    # writes land; the frozen epoch must not see them
    live.append(ops[cut:])
    assert live.engine is eng0 and live.t_served == w0
    _assert_bitequal(live.evaluate_many(qs), ref, "pending writes")
    # after the swap the SAME queries still return the SAME results:
    # served history is append-only
    live.swap()
    assert live.engine is not eng0 and live.t_served > w0
    _assert_bitequal(live.evaluate_many(qs), ref, "after swap")


def test_swap_async_serves_during_swap():
    ops = _gen_ops(seed=9)
    cut = _cut_at_time(ops, ops[-1].t // 2)
    live = LiveGraphStore(n_cap=N_CAP)
    live.append(ops[:cut])
    live.swap()
    w0 = live.t_served
    rng = np.random.default_rng(1)
    qs = _mixed_queries(w0, rng, n=8)
    ref = live.evaluate_many(qs)
    live.append(ops[cut:])
    th = live.swap_async()
    # the old epoch keeps serving (exactly) while the swap runs
    for _ in range(3):
        _assert_bitequal(live.evaluate_many(qs), ref, "during swap")
    th.join(timeout=60)
    assert not th.is_alive()
    assert live.t_served == ops[-1].t and live.pending_ops == 0
    _assert_bitequal(live.evaluate_many(qs), ref, "after async swap")


# ---------------------------------------------------------------------------
# Serving parity: interleaved ingest vs from-scratch store, both layouts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["dense", "edge"])
def test_interleaved_serving_matches_from_scratch(layout):
    ops = _gen_ops(seed=13)
    t_max = ops[-1].t
    cuts = [_cut_at_time(ops, t_max // 4), _cut_at_time(ops, t_max // 2),
            _cut_at_time(ops, 3 * t_max // 4), len(ops)]
    live = LiveGraphStore(n_cap=N_CAP, layout=layout)
    rng = np.random.default_rng(2)
    lo = 0
    for cut in cuts:
        if cut > lo:
            live.append(ops[lo:cut])
            lo = cut
        live.swap()
        w = live.t_served
        qs = _mixed_queries(w, rng, with_distribution=True)
        oracle = _oracle(ops[:cut], layout=layout)
        assert oracle.t_cur == w
        _assert_bitequal(live.evaluate_many(qs),
                         oracle.evaluate_many(qs),
                         (layout, "watermark", w))


# ---------------------------------------------------------------------------
# Property test: interleaved ingest/serve against the oracle
# ---------------------------------------------------------------------------

N_PROP = 12
_OP_MIX = [ADD_NODE, ADD_NODE, ADD_EDGE, ADD_EDGE, ADD_EDGE, REM_EDGE,
           REM_NODE]


def _check_interleaving(segments, layout):
    """Drive a LiveGraphStore through (ingest batch | query probe)
    events; at every watermark, results must bit-equal a from-scratch
    store replaying the proposals seen so far."""
    live = LiveGraphStore(n_cap=N_PROP, layout=layout)
    seen: list[Op] = []
    for seg, probes in segments:
        live.append(seg)
        seen.extend(seg)
        live.swap()
        w = live.t_served
        assert w == max(o.t for o in seen)
        qs = []
        for t_raw, v in probes:
            t = t_raw % (w + 1)
            qs.append(Query("point", "node", "degree", t_k=t, v=v))
            qs.append(Query("point", "global", "num_edges", t_k=t))
            qs.append(Query("point", "global", "degree_distribution",
                            t_k=t))
        oracle = _oracle(seen, n_cap=N_PROP, layout=layout)
        _assert_bitequal(live.evaluate_many(qs), oracle.evaluate_many(qs),
                         (layout, "watermark", w))


def _random_interleaving(rng):
    """Seeded fallback generator mirroring the hypothesis strategy:
    segment times strictly increase so each batch stays appendable past
    the previous watermark; ops are proposals (the store rejects the
    illegal ones identically on both sides)."""
    segments = []
    t = 0
    for _ in range(int(rng.integers(1, 5))):
        t += int(rng.integers(1, 3))
        seg = []
        for _ in range(int(rng.integers(1, 13))):
            t += int(rng.integers(0, 2))
            kind = _OP_MIX[int(rng.integers(0, len(_OP_MIX)))]
            u = int(rng.integers(0, N_PROP))
            v = int(rng.integers(0, N_PROP))
            seg.append(Op(kind, u, v if kind in (ADD_EDGE, REM_EDGE)
                          else u, t))
        probes = [(int(rng.integers(0, 200)), int(rng.integers(0, N_PROP)))
                  for _ in range(int(rng.integers(1, 4)))]
        segments.append((seg, probes))
    return segments


try:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def interleavings(draw):
        n_segments = draw(st.integers(min_value=1, max_value=4))
        t = 0
        segments = []
        for _ in range(n_segments):
            t += draw(st.integers(min_value=1, max_value=2))
            n_ops = draw(st.integers(min_value=1, max_value=12))
            seg = []
            for _ in range(n_ops):
                t += draw(st.integers(min_value=0, max_value=1))
                kind = draw(st.sampled_from(_OP_MIX))
                u = draw(st.integers(min_value=0, max_value=N_PROP - 1))
                v = draw(st.integers(min_value=0, max_value=N_PROP - 1))
                seg.append(Op(kind, u,
                              v if kind in (ADD_EDGE, REM_EDGE) else u,
                              t))
            probes = draw(st.lists(
                st.tuples(st.integers(min_value=0, max_value=200),
                          st.integers(min_value=0, max_value=N_PROP - 1)),
                min_size=1, max_size=3))
            segments.append((seg, probes))
        return segments

    @given(interleavings(), st.sampled_from(["dense", "edge"]))
    @settings(max_examples=20, deadline=None)
    def test_property_interleaved_ingest_serve_bitequal(segments, layout):
        _check_interleaving(segments, layout)

except ImportError:
    @pytest.mark.parametrize("layout", ["dense", "edge"])
    def test_property_interleaved_ingest_serve_bitequal(layout):
        """Seeded-random stand-in for the hypothesis property when
        hypothesis is unavailable (same generator shape, 8 cases)."""
        for seed in range(8):
            _check_interleaving(
                _random_interleaving(np.random.default_rng(seed)), layout)


# ---------------------------------------------------------------------------
# Micro-batching frontend
# ---------------------------------------------------------------------------


def _live_small():
    ops = _gen_ops(seed=5)
    live = LiveGraphStore(n_cap=N_CAP)
    live.append(ops)
    live.swap()
    return live


def test_frontend_coalesces_and_caches():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=16)
    tc = live.t_served
    q_hot = Query("point", "node", "degree", t_k=tc // 2, v=3)
    q_other = Query("point", "global", "num_edges", t_k=tc // 3)
    out = fe.serve([q_hot, q_hot, q_hot, q_other])
    assert out[0] == out[1] == out[2]
    # three identical submissions collapsed into one evaluation
    assert fe.stats.coalesced_dupes == 2 and fe.stats.batches == 1
    # second round is pure cache
    out2 = fe.serve([q_hot, q_other])
    assert fe.stats.cache_hits == 2 and fe.stats.batches == 1
    assert out2[0] == out[0] and out2[1] == out[3]
    # parity with the engine path
    assert out[0] == _item(live.query(q_hot))


def test_frontend_cache_invalidated_by_watermark_advance():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=8)
    tc = live.t_served
    q = Query("point", "global", "num_edges", t_k=tc)
    first = fe.serve([q])[0]
    assert fe.stats.cache_misses == 1
    # watermark advance (epoch swap) invalidates the exact cache
    live.append([Op(ADD_NODE, N_CAP - 1, N_CAP - 1, tc + 1)])
    live.swap()
    second = fe.serve([q])[0]
    assert fe.stats.cache_misses == 2 and fe.stats.cache_hits == 0
    # the query time is within both watermarks — history immutable
    assert first == second


def test_frontend_full_queue_autodrains():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=4)
    tc = live.t_served
    futs = [fe.submit(Query("point", "node", "degree", t_k=1 + i % tc,
                            v=i))
            for i in range(4)]
    # 4th submit hit max_batch → drained inline without flush()
    assert all(f.done() for f in futs)
    assert fe.stats.batches == 1 and fe.stats.max_batch_seen == 4


def test_frontend_threaded_deadline_drain():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=64, max_delay_ms=5.0).start()
    try:
        tc = live.t_served
        futs = [fe.submit(Query("point", "node", "degree",
                                t_k=1 + i % tc, v=i)) for i in range(5)]
        # the deadline, not the batch size, must trigger the dispatch
        for f in futs:
            f.result(timeout=30)
        assert fe.stats.batches >= 1
    finally:
        fe.stop()


def test_frontend_does_not_cache_past_watermark():
    live = _live_small()
    tc = live.t_served
    fe = MicroBatchFrontend(live, max_batch=8, stale="serve")
    q_future = Query("point", "global", "num_edges", t_k=tc + 5)
    fe.serve([q_future])
    fe.serve([q_future])
    # best-effort answers are re-evaluated, never cached
    assert fe.stats.cache_hits == 0 and fe.stats.batches == 2


def test_frontend_surfaces_watermark_errors():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=8)  # stale="raise"
    fut = fe.submit(Query("point", "global", "num_edges",
                          t_k=live.t_served + 5))
    fe.flush()
    with pytest.raises(WatermarkError):
        fut.result(timeout=30)


# ---------------------------------------------------------------------------
# Frontend backpressure
# ---------------------------------------------------------------------------


def _distinct_queries(live, n):
    tc = live.t_served
    return [Query("point", "node", "degree", t_k=1 + i % tc, v=i)
            for i in range(n)]


def test_frontend_overload_raises_at_max_pending():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=64, max_pending=3)
    qs = _distinct_queries(live, 4)
    futs = [fe.submit(q) for q in qs[:3]]
    # the 4th submit finds the queue at its bound: explicit rejection,
    # nothing enqueued, nothing already queued disturbed
    with pytest.raises(OverloadError):
        fe.submit(qs[3])
    assert fe.stats.rejected == 1 and fe.stats.max_pending_seen == 3
    fe.flush()
    assert all(f.done() for f in futs)
    # space freed: the same query is admitted now
    fut = fe.submit(qs[3])
    fe.flush()
    assert fut.result(timeout=5) is not None
    assert fe.stats.rejected == 1


def test_frontend_overload_raise_cache_hit_is_never_rejected():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=64, max_pending=2)
    q = Query("point", "global", "num_edges", t_k=live.t_served)
    fe.serve([q])                        # warm the exact cache
    for fill in _distinct_queries(live, 2):
        fe.submit(fill)                  # saturate the queue
    # a hit resolves from the cache without touching the queue
    assert fe.submit(q).result(timeout=1) is not None
    assert fe.stats.rejected == 0
    fe.flush()


def test_frontend_overload_block_paces_producers():
    import threading as th
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=2, max_delay_ms=1.0,
                            max_pending=2, overload="block").start()
    try:
        qs = _distinct_queries(live, 8)
        futs = []
        done = th.Event()

        def producer():
            for q in qs:                 # blocks whenever queue is full
                futs.append(fe.submit(q))
            done.set()

        th.Thread(target=producer, daemon=True).start()
        assert done.wait(timeout=30)     # drain thread kept it moving
        for f in futs:
            f.result(timeout=30)
        assert fe.stats.rejected == 0
        assert fe.stats.max_pending_seen <= 2   # the bound really held
        assert fe.stats.served == len(qs)
    finally:
        fe.stop()


def test_frontend_sheds_aged_requests_at_dispatch():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=64, shed_after_ms=5.0)
    qs = _distinct_queries(live, 3)
    stale_fut = fe.submit(qs[0])
    time.sleep(0.03)                     # ages past shed_after_ms
    fresh_futs = [fe.submit(q) for q in qs[1:]]
    fe.flush()
    with pytest.raises(OverloadError):
        stale_fut.result(timeout=5)
    for f in fresh_futs:                 # fresh ones still served
        assert f.result(timeout=5) is not None
    assert fe.stats.shed == 1
    assert fe.stats.served == 2


def test_frontend_shed_entire_batch_returns_progress():
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=64, shed_after_ms=1.0)
    futs = [fe.submit(q) for q in _distinct_queries(live, 3)]
    time.sleep(0.02)
    assert fe.flush() == 3               # progress counted, not looped
    for f in futs:
        with pytest.raises(OverloadError):
            f.result(timeout=5)
    assert fe.stats.shed == 3 and fe.stats.served == 0


# ---------------------------------------------------------------------------
# Workload-driven materialization
# ---------------------------------------------------------------------------


def _stats_at(times):
    s = WorkloadStats()
    s.record(times)
    return s


def test_workload_policy_places_hot_anchor_under_budget():
    ops = _gen_ops(seed=3)
    pol = WorkloadMaterializationPolicy(budget_bytes=1 << 20,
                                        min_gap_ops=64)
    live = LiveGraphStore(n_cap=N_CAP, policy=pol)
    live.append(ops)
    live.swap()
    tc = live.t_served
    rng = np.random.default_rng(0)
    hot = tc // 3
    for _ in range(3):
        qs = [Query("point", "node", "degree",
                    t_k=int(np.clip(hot + rng.integers(-2, 3), 1, tc)),
                    v=int(rng.integers(0, N_CAP)))
              for _ in range(24)]
        live.evaluate_many(qs)
        live.append([Op(ADD_NODE, 0, 0, live.t_served + 1)])
        rec = live.swap()
    times = live.store.materialized.times
    assert times, "hot band should be materialized"
    from repro.core.engine import _snapshot_bytes
    assert (len(times) * _snapshot_bytes(live.store.current)
            <= pol.budget_bytes)
    # the planner now anchors hot-band queries at the new snapshot
    choice = live.engine.plan(Query("point", "node", "degree", t_k=hot,
                                    v=5))
    assert choice.anchor_id != -1
    assert rec.epoch == live.epoch


def test_workload_policy_evicts_cold_anchor_when_workload_moves():
    ops = _gen_ops(seed=4)
    pol = WorkloadMaterializationPolicy(budget_bytes=1 << 20,
                                        min_gap_ops=32, decay=0.0)
    live = LiveGraphStore(n_cap=N_CAP, policy=pol)
    live.append(ops)
    live.swap()
    tc = live.t_served
    for hot in (tc // 4, 3 * tc // 4):
        for _ in range(2):
            live.evaluate_many(
                [Query("point", "node", "degree", t_k=hot, v=v)
                 for v in range(16)])
            live.append([Op(ADD_NODE, 0, 0, live.t_served + 1)])
            live.swap()
    times = live.store.materialized.times
    evicted = [t for r in live.swap_history for t in r.anchors_evicted]
    # the first hot band went cold (decay=0) and was evicted
    assert evicted and all(abs(t - tc // 4) < abs(t - 3 * tc // 4)
                           for t in evicted)
    assert times and min(abs(t - 3 * tc // 4) for t in times) <= 2


def test_workload_policy_plan_respects_budget_and_gap():
    t_sorted = np.repeat(np.arange(100), 10)  # 10 ops per time unit
    pol = WorkloadMaterializationPolicy(budget_bytes=2000, min_gap_ops=100)
    stats = _stats_at([20] * 50 + [22] * 40 + [60] * 30 + [61] * 20)
    res = pol.plan(stats=stats, existing=[], t_sorted=t_sorted, t_cur=99,
                   bytes_per_snapshot=1000)
    assert res.budget_snapshots == 2
    assert res.added == [20, 60]  # hottest two, gap-separated
    # 22 is within min_gap_ops of 20 → not a second target
    assert 22 not in res.targets
    # an existing anchor near a target is kept, the target covered
    res2 = pol.plan(stats=stats, existing=[21], t_sorted=t_sorted,
                    t_cur=99, bytes_per_snapshot=1000)
    assert 21 in res2.kept and res2.added == [60]
    # no observed workload → budget still enforced, nothing added
    res3 = pol.plan(stats=WorkloadStats(), existing=[5, 50, 90],
                    t_sorted=t_sorted, t_cur=99, bytes_per_snapshot=1000)
    assert res3.added == [] and len(res3.evicted) == 1


def test_periodic_policy_baseline_protocol():
    ops = _gen_ops(seed=6)
    pol = PeriodicMaterializationPolicy(period=8, budget_bytes=1 << 20)
    live = LiveGraphStore(n_cap=N_CAP, policy=pol)
    live.append(ops)
    live.swap()
    times = live.store.materialized.times
    assert times and all(t % 8 == 0 for t in times)
    from repro.core.engine import _snapshot_bytes
    assert (len(times) * _snapshot_bytes(live.store.current)
            <= pol.budget_bytes)


def test_delta_cap_hint_keeps_shapes_stable():
    """delta_cap_hint pre-sizes the device log so the frozen delta
    keeps one capacity across epochs (no steady-state recompiles)."""
    live = LiveGraphStore(n_cap=16, delta_cap_hint=100)   # → pow2 128
    live.append([Op(ADD_NODE, i, i, 1) for i in range(8)])
    live.swap()
    assert live.engine.delta.capacity == 128
    live.append([Op(ADD_EDGE, 0, 1, 2), Op(ADD_EDGE, 1, 2, 3)])
    live.swap()
    assert live.engine.delta.capacity == 128
    # parity unaffected by padding
    assert _item(live.query(Query("point", "global", "num_edges",
                                  t_k=2))) == 1


def test_epoch_swaps_share_sealed_segments():
    """Successive frozen epochs hold the sealed history by reference:
    a swap seals + converts ONLY the epoch's tail, so earlier
    segments' device arrays are shared, not rebuilt (the O(epoch-ops)
    swap contract of the segmented delta log)."""
    ops = _gen_ops(seed=9)
    t_max = ops[-1].t
    cuts = [_cut_at_time(ops, t_max // 3), _cut_at_time(ops, 2 * t_max // 3),
            len(ops)]
    store = TemporalGraphStore(n_cap=N_CAP, segment_min_ops=4)
    live = LiveGraphStore(store=store)
    views, lo = [], 0
    for cut in cuts:
        live.append(ops[lo:cut])
        lo = cut
        live.swap()
        views.append(live.engine.view)
    assert len(views[-1].segments) > len(views[-2].segments)
    for a, b in zip(views[-2].segments, views[-1].segments):
        assert a is b and a.delta is b.delta   # shared device arrays
    # and the shared state still serves exactly
    rng = np.random.default_rng(4)
    qs = _mixed_queries(live.t_served, rng, n=8)
    _assert_bitequal(live.evaluate_many(qs),
                     _oracle(ops).evaluate_many(qs), "segment sharing")


def test_segment_device_budget_spills_cold_segments():
    """The host-residency knob: under a byte budget the swap spills
    cold sealed segments off-device; queries into spilled history
    still answer exactly (reload on demand)."""
    ops = _gen_ops(seed=10)
    t_max = ops[-1].t
    store = TemporalGraphStore(n_cap=N_CAP, segment_min_ops=2)
    live = LiveGraphStore(store=store, segment_device_budget=1)
    lo = 0
    for t_mid in (t_max // 3, 2 * t_max // 3, t_max):
        cut = _cut_at_time(ops, t_mid)
        if cut > lo:
            live.append(ops[lo:cut])
            lo = cut
        live.swap()
    view = live.engine.view
    assert len(view.segments) >= 3
    # the budget (1 byte) can keep nothing resident except the two
    # protected hot segments (the freshly sealed epoch and, when
    # future-dated ops left one, the volatile tail)
    assert not any(s.is_resident for s in view.segments[:-2])
    assert view.segments[-1].is_resident
    rng = np.random.default_rng(5)
    qs = _mixed_queries(live.t_served, rng, n=8)
    _assert_bitequal(live.evaluate_many(qs),
                     _oracle(ops[:lo]).evaluate_many(qs), "spilled serve")


def test_group_pad_min_bounds_shapes_and_keeps_parity():
    """group_pad_min pads fragmented groups to one program shape;
    results stay bit-identical to the unpadded executor."""
    ops = _gen_ops(seed=8)
    live_pad = LiveGraphStore(n_cap=N_CAP, group_pad_min=8)
    live_ref = LiveGraphStore(n_cap=N_CAP)
    for lv in (live_pad, live_ref):
        lv.append(ops)
        lv.swap()
    rng = np.random.default_rng(3)
    qs = _mixed_queries(live_pad.t_served, rng, n=5)
    _assert_bitequal(live_pad.evaluate_many(qs),
                     live_ref.evaluate_many(qs), "group_pad_min")
    assert live_pad.engine.group_pad_min == 8


def test_segment_budget_rejects_monolithic_store():
    """A residency budget on a monolithic store would be a silent
    no-op (the full log stays device-resident); fail loudly instead."""
    with pytest.raises(ValueError, match="segmented"):
        LiveGraphStore(store=TemporalGraphStore(8, segmented=False),
                       segment_device_budget=1 << 20)


def test_edge_layout_rejects_materialization_policy():
    with pytest.raises(ValueError, match="dense layout"):
        LiveGraphStore(n_cap=8, layout="edge",
                       policy=WorkloadMaterializationPolicy())


def test_append_at_swap_closing_time_rejected_mid_swap():
    """Race regression: between a swap's buffer drain and its engine
    flip, the old engine's watermark still reads low — but the swap
    has already claimed its closing time, so an append AT that time
    (which would be logged yet never applied to the advanced current
    snapshot) must be rejected, and parity must survive."""
    live = LiveGraphStore(n_cap=8)
    live.append([Op(ADD_NODE, 0, 0, 10), Op(ADD_NODE, 1, 1, 10)])
    orig_ingest = live.store.ingest
    raced = {}

    def mid_swap_ingest(ops_):
        n = orig_ingest(ops_)
        # a concurrent client appends at the unit the swap is closing
        try:
            live.append([Op(ADD_NODE, 2, 2, 10)])
            raced["accepted"] = True
        except ValueError:
            raced["accepted"] = False
        return n

    live.store.ingest = mid_swap_ingest
    try:
        live.swap()
    finally:
        live.store.ingest = orig_ingest
    assert raced == {"accepted": False}
    # exactness holds: num_nodes at the watermark matches the oracle
    got = _item(live.query(Query("point", "global", "num_nodes",
                                 t_k=10)))
    oracle = _oracle([Op(ADD_NODE, 0, 0, 10), Op(ADD_NODE, 1, 1, 10)],
                     n_cap=8)
    assert got == _item(oracle.query(Query("point", "global",
                                           "num_nodes", t_k=10))) == 2


def test_frontend_late_query_does_not_poison_batch():
    """One past-watermark request must fail alone; the coalesced
    within-watermark requests in the same batch still get answers."""
    live = _live_small()
    fe = MicroBatchFrontend(live, max_batch=8)  # stale="raise"
    tc = live.t_served
    good = [fe.submit(Query("point", "node", "degree", t_k=tc // 2, v=v))
            for v in range(3)]
    bad = fe.submit(Query("point", "global", "num_edges", t_k=tc + 7))
    fe.flush()
    with pytest.raises(WatermarkError):
        bad.result(timeout=30)
    ref = live.query(Query("point", "node", "degree", t_k=tc // 2, v=0))
    assert good[0].result(timeout=30) == _item(ref)
    assert all(f.done() and f.exception() is None for f in good)


def test_group_pad_min_applies_to_sharded_groups():
    """The shape-stability floor must hold in the sharded branches too
    (mode batch/rows/slots), not just single-device dispatch."""
    from repro.core.engine import _pow2
    ops = _gen_ops(seed=8)
    live = LiveGraphStore(n_cap=N_CAP, group_pad_min=16)
    live.append(ops)
    live.swap()
    eng = live.engine
    # single-device floor
    qs = [Query("point", "global", "num_edges", t_k=live.t_served // 2)]
    r, = eng.evaluate_many(qs)
    (key, b, mode), = eng.last_group_stats
    assert b == 1 and mode is None
    # _run_group returns the padded device array: a 1-query group must
    # come back at the 16-wide floor shape
    out = eng._run_group(key, qs)
    assert out.shape[0] == _pow2(eng.group_pad_min) == 16
    assert _item(out[0]) == _item(r)
