"""Runtime: training convergence, failure recovery, stragglers, data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (ShardingConfig, TrainConfig, reduced)
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.runtime import (FailureInjector, StragglerPolicy,
                           init_train_state, make_train_step)
from repro.runtime.stragglers import StragglerPolicy


def test_data_deterministic_and_resumable():
    cfg = reduced(get_config("smollm-360m"))
    d1 = SyntheticLM(cfg, 4, 32, seed=3)
    d2 = SyntheticLM(cfg, 4, 32, seed=3)
    b1 = d1.batch_at(17)
    b2 = d2.batch_at(17)  # fresh pipeline, same step -> same batch
    assert np.array_equal(np.asarray(b1["tokens"]),
                          np.asarray(b2["tokens"]))
    b3 = d1.batch_at(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_loss_decreases_tiny_model():
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128,
                  vocab=128)
    tcfg = TrainConfig(global_batch=8, seq_len=64, lr=3e-3,
                       total_steps=40, warmup_steps=4,
                       param_dtype="float32")
    data = SyntheticLM(cfg, tcfg.global_batch, tcfg.seq_len, seed=0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg, ShardingConfig()))
    losses = []
    for i in range(tcfg.total_steps):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_microbatching_matches_full_batch():
    """Gradient accumulation must equal the single-batch gradient step
    (same data, same init)."""
    cfg = reduced(get_config("smollm-360m"), n_layers=1, d_model=64,
                  n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128,
                  vocab=64)
    data = SyntheticLM(cfg, 8, 32, seed=1)
    batch = data.batch_at(0)
    outs = {}
    for mb in (1, 4):
        tcfg = TrainConfig(global_batch=8, seq_len=32, lr=1e-3,
                           microbatches=mb, param_dtype="float32")
        state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg, ShardingConfig()))
        s2, m = step(state, batch)
        outs[mb] = (np.asarray(jax.device_get(s2.params["embed"]["tok"])),
                    float(m["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-4
    assert np.allclose(outs[1][0], outs[4][0], atol=1e-4)


def test_failure_recovery_end_to_end(tmp_path):
    """Inject failures mid-run; training must resume from the delta
    checkpoint store and reach the same final step."""
    from repro.launch.train import train
    from repro.checkpoint import DeltaPolicy
    cfg = reduced(get_config("smollm-360m"), n_layers=1, d_model=64,
                  n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128,
                  vocab=128)
    tcfg = TrainConfig(global_batch=4, seq_len=32, lr=1e-3,
                       total_steps=25, warmup_steps=2,
                       param_dtype="float32")
    inj = FailureInjector(fail_at=(8, 17))
    state, history, store = train(
        cfg, tcfg, ShardingConfig(), ckpt_dir=str(tmp_path),
        ckpt_every=5, policy=DeltaPolicy(period=2), injector=inj,
        log_every=1)
    assert int(jax.device_get(state.step)) == tcfg.total_steps
    assert store.latest_step() == tcfg.total_steps - 1
    # recovery actually used the checkpoint: failures consumed
    assert not inj._pending


def test_recovered_state_bit_exact(tmp_path):
    """The state after recovery equals the state of an uninterrupted
    run at the same step count (determinism across restarts)."""
    from repro.launch.train import train
    cfg = reduced(get_config("smollm-360m"), n_layers=1, d_model=64,
                  n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128,
                  vocab=128)
    tcfg = TrainConfig(global_batch=4, seq_len=32, lr=1e-3,
                       total_steps=12, warmup_steps=2,
                       param_dtype="float32")
    s_clean, _, _ = train(cfg, tcfg, ShardingConfig())
    inj = FailureInjector(fail_at=(6,))
    s_fail, _, _ = train(cfg, tcfg, ShardingConfig(),
                         ckpt_dir=str(tmp_path), ckpt_every=1,
                         injector=inj, log_every=100)
    for a, b in zip(jax.tree.leaves(s_clean.params),
                    jax.tree.leaves(s_fail.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_straggler_policy_sheds_and_restores():
    pol = StragglerPolicy(deadline_ms=100.0, restore_after=3)
    mb = 8
    # slow steps -> shed
    for _ in range(3):
        mb = pol.observe(500.0, mb)
    assert mb < 8
    shed = mb
    # healthy steps -> gradual restore (EWMA must decay below the
    # deadline first, then one doubling per `restore_after` window)
    for _ in range(40):
        mb = pol.observe(10.0, mb)
    assert mb >= 8 > shed


def test_elastic_reshard_preserves_values(tmp_path):
    """Save on one 'mesh', restore + reshard onto another device count
    (1 device here — the point is the logical path works and values
    survive)."""
    from repro.checkpoint import DeltaCheckpointStore
    from repro.runtime import reshard_from_checkpoint
    from jax.sharding import Mesh
    cfg = reduced(get_config("smollm-360m"), n_layers=1, d_model=64,
                  n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128,
                  vocab=64)
    tcfg = TrainConfig(param_dtype="float32")
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    store = DeltaCheckpointStore(str(tmp_path))
    store.save(0, state)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1),
                ("data", "model"))
    template = jax.eval_shape(lambda: state)
    back = reshard_from_checkpoint(store, 0, template, mesh)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(back.params)):
        assert np.array_equal(np.asarray(jax.device_get(a)),
                              np.asarray(jax.device_get(b)))
