"""Observability layer (repro/obs): registry, tracing, slow queries.

The acceptance contract (ISSUE 9):

* ``MetricsRegistry`` survives concurrent hammering with exact totals
  (counters monotonic, histograms count-consistent, parent aggregation
  lossless across leaf registries);
* tracing that is OFF costs nothing — ``trace_span`` returns one
  shared no-op singleton (identity-pinned here);
* one routed query through ``GraphSession`` → ``QueryRouter`` →
  ``ReadReplica`` produces a Chrome-trace timeline whose plan /
  dispatch spans nest (by time containment) inside the query span, and
  ``session.metrics()`` carries ``wal_fsync_seconds``,
  ``serving_swap_phase_seconds`` and ``router_replica_lag``;
* the slow-query log attributes slow calls to their engine groups;
* ``WorkloadStats`` is bounded (``max_times``) and its activity level
  decays at rollover instead of growing forever.
"""
import json
import threading

import pytest

from repro.obs.metrics import (COUNT_BUCKETS, MetricsRegistry,
                               NullRegistry, timed)
from repro.obs.trace import (NULL_SPAN, Tracer, active_tracer,
                             install_tracer, trace_span,
                             uninstall_tracer)
from repro.obs.slowlog import SlowQueryLog
from repro.serving.policy import WorkloadStats


@pytest.fixture(autouse=True)
def _no_tracer_leaks():
    """Every test starts and ends with the process-wide tracer slot
    empty (a leaked tracer would silently record other tests)."""
    uninstall_tracer()
    yield
    uninstall_tracer()


# ---------------------------------------------------------------------------
# registry basics
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(4)
    assert c.value == 5

    g = reg.gauge("g", "a gauge")
    g.set(7)
    g.inc(3)
    g.dec()
    assert g.value == 9
    g.set_max(4)
    assert g.value == 9          # set_max never lowers
    g.set_max(20)
    assert g.value == 20

    h = reg.histogram("h_seconds", "a histogram")
    for v in (1e-4, 2e-4, 3e-4, 1e-1):
        h.observe(v)
    assert h.count == 4
    assert abs(h.sum - 0.1006) < 1e-9
    assert h.min == 1e-4 and h.max == 1e-1
    assert 0 < h.quantile(0.5) < 1e-2


def test_same_series_is_same_child():
    reg = MetricsRegistry()
    assert reg.counter("x_total") is reg.counter("x_total")
    assert (reg.counter("lbl_total", phase="a")
            is not reg.counter("lbl_total", phase="b"))


def test_snapshot_shape_and_labels():
    reg = MetricsRegistry()
    reg.counter("ops_total", "ops", kind="read").inc(3)
    reg.counter("ops_total", "ops", kind="write").inc(1)
    reg.gauge("depth").set(5)
    reg.histogram("lat_seconds").observe(0.25)
    snap = reg.snapshot()
    assert snap["counters"]["ops_total"] == {"kind=read": 3,
                                             "kind=write": 1}
    assert snap["gauges"]["depth"] == {"": 5}
    st = snap["histograms"]["lat_seconds"][""]
    assert st["count"] == 1 and st["sum"] == 0.25
    # bucket list pairs (upper_bound, count) ending at +Inf
    assert st["buckets"][-1][0] == "+Inf"
    assert sum(n for _, n in st["buckets"]) == 1
    assert json.loads(json.dumps(snap)) == snap    # JSON-able


def test_parent_aggregation_is_lossless_and_leaf_exact():
    parent = MetricsRegistry()
    leaf_a = MetricsRegistry(parent=parent)
    leaf_b = MetricsRegistry(parent=parent)
    leaf_a.counter("served_total").inc(10)
    leaf_b.counter("served_total").inc(32)
    assert leaf_a.counter("served_total").value == 10
    assert leaf_b.counter("served_total").value == 32
    assert parent.counter("served_total").value == 42
    leaf_a.histogram("wait_seconds").observe(0.5)
    leaf_b.histogram("wait_seconds").observe(1.5)
    assert parent.histogram("wait_seconds").count == 2
    assert parent.histogram("wait_seconds").sum == 2.0


def test_null_registry_is_a_noop():
    reg = NullRegistry()
    c = reg.counter("anything_total")
    c.inc(1000)
    assert c.value == 0
    reg.histogram("h").observe(1.0)
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_reset_orphans_held_children():
    reg = MetricsRegistry()
    old = reg.counter("n_total")
    old.inc(5)
    reg.reset()
    old.inc(100)                  # keeps working, lands nowhere
    fresh = reg.counter("n_total")
    assert fresh.value == 0
    fresh.inc(2)
    assert reg.snapshot()["counters"]["n_total"][""] == 2


def test_prometheus_exposition_parses():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", route="a").inc(3)
    reg.gauge("up", "1 when serving").set(1)
    h = reg.histogram("lat_seconds", "latency")
    h.observe(1e-3)
    h.observe(2.0)
    text = reg.render_prometheus()
    typed, samples = {}, []
    for line in text.splitlines():
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split()
            typed[name] = kind
        elif line and not line.startswith("#"):
            name_part, _, value = line.rpartition(" ")
            samples.append((name_part, float(value)))
    assert typed == {"req_total": "counter", "up": "gauge",
                     "lat_seconds": "histogram"}
    as_dict = dict(samples)
    assert as_dict['req_total{route="a"}'] == 3.0
    assert as_dict["up"] == 1.0
    assert as_dict["lat_seconds_count"] == 2.0
    assert as_dict["lat_seconds_sum"] == 2.001
    # cumulative bucket counts are monotone and end at the total
    buckets = [v for k, v in samples if k.startswith("lat_seconds_bucket")]
    assert buckets == sorted(buckets) and buckets[-1] == 2.0


# ---------------------------------------------------------------------------
# concurrency: the hammer tests
# ---------------------------------------------------------------------------

def _hammer(fn, n_threads=4, n_iter=5000):
    errs = []

    def run():
        try:
            for i in range(n_iter):
                fn(i)
        except Exception as exc:              # noqa: BLE001
            errs.append(exc)

    ts = [threading.Thread(target=run) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs, errs


def test_concurrent_counter_and_histogram_exact():
    reg = MetricsRegistry()
    c = reg.counter("hits_total")
    h = reg.histogram("obs", buckets=COUNT_BUCKETS)
    g = reg.gauge("hiwater")

    def op(i):
        c.inc()
        h.observe(i % 7)
        g.set_max(i)

    _hammer(op, n_threads=4, n_iter=5000)
    assert c.value == 4 * 5000
    assert h.count == 4 * 5000
    assert sum(i % 7 for i in range(5000)) * 4 == h.sum
    assert g.value == 4999


def test_concurrent_leaf_registries_aggregate_exact():
    parent = MetricsRegistry()
    leaves = [MetricsRegistry(parent=parent) for _ in range(4)]
    counters = [leaf.counter("work_total") for leaf in leaves]
    barrier = threading.Barrier(4)

    def run(k):
        barrier.wait()
        for _ in range(3000):
            counters[k].inc()

    ts = [threading.Thread(target=run, args=(k,)) for k in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert [c.value for c in counters] == [3000] * 4
    assert parent.counter("work_total").value == 12000


def test_concurrent_label_family_creation():
    """Racing first-touch of the same labeled series must converge on
    one child (no lost family / duplicate children)."""
    reg = MetricsRegistry()

    def op(i):
        reg.counter("lbl_total", shard=str(i % 3)).inc()

    _hammer(op, n_threads=4, n_iter=3000)
    snap = reg.snapshot()["counters"]["lbl_total"]
    assert sum(snap.values()) == 4 * 3000
    assert set(snap) == {"shard=0", "shard=1", "shard=2"}


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_disabled_tracing_returns_the_null_span_singleton():
    assert active_tracer() is None
    assert trace_span("anything") is NULL_SPAN
    assert trace_span("other", a=1) is NULL_SPAN     # no allocation
    with trace_span("still-off") as sp:
        sp.set(x=2)                                  # all no-ops


def test_tracer_records_spans_with_attrs():
    tr = install_tracer(Tracer())
    with trace_span("outer", a=1) as sp:
        sp.set(b=2)
        with trace_span("inner"):
            pass
    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    outer = evs[1]
    assert outer["args"] == {"a": 1, "b": 2}
    assert outer["dur"] >= evs[0]["dur"] >= 0


def test_tracer_ring_is_bounded_and_seq_monotonic():
    tr = install_tracer(Tracer(capacity=4))
    for i in range(10):
        with trace_span(f"s{i}"):
            pass
    evs = tr.events()
    assert len(evs) == 4
    assert tr.seq == 10
    assert [e["name"] for e in evs] == ["s6", "s7", "s8", "s9"]
    assert [e for e in tr.events_since(8)] == evs[-2:]


def test_span_exception_is_annotated():
    tr = install_tracer(Tracer())
    with pytest.raises(ValueError):
        with trace_span("boom"):
            raise ValueError("x")
    assert tr.events()[-1]["args"]["error"] == "ValueError"


def test_chrome_trace_dump(tmp_path):
    tr = install_tracer(Tracer())
    with trace_span("phase", k="v"):
        pass
    path = tr.dump(str(tmp_path / "trace.json"))
    loaded = json.load(open(path))
    assert loaded["displayTimeUnit"] == "ms"
    ev = loaded["traceEvents"][0]
    for key in ("name", "ph", "pid", "tid", "ts", "dur", "args"):
        assert key in ev
    assert ev["ph"] == "X" and ev["name"] == "phase"


def test_uninstall_only_removes_its_own_tracer():
    a = install_tracer(Tracer())
    b = install_tracer(Tracer())
    uninstall_tracer(a)                  # a is not active: no-op
    assert active_tracer() is b
    uninstall_tracer(b)
    assert active_tracer() is None


def test_timed_feeds_histogram_and_span():
    reg = MetricsRegistry()
    h = reg.histogram("op_seconds")
    tr = install_tracer(Tracer())
    with timed(h, "op", kind="t") as tm:
        pass
    assert h.count == 1 and tm.seconds >= 0.0
    ev = tr.events()[-1]
    assert ev["name"] == "op" and ev["args"] == {"kind": "t"}


# ---------------------------------------------------------------------------
# slow-query log + workload stats bounds
# ---------------------------------------------------------------------------

def test_slow_query_log_threshold_and_bound():
    log = SlowQueryLog(threshold_ms=10.0, capacity=3)
    built = []

    def entry():
        built.append(1)
        return {"n_queries": 1}

    assert not log.record(0.001, entry)      # fast: builder never runs
    assert built == []
    for _ in range(5):
        assert log.record(0.5, entry)
    assert len(log.entries()) == 3           # ring bound
    assert log.recorded == 5
    assert all(e["seconds"] == 0.5 for e in log.entries())


def test_workload_stats_bounded_by_max_times():
    ws = WorkloadStats(max_times=64)
    ws.record(range(1000))
    hist = ws.histogram()
    assert len(hist) <= 64
    # total tracks exactly the surviving mass
    assert abs(ws.total - sum(hist.values())) < 1e-9
    # the heaviest times survive pruning
    ws.record([5] * 50)
    ws.record(range(2000, 3000))
    assert 5 in ws.histogram()


def test_workload_stats_activity_decays_at_rollover():
    ws = WorkloadStats()

    class _Q:
        kind, t_k, t_l = "point", 3, None

    ws.record_queries([_Q(), _Q()])
    assert ws.queries_recorded == 2
    ws.rollover(0.5)
    assert ws.queries_recorded == 1.0
    for _ in range(100):
        ws.rollover(0.5)
    assert ws.queries_recorded < 1e-9        # never grows unbounded


# ---------------------------------------------------------------------------
# end-to-end: session metrics, slow queries, routed-query trace
# ---------------------------------------------------------------------------

def _ops(n_cap, units, t0=1):
    from repro.core import ADD_EDGE, ADD_NODE
    ops = [(ADD_NODE, v, v, t0) for v in range(n_cap)]
    t = t0
    for u in range(units):
        t += 1
        ops.append((ADD_EDGE, u % n_cap, (u + 1) % n_cap, t))
    return ops, t


def test_session_slow_query_log_carries_plan_attribution():
    from repro.api import GraphSession
    from repro.core import Query
    reg = MetricsRegistry()
    with GraphSession(n_cap=8, metrics=reg, slow_query_ms=0.0) as sess:
        ops, t = _ops(8, 12)
        sess.ingest(ops)
        sess.flush()
        sess.query(Query(kind="point", scope="node", measure="degree",
                         t_k=t // 2, v=1))
        entries = sess.slow_queries()
        assert entries, "0ms threshold must record every call"
        e = entries[-1]
        assert e["n_queries"] == 1 and e["seconds"] > 0
        (group,) = e["groups"]
        assert group["measure"] == "degree" and group["batch"] == 1
        assert group["plan"] in ("two_phase", "hybrid", "delta_only")
    # counters moved too
    snap = reg.snapshot()["counters"]
    assert sum(snap["engine_slow_queries_total"].values()) >= 1


def test_acceptance_routed_query_trace_and_session_metrics(tmp_path):
    """ISSUE 9 acceptance: one routed query through GraphSession →
    QueryRouter → replica yields a Chrome trace whose plan/dispatch
    spans nest inside the query span, and the shared registry exposes
    wal_fsync_seconds / serving_swap_phase_seconds /
    router_replica_lag."""
    from repro.api import GraphSession
    from repro.core import Query

    reg = MetricsRegistry()
    sess = GraphSession.open(str(tmp_path / "writer"), n_cap=16,
                             metrics=reg)
    try:
        tracer = sess.enable_tracing()
        ops, t_last = _ops(16, 40)
        sess.ingest(ops)
        sess.flush()
        sess.publish_to(str(tmp_path / "pub"))

        replica = GraphSession.open_replica(str(tmp_path / "pub"),
                                            str(tmp_path / "mirror"),
                                            name="r1", metrics=reg)
        router = GraphSession.open_router({"r1": replica}, metrics=reg)
        router.heartbeat()
        qs = [Query(kind="point", scope="node", measure="degree",
                    t_k=t_last // 2, v=v) for v in range(4)]
        out = router.evaluate_many(qs)
        assert len(out) == 4

        trace_path = str(tmp_path / "trace.json")
        sess.dump_trace(trace_path)
        events = json.load(open(trace_path))["traceEvents"]
        by_name = {}
        for ev in events:
            by_name.setdefault(ev["name"], []).append(ev)
        # the routed call and the replica-side engine work all traced
        assert "route" in by_name and "query" in by_name
        route = by_name["route"][-1]
        assert route["args"]["replica"] == "r1"

        def inside(child, parent):
            return (child["tid"] == parent["tid"]
                    and child["ts"] >= parent["ts"] - 1e-3
                    and child["ts"] + child["dur"]
                        <= parent["ts"] + parent["dur"] + 1e-3)

        queries = by_name["query"]
        for name in ("plan", "dispatch"):
            assert name in by_name, f"missing {name!r} spans"
            assert any(inside(kid, q)
                       for kid in by_name[name] for q in queries), \
                f"{name!r} spans must nest inside a query span"
        # reconstruction work traced under the routed query too
        assert ("reconstruct" in by_name) or ("window_delta" in by_name)
        # swap instrumentation from the writer's flush
        assert "swap" in by_name and "wal.append" in by_name

        snap = sess.metrics()
        fsync = snap["histograms"]["wal_fsync_seconds"]
        assert any(st["count"] > 0 for st in fsync.values())
        phases = snap["histograms"]["serving_swap_phase_seconds"]
        assert {"phase=drain", "phase=flip", "phase=checkpoint"} <= \
            set(phases)
        lag = snap["gauges"]["router_replica_lag"]
        assert lag == {"replica=r1": 0}      # single replica: no lag
        assert sum(snap["counters"]["router_queries_total"]
                   .values()) == 4
        assert sum(snap["counters"]["replica_queries_served_total"]
                   .values()) == 4
        sess.disable_tracing()
        assert active_tracer() is None
        del tracer
    finally:
        sess.close()


def test_frontend_and_replica_stats_are_registry_views(tmp_path):
    """The consolidated stats surfaces read through the registry — the
    same numbers appear under both the old attribute names and the new
    metric names."""
    from repro.api import GraphSession
    from repro.core import Query

    reg = MetricsRegistry()
    with GraphSession(n_cap=8, metrics=reg) as sess:
        ops, t = _ops(8, 10)
        sess.ingest(ops)
        sess.flush()
        q = Query(kind="point", scope="global", measure="num_edges",
                  t_k=t)
        sess.query(q)
        sess.query(q)                         # exact-cache hit
        fe = sess.frontend
        assert fe.stats.submitted == 2
        assert fe.stats.cache_hits == 1
        snap = reg.snapshot()["counters"]
        assert sum(snap["frontend_submitted_total"].values()) == 2
        assert sum(snap["frontend_cache_hits_total"].values()) == 1
