"""HLO analysis: shape parsing, collective accounting, scan-aware
while-body scaling on a synthetic HLO module."""
import pytest

from repro.launch.roofline import (collective_bytes, model_flops,
                                   roofline_terms, scan_aware_metrics,
                                   shape_bytes)

HLO = """\
HloModule jit_step, entry_computation_layout={()->f32[8,8]{1,0}}

%wcond (arg.1: (s32[], f32[8,8])) -> pred[] {
  %arg.1 = (s32[], f32[8,8]{1,0}) parameter(0)
  %gte.1 = s32[] get-tuple-element(%arg.1), index=0
  %c8 = s32[] constant(5)
  ROOT %cmp = pred[] compare(%gte.1, %c8), direction=LT
}

%wbody (arg.2: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg.2 = (s32[], f32[8,8]{1,0}) parameter(0)
  %gte.2 = s32[] get-tuple-element(%arg.2), index=0
  %gte.3 = f32[8,8]{1,0} get-tuple-element(%arg.2), index=1
  %dot.1 = f32[8,8]{1,0} dot(%gte.3, %gte.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.1 = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %add.1 = s32[] add(%gte.2, %one)
  ROOT %tup.1 = (s32[], f32[8,8]{1,0}) tuple(%add.1, %ar.1)
}

%sum (a.1: f32[], b.1: f32[]) -> f32[] {
  %a.1 = f32[] parameter(0)
  %b.1 = f32[] parameter(1)
  ROOT %s.1 = f32[] add(%a.1, %b.1)
}

ENTRY %main (p0.1: f32[8,8]) -> f32[8,8] {
  %p0.1 = f32[8,8]{1,0} parameter(0)
  %zero.1 = s32[] constant(0)
  %tup.2 = (s32[], f32[8,8]{1,0}) tuple(%zero.1, %p0.1)
  %while.1 = (s32[], f32[8,8]{1,0}) while(%tup.2), condition=%wcond, body=%wbody
  %ag.1 = f32[16,8]{1,0} all-gather(%p0.1), dimensions={0}
  %sl.1 = f32[8,8]{1,0} slice(%ag.1), slice={[0:8], [0:8]}
  ROOT %gte.4 = f32[8,8]{1,0} get-tuple-element(%while.1), index=1
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[8,8]{1,0}") == 256
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4], s32[2])") == 24
    assert shape_bytes("pred[]") == 1


def test_collective_bytes_operands():
    c = collective_bytes(HLO)
    # all-reduce operand = dot result 256 B; appears once in the body
    assert c["per_kind"]["all-reduce"] == 256
    assert c["per_kind"]["all-gather"] == 256  # operand p0 = 256 B
    assert c["counts"]["all-reduce"] == 1


def test_scan_aware_trip_scaling():
    sa = scan_aware_metrics(HLO, default_trips=1)
    # dot: 2*8*8*8 = 1024 flops, body runs 5 times (wcond constant)
    assert sa["flops"] == pytest.approx(5 * 1024)
    # collectives: 5 × 256 (in-loop all-reduce) + 256 (entry all-gather)
    assert sa["coll_bytes"] == pytest.approx(5 * 256 + 256)


def test_known_trip_count_precedence():
    hlo = HLO.replace(
        "while(%tup.2), condition=%wcond, body=%wbody",
        'while(%tup.2), condition=%wcond, body=%wbody, '
        'backend_config={"known_trip_count":{"n":"7"}}')
    sa = scan_aware_metrics(hlo, default_trips=1)
    assert sa["flops"] == pytest.approx(7 * 1024)


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 100e9, 1e9)
    assert t["dominant"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    t2 = roofline_terms(1e12, 819e9 * 2, 0)
    assert t2["dominant"] == "memory"


def test_model_flops_monotonic():
    from repro.config import SHAPES
    from repro.configs import get_config
    cfg = get_config("smollm-360m")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_prefill = model_flops(cfg, SHAPES["prefill_32k"])
    f_decode = model_flops(cfg, SHAPES["decode_32k"])
    assert f_train > f_decode
    assert f_prefill > f_decode
    # MoE active < total
    moe = get_config("mixtral-8x7b")
    f_moe = model_flops(moe, SHAPES["train_4k"])
    dense_equiv = 6 * 47e9 * SHAPES["train_4k"].seq_len * \
        SHAPES["train_4k"].global_batch
    assert f_moe < dense_equiv  # top-2 of 8 experts ≪ all-8 dense
