"""Deterministic crash child for the kill -9 recovery tests.

Run as::

    python tests/persist_harness.py ROOT LAYOUT KILL_SPEC NTH

The child opens a durable ``GraphSession`` at ROOT, streams a fixed
synthetic history into it one time unit per ``ingest`` (swapping every
``SWAP_EVERY`` units), and SIGKILLs itself at a precise point inside
the durability plumbing chosen by KILL_SPEC — a genuine uncatchable
death, not an exception path.  Acknowledged progress is recorded in
``ROOT/acks.log`` (fsync'd per line) so the parent test knows exactly
what the recovery contract obliges the reopened store to remember:
every acked append must survive, and every query at t ≤ the recovered
watermark must bit-match a from-scratch oracle over the same stream.

Kill specs (NTH = fire on the N-th invocation of the hooked point):

* ``append_wal_pre``  — mid-ingest, BEFORE the pending batch reaches
  the WAL: the batch was never acknowledged and may vanish.
* ``append_wal_post`` — mid-ingest, after the WAL append but before
  the buffer mutation: durable yet unacknowledged.
* ``drain_logged``    — mid-swap, right after the drain-intent record:
  the drained ingest/advance never ran; replay must re-execute them.
* ``mid_checkpoint``  — mid-swap, after the rotated WAL is written but
  before the manifest rename: recovery must come from the OLD wal and
  sweep the stray new one.
* ``post_checkpoint`` — mid-swap, manifest durable but the engine
  pointer never flipped: the recovered watermark is AHEAD of anything
  a client observed, which is legal (monotone) and must be exact.
* ``seal_logged``     — right after a seal's WAL record, before the
  segment file write: replay must re-cut the segment and regenerate
  the identical file.

Exits: SIGKILL (parent sees returncode -9) when the hook fires; exit
code 3 when the whole stream ran without the hook firing (a test
misconfiguration — NTH was set past the run's event count).

Replication extensions (tests/test_replica.py):

* KILL_SPEC ``none`` installs no hook: the child streams the whole
  history and exits 0 — with a nonzero NTH it sleeps ``NTH`` ms per
  unit, making it a long-running writer the parent can ``kill -9`` at
  an arbitrary real instant and then *restart* (the reopened session
  skips units already acknowledged and streams the rest).
* A 5th argument names a publish root: the child attaches a
  ``SegmentPublisher`` so every swap ships its manifest diff — the
  writer side of the replica chaos tests.
"""
import os
import signal
import sys

N_CAP = 48
N_NODES = 32
SEED = 11
SWAP_EVERY = 3
SEGMENT_MIN_OPS = 8


def proposal_units(seed: int = SEED):
    """The fixed proposal stream, grouped one batch per time unit.
    Parent and child both derive it from the seed — the oracle side of
    every bit-equality assertion."""
    from repro.core.generate import EvolutionParams, generate_ops
    ops = generate_ops(N_NODES, EvolutionParams(
        m_attach=3, lam_extra=1.0, lam_remove=1.0, p_remove_node=0.02,
        events_per_unit=6), seed=seed)
    units: dict[int, list] = {}
    for o in ops:
        units.setdefault(o.t, []).append(o)
    return [units[t] for t in sorted(units)]


def _kill():
    os.kill(os.getpid(), signal.SIGKILL)


def _hook(orig, before: bool, state: dict, nth: int):
    def wrapped(*args, **kw):
        state["n"] += 1
        if before and state["n"] == nth:
            _kill()
        out = orig(*args, **kw)
        if not before and state["n"] == nth:
            _kill()
        return out
    return wrapped


def install_kill(persist, spec: str, nth: int) -> None:
    state = {"n": 0}
    if spec == "append_wal_pre":
        persist.log_pending = _hook(persist.log_pending, True, state, nth)
    elif spec == "append_wal_post":
        persist.log_pending = _hook(persist.log_pending, False, state, nth)
    elif spec == "drain_logged":
        persist.log_drain = _hook(persist.log_drain, False, state, nth)
    elif spec == "mid_checkpoint":
        from repro.persist import manifest as mf
        mf.write_manifest = _hook(mf.write_manifest, True, state, nth)
    elif spec == "post_checkpoint":
        persist.checkpoint = _hook(persist.checkpoint, False, state, nth)
    elif spec == "seal_logged":
        # class-level: persist.wal is replaced at every rotation
        from repro.persist.wal import WriteAheadLog
        WriteAheadLog.log_seal = _hook(WriteAheadLog.log_seal, False,
                                       state, nth)
    else:
        raise SystemExit(f"unknown kill spec {spec!r}")


def main(argv) -> int:
    root, layout, spec, nth = argv[0], argv[1], argv[2], int(argv[3])
    publish_root = argv[4] if len(argv) > 4 else None
    import time

    from repro.api import GraphSession
    session = GraphSession.open(root, n_cap=N_CAP, layout=layout,
                                segment_min_ops=SEGMENT_MIN_OPS)
    if publish_root:
        session.publish_to(publish_root)
    sleep_s = 0.0
    if spec == "none":
        sleep_s = nth / 1000.0           # NTH doubles as ms-per-unit
    else:
        install_kill(session.store.persist, spec, nth)
    acks = open(os.path.join(root, "acks.log"), "a")

    def ack(line: str) -> None:
        acks.write(line + "\n")
        acks.flush()
        os.fsync(acks.fileno())

    # restart support: a reopened session already holds (at least)
    # every acknowledged unit — ingest is batch-atomic, so skipping
    # whole units by their closing time resumes the stream exactly
    t_done = session.live._t_append_last
    for i, unit in enumerate(proposal_units()):
        if unit[-1].t <= t_done:
            continue
        session.ingest(unit)
        ack(f"unit {i} {unit[-1].t}")
        if (i + 1) % SWAP_EVERY == 0:
            session.flush()
            ack(f"swap {session.watermark}")
        if sleep_s:
            time.sleep(sleep_s)
    if spec == "none":
        session.flush()
        ack(f"swap {session.watermark}")
        session.close()
        return 0
    return 3


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
