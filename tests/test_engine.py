"""Unified engine (core/engine.py): parity with the single-query path,
batch-composition invariance, anchor selection, and planner sanity.

The acceptance contract: ``evaluate_many([q])`` bit-matches
``plans.evaluate(q)`` for every plan × query-kind combination, and
batched results are invariant to batch composition/order.
"""
import numpy as np
import pytest

from repro.core.engine import (AnchorSelector, HistoricalQueryEngine,
                               Planner)
from repro.core.plans import Query, applicable_plans


def _item(x):
    return np.asarray(x).item()


def _ts(store, frac):
    return max(1, int(store.t_cur * frac))


def _engine(store, indexed=False):
    return store.engine(indexed=indexed)


# ---------------------------------------------------------------------------
# Parity: every plan × kind combination (Table 2 matrix)
# ---------------------------------------------------------------------------


def _query_matrix(store):
    """One query per (kind, scope) cell, with integer-exact measures so
    bitwise comparison is meaningful."""
    tc = store.t_cur
    return [
        Query("point", "node", "degree", t_k=tc // 3, v=5),
        Query("diff", "node", "degree", t_k=tc // 4, t_l=3 * tc // 4, v=9),
        Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
              agg="mean"),
        Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
              agg="min"),
        Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
              agg="max"),
        Query("point", "global", "num_edges", t_k=tc // 2),
        Query("point", "global", "num_nodes", t_k=tc // 2),
        Query("diff", "global", "num_edges", t_k=tc // 4, t_l=3 * tc // 4),
        Query("agg", "global", "num_edges", t_k=tc // 2, t_l=tc // 2 + 4,
              agg="max"),
    ]


def test_parity_all_plan_kind_combinations(small_history):
    """evaluate_many([q]) == plans.evaluate(q), bit for bit, for every
    applicable plan of every query-kind/scope cell."""
    store, _ = small_history
    eng = _engine(store)
    for q in _query_matrix(store):
        for plan in applicable_plans(q):
            single = _item(store.query(q, plan=plan))
            batched = _item(eng.evaluate_many([q], plan=plan)[0])
            assert batched == single, (q, plan)


def test_parity_variants(small_history):
    """Indexed / partial / windowed variants bit-match their
    single-query counterparts."""
    store, _ = small_history
    eng = _engine(store, indexed=True)
    tc = store.t_cur
    q_point = Query("point", "node", "degree", t_k=tc // 3, v=5)
    q_diff = Query("diff", "node", "degree", t_k=tc // 4, t_l=3 * tc // 4,
                   v=9)

    for q, plan in ((q_point, "hybrid"), (q_diff, "delta_only"),
                    (q_diff, "hybrid")):
        single = _item(store.query(q, plan=plan, indexed=True))
        batched = _item(eng.evaluate_many([q], plan=plan, indexed=True)[0])
        assert batched == single, (q, plan, "indexed")

    for q in (q_point, q_diff):
        single = _item(store.query(q, plan="two_phase", partial_rows=True))
        batched = _item(eng.evaluate_many([q], plan="two_phase",
                                          partial_rows=True)[0])
        assert batched == single, (q, "partial")
        # windowed reconstruction is exact: same bits as the full log
        full = _item(store.query(q, plan="two_phase"))
        win = _item(eng.evaluate_many([q], plan="two_phase",
                                      windowed=True)[0])
        assert win == full, (q, "windowed")


def test_parity_auto_plan(small_history):
    """Auto-planned batched results match the brute-force oracle."""
    store, bf = small_history
    eng = _engine(store)
    rng = np.random.default_rng(7)
    qs, expect = [], []
    for _ in range(24):
        v = int(rng.integers(0, store.n_cap))
        t1 = int(rng.integers(1, store.t_cur))
        t2 = min(store.t_cur, t1 + int(rng.integers(0, 6)))
        kind = ["point", "diff", "agg"][int(rng.integers(0, 3))]
        if kind == "point":
            qs.append(Query("point", "node", "degree", t_k=t1, v=v))
            expect.append(bf.degree(v, t1))
        elif kind == "diff":
            qs.append(Query("diff", "node", "degree", t_k=t1, t_l=t2, v=v))
            expect.append(abs(bf.degree(v, t2) - bf.degree(v, t1)))
        else:
            qs.append(Query("agg", "node", "degree", t_k=t1, t_l=t2, v=v,
                            agg="max"))
            expect.append(max(bf.degree_series(v, t1, t2)))
    got = eng.evaluate_many(qs)
    for q, g, e in zip(qs, got, expect):
        assert _item(g) == e, q


# ---------------------------------------------------------------------------
# Batch composition / order invariance
# ---------------------------------------------------------------------------


def test_batch_order_invariance(small_history):
    store, _ = small_history
    eng = _engine(store)
    qs = _query_matrix(store) * 3
    base = [_item(r) for r in eng.evaluate_many(qs)]
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(qs))
    shuf = [_item(r) for r in eng.evaluate_many([qs[i] for i in perm])]
    for j, i in enumerate(perm):
        assert shuf[j] == base[i]


def test_batch_composition_invariance(small_history):
    """A query's result does not depend on what else is in the batch."""
    store, _ = small_history
    eng = _engine(store)
    qs = _query_matrix(store)
    solo = [_item(eng.evaluate_many([q])[0]) for q in qs]
    together = [_item(r) for r in eng.evaluate_many(qs)]
    assert solo == together
    # and in a big mixed batch with duplicates
    big = qs * 5
    got = [_item(r) for r in eng.evaluate_many(big)]
    assert got == solo * 5


# ---------------------------------------------------------------------------
# Anchor selection
# ---------------------------------------------------------------------------


def test_anchor_selector_prefers_cheap_anchor(small_history):
    store, bf = small_history
    # materialize a mid-history snapshot; queries near it should anchor
    # there, queries near t_cur should anchor at the current snapshot
    t_mid = store.t_cur // 2
    g_mid = store.snapshot_at(t_mid, use_materialized=False)
    store.materialized.add(t_mid, g_mid)
    store._engine_cache = None
    eng = _engine(store)
    delta = store.delta()
    near_mid = eng.selector.select(t_mid + 1, delta, "ops")
    assert near_mid.anchor_id == 0
    near_cur = eng.selector.select(store.t_cur, delta, "ops")
    assert near_cur.anchor_id == -1
    # results stay exact from either anchor
    for frac in (0.3, 0.55, 0.95):
        t = _ts(store, frac)
        g = store.snapshot_at(t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t
    # cleanup (session-scoped fixture)
    store.materialized.times.clear()
    store.materialized.snapshots.clear()
    store._engine_cache = None


def test_anchor_selector_no_candidates():
    from repro.core.delta import empty_delta
    sel = AnchorSelector((), ())
    with pytest.raises(ValueError):
        sel.select(3, empty_delta(4))


def test_anchor_selector_tie_break_current_wins():
    """Equal op-distance between the current snapshot and a
    materialized one must deterministically pick the current snapshot
    (candidate order: current first, then materialized in store order —
    ``min`` is stable).  Deterministic tie-breaking is what makes batch
    grouping reproducible across runs."""
    from repro.core.delta import delta_from_numpy
    from repro.core.graph import empty_dense

    # one op per time unit 1..8: window (2, 5] and (5, 8] both hold 3
    ts = np.arange(1, 9, dtype=np.int32)
    m = len(ts)
    delta = delta_from_numpy(np.full(m, 2, np.int32), np.zeros(m, np.int32),
                             np.ones(m, np.int32), np.zeros(m, np.int32),
                             ts)
    g = empty_dense(4)
    sel = AnchorSelector([2], [g], t_cur=8, current=g,
                         t_host=np.asarray(ts))
    cands = sel.candidates(5, delta, "ops")
    assert [c.cost for c in cands] == [3, 3]
    assert sel.select(5, delta, "ops").anchor_id == -1
    # equal-cost materialized snapshots: earliest in store order wins
    sel2 = AnchorSelector([2, 8], [g, g], t_host=np.asarray(ts))
    cands = sel2.candidates(5, delta, "ops")
    assert [c.cost for c in cands] == [3, 3]
    assert sel2.select(5, delta, "ops").anchor_id == 0
    # the 'time' metric ties the same way
    assert sel.select(5, delta, "time").anchor_id == -1


def test_batched_two_phase_uses_materialized_anchor(small_history):
    """Two-phase groups anchored at a materialized snapshot return the
    same values as the current-anchored single path."""
    store, _ = small_history
    t_mid = store.t_cur // 2
    g_mid = store.snapshot_at(t_mid, use_materialized=False)
    store.materialized.add(t_mid, g_mid)
    store._engine_cache = None
    eng = _engine(store)
    qs = [Query("point", "node", "degree", t_k=t_mid + 1, v=v)
          for v in (2, 5, 11, 17)]
    res, choices = eng.evaluate_many(qs, plan="two_phase",
                                     return_choices=True)
    assert all(c.anchor_id == 0 for c in choices)
    for q, r in zip(qs, res):
        assert _item(r) == _item(store.query(q, plan="two_phase")), q
    store.materialized.times.clear()
    store.materialized.snapshots.clear()
    store._engine_cache = None


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


def test_planner_picks_applicable_and_cheap(small_history):
    store, _ = small_history
    eng = _engine(store)
    tc = store.t_cur
    for q in _query_matrix(store):
        c = eng.plan(q)
        assert c.plan in applicable_plans(q)
    # non-degree measures must fall back to two-phase (Table 2)
    c = eng.plan(Query("point", "global", "density", t_k=tc // 2))
    assert c.plan == "two_phase"
    # a recent degree diff: the delta-only window is tiny, so the
    # planner must not choose a plan costlier than two-phase
    q = Query("diff", "node", "degree", t_k=tc - 2, t_l=tc - 1, v=1)
    c = eng.plan(q)
    assert c.plan in ("delta_only", "hybrid")


def test_non_degree_measures_match_scalar_path(small_history):
    """Non-degree node measures: auto planning must not enable unsound
    partial reconstruction, and forcing a degree-specialised plan must
    fall back to two-phase exactly like plans.evaluate does."""
    store, _ = small_history
    eng = _engine(store)
    tc = store.t_cur
    for q in (Query("diff", "node", "neighborhood2", t_k=tc // 4,
                    t_l=3 * tc // 4, v=5),
              Query("point", "node", "neighborhood2", t_k=tc // 3, v=5),
              Query("agg", "node", "induced_avg_degree", t_k=tc // 2,
                    t_l=tc // 2 + 3, v=5)):
        assert _item(eng.evaluate_many([q])[0]) == _item(store.query(q)), q
        for plan in applicable_plans(q):
            got = _item(eng.evaluate_many([q], plan=plan)[0])
            assert got == _item(store.query(q, plan=plan)), (q, plan)


def test_agg_series_budget_fallback(small_history):
    """When the union window is too wide for the shared all-nodes
    series, the per-node fallback returns bit-identical results."""
    from repro.core.engine import HistoricalQueryEngine
    store, _ = small_history
    tc = store.t_cur
    qs = [Query("agg", "node", "degree", t_k=1, t_l=4, v=2, agg="mean"),
          Query("agg", "node", "degree", t_k=tc - 4, t_l=tc - 1, v=7,
                agg="mean")]
    normal = store.engine().evaluate_many(qs)
    tiny = HistoricalQueryEngine(
        store.current, store.delta(), store.t_cur,
        mat_times=store.materialized.times,
        mat_snapshots=store.materialized.snapshots, series_budget=1)
    fallback = tiny.evaluate_many(qs)
    assert [_item(a) for a in normal] == [_item(b) for b in fallback]


def test_mesh_single_device_host_fallback(small_history):
    """With one visible device a mesh-bound engine must route every
    group through the ordinary path (mode None) and return identical
    results — the host-process fallback of the distributed layer."""
    from repro.sharding.graph import graph_mesh, single_device
    store, _ = small_history
    mesh = graph_mesh()
    assert single_device(mesh)  # conftest pins tests to one device
    qs = _query_matrix(store)
    base = [_item(r) for r in store.engine().evaluate_many(qs)]
    eng = store.place_on_mesh(mesh)
    got = [_item(r) for r in eng.evaluate_many(qs, mesh=mesh,
                                               shard="force")]
    assert got == base
    assert all(m is None for *_, m in eng.last_group_stats)
    store._engine_cache = None  # session fixture: drop the mesh engine


def test_planner_shard_cost_term(small_history):
    """The cross-device dispatch cost term: tiny groups stay local,
    large groups shard, force overrides the threshold but never makes
    an unshardable group shardable."""
    store, _ = small_history
    eng = store.engine()
    pl = eng.planner
    from repro.core.engine import _GroupKey
    k2p = _GroupKey("two_phase", "point", "global", "num_edges", "",
                    -1, False, False, False)
    cap = eng.delta.capacity
    assert pl.shard_mode(k2p, 1, 1, cap) is None          # 1 device
    assert pl.shard_mode(k2p, 64, 8, cap) == "rows"       # big: rows
    assert pl.shard_mode(k2p, 64, 7, cap) == "batch"      # 96 % 7 != 0
    kb = _GroupKey("hybrid", "point", "node", "degree", "",
                   -1, False, False, False)
    assert pl.shard_mode(kb, 2, 8, cap) is None           # under threshold
    assert pl.shard_mode(kb, 2, 8, cap, force=True) == "batch"
    assert pl.shard_mode(kb, 512, 8, cap) == "batch"
    kpart = _GroupKey("two_phase", "point", "node", "degree", "",
                      -1, False, False, True)
    assert pl.shard_mode(kpart, 512, 8, cap) == "batch"   # partial: no rows


def test_store_query_auto_routes_through_planner(small_history):
    """plans.evaluate(plan='auto') delegates choice to the Planner and
    still matches the oracle."""
    store, bf = small_history
    t = _ts(store, 0.5)
    q = Query("point", "node", "degree", t_k=t, v=5)
    assert _item(store.query(q)) == bf.degree(5, t)
    q2 = Query("diff", "node", "degree", t_k=_ts(store, 0.3),
               t_l=_ts(store, 0.8), v=9)
    assert _item(store.query(q2)) == abs(bf.degree(9, _ts(store, 0.8))
                                         - bf.degree(9, _ts(store, 0.3)))
