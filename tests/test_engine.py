"""Unified engine (core/engine.py): parity with the single-query path,
batch-composition invariance, anchor selection, and planner sanity.

The acceptance contract: ``evaluate_many([q])`` bit-matches
``plans.evaluate(q)`` for every plan × query-kind combination, and
batched results are invariant to batch composition/order.
"""
import numpy as np
import pytest

from repro.core.engine import (AnchorSelector, HistoricalQueryEngine,
                               Planner)
from repro.core.plans import Query, applicable_plans


def _item(x):
    return np.asarray(x).item()


def _ts(store, frac):
    return max(1, int(store.t_cur * frac))


def _engine(store, indexed=False):
    return store.engine(indexed=indexed)


# ---------------------------------------------------------------------------
# Parity: every plan × kind combination (Table 2 matrix)
# ---------------------------------------------------------------------------


def _query_matrix(store):
    """One query per (kind, scope) cell, with integer-exact measures so
    bitwise comparison is meaningful."""
    tc = store.t_cur
    return [
        Query("point", "node", "degree", t_k=tc // 3, v=5),
        Query("diff", "node", "degree", t_k=tc // 4, t_l=3 * tc // 4, v=9),
        Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
              agg="mean"),
        Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
              agg="min"),
        Query("agg", "node", "degree", t_k=tc // 2, t_l=tc // 2 + 6, v=3,
              agg="max"),
        Query("point", "global", "num_edges", t_k=tc // 2),
        Query("point", "global", "num_nodes", t_k=tc // 2),
        Query("diff", "global", "num_edges", t_k=tc // 4, t_l=3 * tc // 4),
        Query("agg", "global", "num_edges", t_k=tc // 2, t_l=tc // 2 + 4,
              agg="max"),
    ]


def test_parity_all_plan_kind_combinations(small_history):
    """evaluate_many([q]) == plans.evaluate(q), bit for bit, for every
    applicable plan of every query-kind/scope cell."""
    store, _ = small_history
    eng = _engine(store)
    for q in _query_matrix(store):
        for plan in applicable_plans(q):
            single = _item(store.query(q, plan=plan))
            batched = _item(eng.evaluate_many([q], plan=plan)[0])
            assert batched == single, (q, plan)


def test_parity_variants(small_history):
    """Indexed / partial / windowed variants bit-match their
    single-query counterparts."""
    store, _ = small_history
    eng = _engine(store, indexed=True)
    tc = store.t_cur
    q_point = Query("point", "node", "degree", t_k=tc // 3, v=5)
    q_diff = Query("diff", "node", "degree", t_k=tc // 4, t_l=3 * tc // 4,
                   v=9)

    for q, plan in ((q_point, "hybrid"), (q_diff, "delta_only"),
                    (q_diff, "hybrid")):
        single = _item(store.query(q, plan=plan, indexed=True))
        batched = _item(eng.evaluate_many([q], plan=plan, indexed=True)[0])
        assert batched == single, (q, plan, "indexed")

    for q in (q_point, q_diff):
        single = _item(store.query(q, plan="two_phase", partial_rows=True))
        batched = _item(eng.evaluate_many([q], plan="two_phase",
                                          partial_rows=True)[0])
        assert batched == single, (q, "partial")
        # windowed reconstruction is exact: same bits as the full log
        full = _item(store.query(q, plan="two_phase"))
        win = _item(eng.evaluate_many([q], plan="two_phase",
                                      windowed=True)[0])
        assert win == full, (q, "windowed")


def test_parity_auto_plan(small_history):
    """Auto-planned batched results match the brute-force oracle."""
    store, bf = small_history
    eng = _engine(store)
    rng = np.random.default_rng(7)
    qs, expect = [], []
    for _ in range(24):
        v = int(rng.integers(0, store.n_cap))
        t1 = int(rng.integers(1, store.t_cur))
        t2 = min(store.t_cur, t1 + int(rng.integers(0, 6)))
        kind = ["point", "diff", "agg"][int(rng.integers(0, 3))]
        if kind == "point":
            qs.append(Query("point", "node", "degree", t_k=t1, v=v))
            expect.append(bf.degree(v, t1))
        elif kind == "diff":
            qs.append(Query("diff", "node", "degree", t_k=t1, t_l=t2, v=v))
            expect.append(abs(bf.degree(v, t2) - bf.degree(v, t1)))
        else:
            qs.append(Query("agg", "node", "degree", t_k=t1, t_l=t2, v=v,
                            agg="max"))
            expect.append(max(bf.degree_series(v, t1, t2)))
    got = eng.evaluate_many(qs)
    for q, g, e in zip(qs, got, expect):
        assert _item(g) == e, q


# ---------------------------------------------------------------------------
# Batch composition / order invariance
# ---------------------------------------------------------------------------


def test_batch_order_invariance(small_history):
    store, _ = small_history
    eng = _engine(store)
    qs = _query_matrix(store) * 3
    base = [_item(r) for r in eng.evaluate_many(qs)]
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(qs))
    shuf = [_item(r) for r in eng.evaluate_many([qs[i] for i in perm])]
    for j, i in enumerate(perm):
        assert shuf[j] == base[i]


def test_batch_composition_invariance(small_history):
    """A query's result does not depend on what else is in the batch."""
    store, _ = small_history
    eng = _engine(store)
    qs = _query_matrix(store)
    solo = [_item(eng.evaluate_many([q])[0]) for q in qs]
    together = [_item(r) for r in eng.evaluate_many(qs)]
    assert solo == together
    # and in a big mixed batch with duplicates
    big = qs * 5
    got = [_item(r) for r in eng.evaluate_many(big)]
    assert got == solo * 5


# ---------------------------------------------------------------------------
# Anchor selection
# ---------------------------------------------------------------------------


def test_anchor_selector_prefers_cheap_anchor(small_history):
    store, bf = small_history
    # materialize a mid-history snapshot; queries near it should anchor
    # there, queries near t_cur should anchor at the current snapshot
    t_mid = store.t_cur // 2
    g_mid = store.snapshot_at(t_mid, use_materialized=False)
    store.materialized.add(t_mid, g_mid)
    store._engine_cache = None
    eng = _engine(store)
    delta = store.delta()
    near_mid = eng.selector.select(t_mid + 1, delta, "ops")
    assert near_mid.anchor_id == 0
    near_cur = eng.selector.select(store.t_cur, delta, "ops")
    assert near_cur.anchor_id == -1
    # results stay exact from either anchor
    for frac in (0.3, 0.55, 0.95):
        t = _ts(store, frac)
        g = store.snapshot_at(t)
        assert np.array_equal(np.asarray(g.adj), bf.adj(t)), t
    # cleanup (session-scoped fixture)
    store.materialized.times.clear()
    store.materialized.snapshots.clear()
    store._engine_cache = None


def test_anchor_selector_no_candidates():
    from repro.core.delta import empty_delta
    sel = AnchorSelector((), ())
    with pytest.raises(ValueError):
        sel.select(3, empty_delta(4))


def test_anchor_selector_tie_break_current_wins():
    """Equal op-distance between the current snapshot and a
    materialized one must deterministically pick the current snapshot
    (candidate order: current first, then materialized in store order —
    ``min`` is stable).  Deterministic tie-breaking is what makes batch
    grouping reproducible across runs."""
    from repro.core.delta import delta_from_numpy
    from repro.core.graph import empty_dense

    # one op per time unit 1..8: window (2, 5] and (5, 8] both hold 3
    ts = np.arange(1, 9, dtype=np.int32)
    m = len(ts)
    delta = delta_from_numpy(np.full(m, 2, np.int32), np.zeros(m, np.int32),
                             np.ones(m, np.int32), np.zeros(m, np.int32),
                             ts)
    g = empty_dense(4)
    sel = AnchorSelector([2], [g], t_cur=8, current=g,
                         t_host=np.asarray(ts))
    cands = sel.candidates(5, delta, "ops")
    assert [c.cost for c in cands] == [3, 3]
    assert sel.select(5, delta, "ops").anchor_id == -1
    # equal-cost materialized snapshots: earliest in store order wins
    sel2 = AnchorSelector([2, 8], [g, g], t_host=np.asarray(ts))
    cands = sel2.candidates(5, delta, "ops")
    assert [c.cost for c in cands] == [3, 3]
    assert sel2.select(5, delta, "ops").anchor_id == 0
    # the 'time' metric ties the same way
    assert sel.select(5, delta, "time").anchor_id == -1


def test_batched_two_phase_uses_materialized_anchor(small_history):
    """Two-phase groups anchored at a materialized snapshot return the
    same values as the current-anchored single path."""
    store, _ = small_history
    t_mid = store.t_cur // 2
    g_mid = store.snapshot_at(t_mid, use_materialized=False)
    store.materialized.add(t_mid, g_mid)
    store._engine_cache = None
    eng = _engine(store)
    qs = [Query("point", "node", "degree", t_k=t_mid + 1, v=v)
          for v in (2, 5, 11, 17)]
    res, choices = eng.evaluate_many(qs, plan="two_phase",
                                     return_choices=True)
    assert all(c.anchor_id == 0 for c in choices)
    for q, r in zip(qs, res):
        assert _item(r) == _item(store.query(q, plan="two_phase")), q
    store.materialized.times.clear()
    store.materialized.snapshots.clear()
    store._engine_cache = None


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


def test_planner_picks_applicable_and_cheap(small_history):
    store, _ = small_history
    eng = _engine(store)
    tc = store.t_cur
    for q in _query_matrix(store):
        c = eng.plan(q)
        assert c.plan in applicable_plans(q)
    # non-degree measures must fall back to two-phase (Table 2)
    c = eng.plan(Query("point", "global", "density", t_k=tc // 2))
    assert c.plan == "two_phase"
    # a recent degree diff: the delta-only window is tiny, so the
    # planner must not choose a plan costlier than two-phase
    q = Query("diff", "node", "degree", t_k=tc - 2, t_l=tc - 1, v=1)
    c = eng.plan(q)
    assert c.plan in ("delta_only", "hybrid")


def test_non_degree_measures_match_scalar_path(small_history):
    """Non-degree node measures: auto planning must not enable unsound
    partial reconstruction, and forcing a degree-specialised plan must
    fall back to two-phase exactly like plans.evaluate does."""
    store, _ = small_history
    eng = _engine(store)
    tc = store.t_cur
    for q in (Query("diff", "node", "neighborhood2", t_k=tc // 4,
                    t_l=3 * tc // 4, v=5),
              Query("point", "node", "neighborhood2", t_k=tc // 3, v=5),
              Query("agg", "node", "induced_avg_degree", t_k=tc // 2,
                    t_l=tc // 2 + 3, v=5)):
        assert _item(eng.evaluate_many([q])[0]) == _item(store.query(q)), q
        for plan in applicable_plans(q):
            got = _item(eng.evaluate_many([q], plan=plan)[0])
            assert got == _item(store.query(q, plan=plan)), (q, plan)


def test_agg_series_budget_fallback(small_history):
    """When the union window is too wide for the shared all-nodes
    series, the per-node fallback returns bit-identical results."""
    from repro.core.engine import HistoricalQueryEngine
    store, _ = small_history
    tc = store.t_cur
    qs = [Query("agg", "node", "degree", t_k=1, t_l=4, v=2, agg="mean"),
          Query("agg", "node", "degree", t_k=tc - 4, t_l=tc - 1, v=7,
                agg="mean")]
    normal = store.engine().evaluate_many(qs)
    tiny = HistoricalQueryEngine(
        store.current, store.delta(), store.t_cur,
        mat_times=store.materialized.times,
        mat_snapshots=store.materialized.snapshots, series_budget=1)
    fallback = tiny.evaluate_many(qs)
    assert [_item(a) for a in normal] == [_item(b) for b in fallback]


def test_mesh_single_device_host_fallback(small_history):
    """With one visible device a mesh-bound engine must route every
    group through the ordinary path (mode None) and return identical
    results — the host-process fallback of the distributed layer."""
    from repro.sharding.graph import graph_mesh, single_device
    store, _ = small_history
    mesh = graph_mesh()
    assert single_device(mesh)  # conftest pins tests to one device
    qs = _query_matrix(store)
    base = [_item(r) for r in store.engine().evaluate_many(qs)]
    eng = store.place_on_mesh(mesh)
    got = [_item(r) for r in eng.evaluate_many(qs, mesh=mesh,
                                               shard="force")]
    assert got == base
    assert all(m is None for *_, m in eng.last_group_stats)
    store._engine_cache = None  # session fixture: drop the mesh engine


def test_planner_shard_cost_term(small_history):
    """The cross-device dispatch cost term: tiny groups stay local,
    large groups shard, force overrides the threshold but never makes
    an unshardable group shardable."""
    store, _ = small_history
    eng = store.engine()
    pl = eng.planner
    from repro.core.engine import _GroupKey
    k2p = _GroupKey("two_phase", "point", "global", "num_edges", "",
                    -1, False, False, False)
    cap = eng.delta.capacity
    assert pl.shard_mode(k2p, 1, 1, cap) is None          # 1 device
    assert pl.shard_mode(k2p, 64, 8, cap) == "rows"       # big: rows
    assert pl.shard_mode(k2p, 64, 7, cap) == "batch"      # 96 % 7 != 0
    kb = _GroupKey("hybrid", "point", "node", "degree", "",
                   -1, False, False, False)
    assert pl.shard_mode(kb, 2, 8, cap) is None           # under threshold
    assert pl.shard_mode(kb, 2, 8, cap, force=True) == "batch"
    assert pl.shard_mode(kb, 512, 8, cap) == "batch"
    kpart = _GroupKey("two_phase", "point", "node", "degree", "",
                      -1, False, False, True)
    assert pl.shard_mode(kpart, 512, 8, cap) == "batch"   # partial: no rows


def test_store_query_auto_routes_through_planner(small_history):
    """plans.evaluate(plan='auto') delegates choice to the Planner and
    still matches the oracle."""
    store, bf = small_history
    t = _ts(store, 0.5)
    q = Query("point", "node", "degree", t_k=t, v=5)
    assert _item(store.query(q)) == bf.degree(5, t)
    q2 = Query("diff", "node", "degree", t_k=_ts(store, 0.3),
               t_l=_ts(store, 0.8), v=9)
    assert _item(store.query(q2)) == abs(bf.degree(9, _ts(store, 0.8))
                                         - bf.degree(9, _ts(store, 0.3)))


# ---------------------------------------------------------------------------
# Edge-slot layout: dense↔edge bit parity + planner layout choice
# ---------------------------------------------------------------------------


def _edge_safe_matrix(store):
    """The query-matrix cells whose measures have an edge-layout
    implementation (degree + the slot-decomposable globals)."""
    from repro.core.queries import edge_supported
    return [q for q in _query_matrix(store)
            if edge_supported(q.measure, q.scope)]


def test_edge_layout_bit_parity_all_kinds(small_history):
    """Forced edge layout == forced dense layout, bit for bit, for
    every supported (kind, scope, measure) cell and every plan."""
    store, _ = small_history
    eng = _engine(store)
    qs = _edge_safe_matrix(store)
    dense = [_item(r) for r in eng.evaluate_many(qs, plan="two_phase",
                                                 layout="dense")]
    edge = [_item(r) for r in eng.evaluate_many(qs, plan="two_phase",
                                                layout="edge")]
    assert edge == dense
    assert all(k.layout == "edge" for k, _, _ in eng.last_group_stats)
    # hybrid / delta-only edge variants (degree-specialised kernels
    # reading the snapshot only through degrees) bit-match too
    deg = [q for q in qs if q.scope == "node" and q.measure == "degree"]
    for plan, sub in (("hybrid", deg),
                      ("delta_only",
                       [q for q in deg if q.kind == "diff"])):
        d = [_item(r) for r in eng.evaluate_many(sub, plan=plan,
                                                 layout="dense")]
        e = [_item(r) for r in eng.evaluate_many(sub, plan=plan,
                                                 layout="edge")]
        assert e == d, plan


def test_edge_layout_unsupported_measure_falls_back(small_history):
    """layout='edge' on a measure without an edge implementation falls
    back to dense per query (mirroring forced-plan fallbacks)."""
    store, _ = small_history
    eng = _engine(store)
    q = Query("point", "node", "neighborhood2",
              t_k=store.t_cur // 3, v=5)
    ref = _item(eng.evaluate_many([q], layout="dense")[0])
    got = _item(eng.evaluate_many([q], layout="edge")[0])
    assert got == ref
    assert eng.last_group_stats[0][0].layout == "dense"


def test_edge_layout_materialized_anchor_parity(small_history):
    """dense_to_edge anchor conversion is exact: edge groups anchored
    at a materialized (dense) snapshot still bit-match."""
    store, _ = small_history
    t_mid = store.t_cur // 2
    store.materialized.add(t_mid, store.snapshot_at(
        t_mid, use_materialized=False))
    store._engine_cache = None
    try:
        eng = _engine(store)
        qs = [Query("point", "global", "num_edges", t_k=t_mid - 1),
              Query("point", "node", "degree", t_k=t_mid + 1, v=7)]
        dense, choices = eng.evaluate_many(qs, plan="two_phase",
                                           layout="dense",
                                           return_choices=True)
        assert any(c.anchor_id != -1 for c in choices)
        edge = eng.evaluate_many(qs, plan="two_phase", layout="edge")
        assert [_item(r) for r in edge] == [_item(r) for r in dense]
    finally:
        store.materialized.times.clear()
        store.materialized.snapshots.clear()
        store._engine_cache = None


def test_planner_layout_cost_term(small_history):
    """The N²-vs-E term: global two-phase prefers the slot scatter when
    E ≪ N²; an engine without a slot registry stays dense; an
    edge-only engine routes everything edge."""
    store, _ = small_history
    eng = _engine(store)
    pl = eng.planner
    q_glob = Query("point", "global", "num_edges", t_k=store.t_cur // 2)
    assert pl.layout_for(q_glob, "two_phase") == "edge"
    assert pl.layout_for(q_glob, "hybrid") == "dense"
    # e_cap dominating the dense scatter → dense wins
    from repro.core.engine import HistoricalQueryEngine
    pl2 = type(pl)(pl.selector, n_cap=pl.n_cap, e_cap=pl.n_cap ** 2,
                   dense_available=True, edge_available=True)
    assert pl2.layout_for(q_glob, "two_phase") == "dense"
    # no registry → dense; no dense state → edge
    pl3 = type(pl)(pl.selector, n_cap=pl.n_cap)
    assert pl3.layout_for(q_glob, "two_phase") == "dense"
    eng_e = HistoricalQueryEngine(
        None, store.delta(), store.t_cur,
        current_edge=store.current_edge_snapshot())
    assert eng_e.planner.layout_for(q_glob, "two_phase") == "edge"
    got = _item(eng_e.evaluate_many([q_glob])[0])
    assert got == _item(eng.evaluate_many([q_glob], layout="dense")[0])


def test_edge_layout_store_end_to_end(small_history):
    """A layout='edge' store (no N² array anywhere) serves the
    edge-supported measures with values equal to the dense store."""
    from repro.core.graph import EdgeGraph
    from repro.core.store import Op, TemporalGraphStore
    store, bf = small_history
    acc = [Op(int(o), int(u), int(v), int(t)) for o, u, v, t in
           zip(store._op, store._u, store._v, store._t)]
    es = TemporalGraphStore(n_cap=store.n_cap, layout="edge",
                            enforce_invertible=False)
    es.ingest(acc)
    es.advance_to(store.t_cur)
    assert isinstance(es.current, EdgeGraph)
    t = max(1, store.t_cur // 2)
    qs = [Query("point", "node", "degree", t_k=t, v=5),
          Query("point", "global", "num_edges", t_k=t),
          Query("diff", "node", "degree", t_k=t // 2, t_l=t, v=9),
          Query("agg", "node", "degree", t_k=t, t_l=t + 4, v=3,
                agg="mean")]
    got = es.evaluate_many(qs)
    ref = store.evaluate_many(qs, layout="dense")
    assert [_item(a) for a in got] == [_item(b) for b in ref]
    # snapshot_at returns the edge layout; its dense projection matches
    g = es.snapshot_at(t)
    assert isinstance(g, EdgeGraph)
    assert np.array_equal(np.asarray(g.to_dense().adj), bf.adj(t))


# ---------------------------------------------------------------------------
# Per-anchor reconstruction cache
# ---------------------------------------------------------------------------


def test_reconstruction_cache_hits_and_parity(small_history):
    """Repeated point queries at hot timestamps hit the per-anchor LRU
    (counters exposed in last_group_stats) and keep bit parity."""
    store, _ = small_history
    eng = _engine(store)
    eng._snap_cache.clear()
    tc = store.t_cur
    hot = [Query("point", "global", "num_edges", t_k=tc // 2)] * 6 + \
          [Query("point", "node", "degree", t_k=tc // 2, v=5)] * 6
    ref = [_item(r) for r in eng.evaluate_many(
        hot, plan="two_phase", layout="dense")]
    s1 = eng.last_group_stats
    assert s1.cache_misses >= 1
    got = [_item(r) for r in eng.evaluate_many(
        hot, plan="two_phase", layout="dense")]
    s2 = eng.last_group_stats
    assert got == ref
    assert s2.cache_hits >= 1 and s2.cache_misses == 0
    # engine-lifetime counters accumulate
    assert eng.cache_hits >= s2.cache_hits
    # the cached path serves each unique time once per (measure) group
    assert len(s2) == 2


def test_reconstruction_cache_lru_eviction(small_history):
    store, _ = small_history
    eng = _engine(store)
    eng._snap_cache.clear()
    cap = eng.snap_cache_cap
    for t in range(1, cap + 4):
        eng.reconstruct_cached(-1, t)
    assert len(eng._snap_cache) == cap
    # oldest entries evicted, newest retained
    assert (-1, 1, "dense") not in eng._snap_cache
    assert (-1, cap + 3, "dense") in eng._snap_cache


def test_snapshot_at_routes_through_cache(small_history):
    store, _ = small_history
    eng = store.engine()
    eng._snap_cache.clear()
    h0, m0 = eng.cache_hits, eng.cache_misses
    a = store.snapshot_at(store.t_cur // 3)
    b = store.snapshot_at(store.t_cur // 3)
    assert eng.cache_misses == m0 + 1 and eng.cache_hits == h0 + 1
    assert bool(np.all(np.asarray(a.adj) == np.asarray(b.adj)))


def test_edge_store_ingest_after_advance_sees_new_slots():
    """Slots registered after the edge current was built — without
    crossing a pow2 e_cap boundary — must still be visible: both the
    engine path and store.query rebase onto the latest registry."""
    from repro.core.delta import ADD_EDGE, ADD_NODE
    from repro.core.store import Op, TemporalGraphStore
    for n_slots in (3, 4):   # same-pow2 and boundary-crossing growth
        es = TemporalGraphStore(n_cap=8, layout="edge")
        ops = [Op(ADD_NODE, i, i, 1) for i in range(5)]
        ops += [Op(ADD_EDGE, 0, i + 1, 2) for i in range(n_slots)]
        es.ingest(ops)
        es.advance_to(3)
        # new slot registered by a later ingest, no advance_to yet
        es.ingest([Op(ADD_EDGE, 1, 4, 5)])
        q = Query("point", "global", "num_edges", t_k=5)
        assert _item(es.evaluate_many([q])[0]) == n_slots + 1, n_slots
        assert _item(es.query(q, plan="two_phase")) == n_slots + 1, \
            n_slots


def test_cache_path_not_taken_for_large_distinct_time_groups(
        small_history):
    """A stray LRU hit must not demote a distinct-time point batch to
    the sequential per-time loop: with unique times > b/2 and not all
    cached, the vmapped batch kernel runs (one group stat, no new
    cache insertions)."""
    store, _ = small_history
    eng = _engine(store)
    eng._snap_cache.clear()
    tc = store.t_cur
    ts = list(range(1, min(tc, 17)))
    eng.reconstruct_cached(-1, ts[0])          # seed one stray hit
    size_before = len(eng._snap_cache)
    qs = [Query("point", "node", "degree", t_k=t, v=3) for t in ts]
    ref = [_item(r) for r in eng.evaluate_many(
        qs, plan="two_phase", layout="dense")]
    assert eng.last_group_stats.cache_misses == 0
    assert len(eng._snap_cache) == size_before
    # and the values still match per-query evaluation
    single = [_item(store.query(q, plan="two_phase")) for q in qs]
    assert ref == single


# ---------------------------------------------------------------------------
# degree_distribution: edge-layout parity (satellite, PR 4)
# ---------------------------------------------------------------------------


def test_degree_distribution_edge_dense_parity(small_history):
    """The edge-layout histogram (bincount over slot-endpoint degrees
    masked by validity) bit-matches the dense one at every probed time,
    including through the repeated-time cached point path (vector
    measures flow through the LRU too)."""
    from repro.core.queries import (DEGREE_DIST_BINS, EDGE_GLOBAL_MEASURES,
                                    edge_supported)
    store, bf = small_history
    assert "degree_distribution" in EDGE_GLOBAL_MEASURES
    assert edge_supported("degree_distribution", "global")
    eng = _engine(store)
    tc = store.t_cur
    qs = [Query("point", "global", "degree_distribution", t_k=t)
          for t in (1, tc // 4, tc // 2, tc)]
    dense = eng.evaluate_many(qs, layout="dense")
    edge = eng.evaluate_many(qs, layout="edge")
    for d, e, q in zip(dense, edge, qs):
        assert d.shape == (DEGREE_DIST_BINS + 1,)
        assert np.array_equal(np.asarray(d), np.asarray(e)), q
        # brute-force oracle: histogram of the replayed snapshot
        mask, adj = bf.node_mask(q.t_k), bf.adj(q.t_k)
        deg = np.clip(adj[mask].sum(axis=1), 0, DEGREE_DIST_BINS)
        ref = np.bincount(deg.astype(np.int64),
                          minlength=DEGREE_DIST_BINS + 1)
        assert np.array_equal(np.asarray(d), ref), q
    # repeated times route through the reconstruction cache and must
    # carry the vector shape through (regression: cached path assumed
    # scalars)
    hot = [qs[1]] * 6
    eng._snap_cache.clear()
    a = eng.evaluate_many(hot, plan="two_phase", layout="edge")
    b = eng.evaluate_many(hot, plan="two_phase", layout="edge")
    assert eng.last_group_stats.cache_hits >= 1
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))
        assert np.array_equal(np.asarray(x), np.asarray(dense[1]))


# ---------------------------------------------------------------------------
# Reconstruction-cache byte budget (satellite, PR 4)
# ---------------------------------------------------------------------------


def test_snapshot_bytes_sizing(small_history):
    """_snapshot_bytes prices dense entries at N² + N and edge entries
    at (4+4+1)·E + N — the ~64x gap is what lets the byte budget keep
    many more edge-layout entries."""
    from repro.core.engine import _snapshot_bytes
    store, _ = small_history
    eng = _engine(store)
    g_dense = eng.reconstruct_cached(-1, store.t_cur // 2, layout="dense")
    g_edge = eng.reconstruct_cached(-1, store.t_cur // 2, layout="edge")
    n = store.n_cap
    assert _snapshot_bytes(g_dense) == n * n + n
    assert _snapshot_bytes(g_edge) == 9 * g_edge.e_cap + n
    assert _snapshot_bytes(g_edge) < _snapshot_bytes(g_dense)


def test_reconstruction_cache_byte_budget_eviction(small_history):
    """Eviction triggers on snap_cache_bytes even when the entry count
    is far below snap_cache_cap, and the byte counter stays exact
    through evictions."""
    from repro.core.engine import _snapshot_bytes
    store, _ = small_history
    eng = HistoricalQueryEngine.from_store(store)
    per = _snapshot_bytes(store.current)
    eng.snap_cache_bytes = int(2.5 * per)     # fits 2 dense entries
    assert eng.snap_cache_cap >= 8            # count cap must NOT bind
    for t in (1, 2, 3, 4):
        eng.reconstruct_cached(-1, t, layout="dense")
    assert len(eng._snap_cache) == 2
    assert eng._snap_cache_total == 2 * per
    # LRU order: oldest dense entries evicted, newest kept
    assert (-1, 1, "dense") not in eng._snap_cache
    assert (-1, 2, "dense") not in eng._snap_cache
    assert (-1, 4, "dense") in eng._snap_cache
    # a hit refreshes recency and leaves the byte counter untouched
    m0 = eng.cache_misses
    eng.reconstruct_cached(-1, 3, layout="dense")
    assert eng.cache_misses == m0 and eng._snap_cache_total == 2 * per
    eng.reconstruct_cached(-1, 5, layout="dense")
    assert (-1, 3, "dense") in eng._snap_cache      # refreshed survivor
    assert (-1, 4, "dense") not in eng._snap_cache  # LRU victim
    assert eng._snap_cache_total == 2 * per


def test_reconstruction_cache_edge_entries_fit_byte_budget(small_history):
    """Edge-layout entries are E-sized: a budget that holds only two
    dense snapshots holds many edge ones (the sizing asymmetry the
    byte budget exists for)."""
    from repro.core.engine import _snapshot_bytes
    store, _ = small_history
    eng = HistoricalQueryEngine.from_store(store)
    budget = int(2.5 * _snapshot_bytes(store.current))
    eng.snap_cache_bytes = budget
    for t in range(1, 9):
        eng.reconstruct_cached(-1, t, layout="edge")
    per_edge = _snapshot_bytes(eng.current_edge)
    expect = min(8, budget // per_edge)
    assert expect > 2          # strictly more than the 2 dense entries
    assert len(eng._snap_cache) == expect
    assert eng._snap_cache_total == sum(
        _snapshot_bytes(g) for g in eng._snap_cache.values())
    assert eng._snap_cache_total <= budget
