from repro.runtime.elastic import reshard_from_checkpoint, reshard_state
from repro.runtime.failures import (FailureInjector, InjectedFailure,
                                    run_with_recovery)
from repro.runtime.steps import (TrainState, init_train_state,
                                 make_decode_step, make_prefill_step,
                                 make_train_step)
from repro.runtime.stragglers import StragglerPolicy

__all__ = ["TrainState", "init_train_state", "make_train_step",
           "make_prefill_step", "make_decode_step", "FailureInjector",
           "InjectedFailure", "run_with_recovery", "reshard_state",
           "reshard_from_checkpoint", "StragglerPolicy"]
