"""Step builders: train_step / prefill_step / decode_step.

These are the functions the launcher jits with in/out shardings — the
objects the multi-pod dry-run lowers and the roofline reads.  They are
pure: (state, batch) → (state, metrics).

Microbatching: gradient accumulation via ``lax.scan`` over microbatch
slices — the scan body contains both the microbatch's backward matmuls
and the accumulation add, which is what lets XLA's latency-hiding
scheduler overlap the DP reduce of microbatch k with compute of k+1.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShardingConfig, TrainConfig
from repro.models import api
from repro.optim import adamw_update, lr_schedule
from repro.optim.adamw import AdamWState


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    scfg: ShardingConfig) -> Callable:
    """(TrainState, batch) → (TrainState, metrics)."""

    def loss_of(params, mb):
        return api.loss_fn(params, mb, cfg, remat=scfg.remat,
                           impl=scfg.attn_impl)

    def train_step(state: TrainState, batch):
        if tcfg.microbatches > 1:
            n = tcfg.microbatches

            def slice_mb(x, i):
                b = x.shape[0] // n
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

            def body(acc, i):
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                l, g = jax.value_and_grad(loss_of)(state.params, mb)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(jnp.add, acc_g, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zero_g),
                jnp.arange(n, dtype=jnp.int32))
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(state.params, batch)

        lr = lr_schedule(state.step + 1, tcfg)  # 1-indexed: warmup
        # fraction 1/W on the first step, never exactly zero
        params, opt, stats = adamw_update(grads, state.opt, state.params,
                                          tcfg, lr)
        new_state = TrainState(params=params, opt=opt,
                               step=state.step + 1)
        metrics = {"loss": loss, **stats}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_cap: int | None = None,
                      impl: str = "xla"):
    def prefill_step(params, batch):
        logits, caches = api.prefill(params, batch, cfg,
                                     cache_cap=cache_cap, impl=impl)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, caches, token, pos):
        logits, caches = api.decode_step(params, token, pos, caches, cfg)
        return logits, caches

    return decode_step


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig,
                     dtype=None) -> TrainState:
    from repro.optim.adamw import adamw_init
    dtype = dtype or (jnp.bfloat16 if tcfg.param_dtype == "bfloat16"
                      else jnp.float32)
    params = api.init_params(key, cfg, dtype)
    return TrainState(params=params, opt=adamw_init(params, tcfg),
                      step=jnp.int32(0))
