"""Straggler mitigation bookkeeping.

In an SPMD step there is no per-worker skipping — the mitigation levers
at 1000+ nodes are (a) deadline-based microbatch shedding: if the host
loop observes step latency above a deadline, reduce the microbatch
count for subsequent steps (gradient accumulation is elastic — the
effective batch shrinks, the optimizer scales loss by actual
microbatches); (b) flagging persistently slow pods for exclusion at the
next elastic restart (runtime/elastic.py).

On one host we implement the *policy* (latency EWMA + deadline + shed /
restore decisions) and test it with synthetic latencies; the decisions
feed TrainConfig.microbatches between (jitted) steps, which is a
recompile-free knob when the shed factor divides the batch.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StragglerPolicy:
    deadline_ms: float          # per-step budget
    ewma: float = 0.2
    min_microbatches: int = 1
    restore_after: int = 20     # healthy steps before restoring

    def __post_init__(self):
        self._lat = None
        self._healthy = 0

    def observe(self, step_ms: float, microbatches: int) -> int:
        """Feed one step latency; returns the microbatch count to use
        next step."""
        self._lat = (step_ms if self._lat is None
                     else (1 - self.ewma) * self._lat
                     + self.ewma * step_ms)
        if self._lat > self.deadline_ms and \
                microbatches > self.min_microbatches:
            self._healthy = 0
            return max(self.min_microbatches, microbatches // 2)
        if self._lat <= 0.8 * self.deadline_ms:
            self._healthy += 1
            if self._healthy >= self.restore_after:
                self._healthy = 0
                return microbatches * 2
        return microbatches
