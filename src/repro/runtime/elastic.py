"""Elastic scaling: reshard a training state onto a different mesh.

Checkpoints are logical (host arrays keyed by tree path — io.py), so a
restore onto a new mesh is: load → device_put with the new mesh's
NamedShardings (sharding/param_specs re-resolves logical axes against
the new axis sizes, dropping what no longer divides).  The same path
serves planned rescales (mesh grown/shrunk between jobs) and unplanned
ones (restart excluding a failed pod: the (2,16,16) job re-lands on
(16,16)).
"""
from __future__ import annotations

import jax

from repro.sharding import named_shardings


def reshard_state(state, mesh):
    """Place every leaf of ``state`` per the param rules on ``mesh``."""
    sh = named_shardings(state, mesh)
    return jax.tree.map(jax.device_put, state, sh)


def reshard_from_checkpoint(store, step, template, mesh):
    state = store.restore(step, template)
    return reshard_state(state, mesh)
