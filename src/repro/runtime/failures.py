"""Failure injection + recovery harness.

On a real cluster, node failure surfaces as a raised exception from the
collective runtime (or a coordinator timeout).  The training driver's
contract is: any step may raise; recovery = reconstruct the last logged
state from the DeltaCheckpointStore (paper Theorem 1 — nearest
materialized snapshot + delta chain) and resume from its step counter.
The synthetic-data pipeline is stateless, so the token stream continues
exactly.

``FailureInjector`` makes that path testable on one host.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises InjectedFailure at the given steps (once each)."""
    fail_at: tuple[int, ...] = ()

    def __post_init__(self):
        self._pending = set(self.fail_at)

    def check(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            raise InjectedFailure(f"injected node failure at step {step}")


def run_with_recovery(train_loop: Callable[[int], int], store,
                      template, max_restarts: int = 10) -> int:
    """Drive ``train_loop(start_step) -> final_step`` with restart-on-
    failure semantics.  ``train_loop`` must checkpoint into ``store``;
    on failure we restore the latest logged state and re-enter."""
    restarts = 0
    start = 0
    while True:
        try:
            return train_loop(start)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = store.latest_step()
            if latest is None:
                start = 0
            else:
                start = latest
