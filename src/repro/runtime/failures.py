"""Failure injection + recovery harness for the training loop.

On a real cluster, node failure surfaces as a raised exception from the
collective runtime (or a coordinator timeout).  The training driver's
contract is: any step may raise; recovery = reconstruct the last logged
state from the DeltaCheckpointStore (paper Theorem 1 — nearest
materialized snapshot + delta chain) and resume from its step counter.
The synthetic-data pipeline is stateless, so the token stream continues
exactly.

``FailureInjector`` makes that path testable on one host.  It is the
training-loop face of the shared fault-injection layer
(``repro.replica.faults``) — the replication chaos tests use the same
``FaultInjector`` core for torn writes, bit flips, dropped/delayed
transfers, and EIO, so one seeded schedule drives every failure mode
in the repo.
"""
from __future__ import annotations

from typing import Callable

from repro.replica.faults import FaultInjector, FaultRule, InjectedFault


class InjectedFailure(InjectedFault):
    pass


class FailureInjector(FaultInjector):
    """Raises InjectedFailure at the given steps (once each)."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = tuple(fail_at)
        super().__init__([FaultRule(point="step", kind="raise",
                                    at=self.fail_at, exc=InjectedFailure)])

    def check(self, step: int) -> None:   # noqa: D401 — legacy signature
        super().check("step", value=step)

    @property
    def _pending(self) -> set:
        """Steps scheduled but not yet fired (legacy test surface)."""
        return set().union(set(), *(r._at_pending for r in self.rules
                                    if r.point == "step"))


def run_with_recovery(train_loop: Callable[[int], int], store,
                      template, max_restarts: int = 10) -> int:
    """Drive ``train_loop(start_step) -> final_step`` with restart-on-
    failure semantics.  ``train_loop`` must checkpoint into ``store``;
    on failure we restore the latest logged state and re-enter."""
    restarts = 0
    start = 0
    while True:
        try:
            return train_loop(start)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = store.latest_step()
            if latest is None:
                start = 0
            else:
                start = latest
