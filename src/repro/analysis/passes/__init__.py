"""Built-in graphlint passes.  Importing this package registers every
pass with ``repro.analysis.registry`` (each module's ``@register``
decorator fires at import)."""
from repro.analysis.passes import clock_discipline  # noqa: F401
from repro.analysis.passes import epoch_immutability  # noqa: F401
from repro.analysis.passes import jax_hotpath  # noqa: F401
from repro.analysis.passes import lock_discipline  # noqa: F401
from repro.analysis.passes import wal_ordering  # noqa: F401
