"""epoch-freeze: frozen-epoch state is written only by its owners.

Sealed ``Segment``s, merged tree nodes, and each epoch's
``SegmentedDeltaView`` are immutable by contract — a frozen engine
serves from them while the next swap builds the successor, and the
bit-exact watermark guarantee assumes nothing it reads ever changes.
The owners of that state are ``core/segments.py`` (seal, spill/reload,
residency) and ``core/store.py`` (tail building, freeze): only they may
write it.  Any other module assigning or mutating through a
segment/view receiver is either a correctness bug (mutating state an
in-flight epoch serves from) or a layering violation that will become
one.

Heuristic receiver matching (static Python has no types): an
expression mutates frozen-epoch state when the receiver *looks like* a
segment/view (variable or attribute named ``seg``/``segment``/
``view``/``node``/``merged``, or a ``.segments[...]`` element) and the
attribute written is one of the view/segment internals.  Precision
over recall — the runtime contract tests remain the backstop.
"""
from __future__ import annotations

import ast

from repro.analysis.base import (Finding, LintPass, ParsedFile,
                                 attr_chain)
from repro.analysis.registry import register

#: who may write frozen-epoch state
OWNER_SUFFIXES = ("core/segments.py", "core/store.py")

#: receiver names that read as a segment / view / tree node
RECEIVER_HINTS = frozenset({"seg", "segment", "view", "node", "merged",
                            "segments"})

#: segment/view fields that define the frozen state
WATCHED_ATTRS = frozenset({
    "segments", "merged", "ops", "op", "u", "v", "t", "slot",
    "t_min", "t_max", "n_ops", "span",
    "_cache", "_full", "_delta", "_host", "_node_ops_sum",
    "_tmin", "_tmax", "_cum",
})

_MUTATORS = frozenset({
    "append", "extend", "insert", "pop", "popitem", "clear", "update",
    "add", "remove", "discard", "setdefault", "fill", "sort",
})


def _receiver_is_epoch_state(recv: ast.AST) -> bool:
    """True when ``recv`` syntactically reads as segment/view state."""
    chain = attr_chain(recv)
    if chain:
        tail = [p for p in chain if p != "self"]
        if tail and tail[-1] in RECEIVER_HINTS:
            return True
        return False
    # segments[i].attr — a Subscript receiver over a hinted name
    if isinstance(recv, ast.Subscript):
        inner = attr_chain(recv.value)
        return bool(inner) and inner[-1] in RECEIVER_HINTS
    return False


@register
class EpochImmutabilityPass(LintPass):
    name = "epoch-immutability"
    description = ("writes to frozen-epoch state (Segment fields, "
                   "SegmentedDeltaView internals) outside the seal/"
                   "swap owners core/segments.py and core/store.py")
    rules = ("epoch-freeze",)

    def applies(self, pf: ParsedFile) -> bool:
        return not any(pf.endswith(sfx) for sfx in OWNER_SUFFIXES)

    def check_file(self, pf: ParsedFile) -> list[Finding]:
        out: list[Finding] = []

        def _flag(attr: str, recv: ast.AST, line: int,
                  how: str) -> None:
            if attr in WATCHED_ATTRS and _receiver_is_epoch_state(recv):
                out.append(self.finding(
                    "epoch-freeze", pf, line,
                    f"{how} of frozen-epoch state .{attr} — sealed "
                    "segments and epoch views are immutable; only "
                    "core/segments.py and core/store.py (seal/swap "
                    "owners) may write them"))

        def _check_target(t: ast.AST, how: str) -> None:
            if isinstance(t, (ast.Tuple, ast.List)):
                for el in t.elts:
                    _check_target(el, how)
                return
            if isinstance(t, ast.Starred):
                _check_target(t.value, how)
                return
            if isinstance(t, ast.Subscript):
                # seg.u[...] = x  — element store into a watched field
                if isinstance(t.value, ast.Attribute):
                    _flag(t.value.attr, t.value.value, t.lineno,
                          "element store into")
                return
            if isinstance(t, ast.Attribute):
                _flag(t.attr, t.value, t.lineno, "assignment")

        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    _check_target(t, "assignment")
            elif isinstance(node, ast.AugAssign):
                _check_target(node.target, "assignment")
            elif isinstance(node, ast.AnnAssign) \
                    and node.value is not None:
                _check_target(node.target, "assignment")
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    _check_target(t, "deletion")
            elif isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _MUTATORS \
                        and isinstance(node.func.value, ast.Attribute):
                    _flag(node.func.value.attr, node.func.value.value,
                          node.lineno, f"in-place {node.func.attr}()")
        return out
