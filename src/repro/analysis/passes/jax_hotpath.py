"""host-sync / jit-unhashable-default: JAX hot-path hygiene.

A single ``float(jnp_value)`` in the dispatch path stalls the device
pipeline: conversion forces a blocking device→host transfer, turning an
async program launch into a synchronous round trip (the PR 3 edge-path
speedups came in part from deleting exactly these).  And a jitted
function with an unhashable (mutable) default argument either crashes
at trace time (static arg) or silently retraces per call.

Rules, scoped to the modules where device values live —
``core/engine.py``, ``core/distributed.py``, ``kernels/``, ``models/``
and ``runtime/``:

* ``host-sync`` — per-function taint analysis.  Sources: calls rooted
  at ``jnp``/``lax``/``pl``/``pltpu``, parameters of jit-decorated
  functions, and attribute reads that read as device arrays (delta/
  graph array fields).  Attribute access, subscripts, arithmetic and
  assignment propagate taint.  Sinks: ``float()``/``int()``/``bool()``
  /``np.asarray()``/``np.array()`` over a tainted value, and
  ``.item()``/``.tolist()`` on a tainted receiver.

* ``jit-unhashable-default`` — a function decorated with ``jax.jit``
  (bare or via ``functools.partial``) whose signature carries a
  mutable default (list/dict/set literal or constructor).

Heuristic (no type inference); suppress justified one-time host copies
with ``# graphlint: ignore[host-sync] <why>``.
"""
from __future__ import annotations

import ast

from repro.analysis.base import (Finding, LintPass, ParsedFile,
                                 attr_chain)
from repro.analysis.registry import register

_SCOPE_SUFFIXES = ("core/engine.py", "core/distributed.py")
_SCOPE_DIRS = ("kernels", "models", "runtime")

#: call roots whose results live on device
_DEVICE_ROOTS = frozenset({"jnp", "lax", "pl", "pltpu"})
#: jax.* constructors that return device values (jax.jit handled apart)
_JAX_DEVICE_FUNCS = frozenset({"vmap", "pmap", "grad", "value_and_grad",
                               "checkpoint", "remat"})

#: (receiver hint, attr) pairs that read as device-array fields
_DEVICE_RECEIVERS = frozenset({"delta", "graph", "anchor", "snap",
                               "current"})
_DEVICE_ATTRS = frozenset({"op", "u", "v", "slot", "t", "adj", "emask",
                           "eu", "ev", "deg", "mask"})

_CONVERTERS = frozenset({"float", "int", "bool", "complex"})
_MUTABLE_DEFAULTS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)
_MUTABLE_CTORS = frozenset({"list", "dict", "set", "bytearray"})


def _is_jit_decorator(dec: ast.AST) -> bool:
    chain = attr_chain(dec)
    if chain in (("jax", "jit"), ("jit",)):
        return True
    if isinstance(dec, ast.Call):
        fchain = attr_chain(dec.func)
        if fchain in (("jax", "jit"), ("jit",)):
            return True
        if fchain and fchain[-1] == "partial" and dec.args:
            return attr_chain(dec.args[0]) in (("jax", "jit"), ("jit",))
    return False


class _Taint:
    """Flow-insensitive per-function taint: names assigned (anywhere in
    the function) from a device-valued expression are tainted."""

    def __init__(self, fn: ast.FunctionDef, jitted: bool):
        self.names: set[str] = set()
        if jitted:
            args = fn.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs):
                self.names.add(a.arg)
            for a in (args.vararg, args.kwarg):
                if a is not None:
                    self.names.add(a.arg)
        # fixpoint over assignments
        changed = True
        while changed:
            changed = False
            for node in ast.walk(fn):
                targets: list[ast.AST] = []
                value = None
                if isinstance(node, ast.Assign):
                    targets, value = node.targets, node.value
                elif isinstance(node, ast.AnnAssign) \
                        and node.value is not None:
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.AugAssign):
                    targets, value = [node.target], node.value
                if value is None or not self.tainted(value):
                    continue
                for t in targets:
                    for name in _target_names(t):
                        if name not in self.names:
                            self.names.add(name)
                            changed = True

    def tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if chain and chain[0] in _DEVICE_ROOTS:
                return True
            if len(chain) == 2 and chain[0] == "jax" \
                    and chain[1] in _JAX_DEVICE_FUNCS:
                return True
            # method call on a tainted receiver stays on device
            # (x.sum(), x.astype(...)) — except the sinks themselves
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr not in ("item", "tolist") \
                    and self.tainted(node.func.value):
                return True
            return False
        if isinstance(node, ast.Attribute):
            chain = attr_chain(node)
            if chain:
                hints = [p for p in chain[:-1] if p != "self"]
                if hints and hints[-1] in _DEVICE_RECEIVERS \
                        and chain[-1] in _DEVICE_ATTRS:
                    return True
            return self.tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.tainted(node.value)
        if isinstance(node, ast.BinOp):
            return self.tainted(node.left) or self.tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.tainted(node.operand)
        if isinstance(node, ast.Compare):
            return (self.tainted(node.left)
                    or any(self.tainted(c) for c in node.comparators))
        if isinstance(node, ast.IfExp):
            return self.tainted(node.body) or self.tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.tainted(e) for e in node.elts)
        return False


def _target_names(t: ast.AST):
    if isinstance(t, ast.Name):
        yield t.id
    elif isinstance(t, (ast.Tuple, ast.List)):
        for el in t.elts:
            yield from _target_names(el)
    elif isinstance(t, ast.Starred):
        yield from _target_names(t.value)


@register
class JaxHotPathPass(LintPass):
    name = "jax-hotpath"
    description = ("implicit device→host syncs (float/int/bool/"
                   "np.asarray/.item on JAX values) and unhashable "
                   "jit default args in engine/distributed/kernels/"
                   "models/runtime")
    rules = ("host-sync", "jit-unhashable-default")

    def applies(self, pf: ParsedFile) -> bool:
        if any(pf.endswith(sfx) for sfx in _SCOPE_SUFFIXES):
            return True
        return pf.in_dir(*_SCOPE_DIRS) and "repro" in pf.relparts

    def check_file(self, pf: ParsedFile) -> list[Finding]:
        out: list[Finding] = []
        for fn in ast.walk(pf.tree):
            if not isinstance(fn, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)):
                continue
            jitted = any(_is_jit_decorator(d) for d in fn.decorator_list)
            if jitted:
                out.extend(self._check_defaults(pf, fn))
            out.extend(self._check_syncs(pf, fn, jitted))
        return out

    def _check_defaults(self, pf: ParsedFile,
                        fn: ast.FunctionDef) -> list[Finding]:
        out = []
        defaults = list(fn.args.defaults) + [
            d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            bad = isinstance(d, _MUTABLE_DEFAULTS) or (
                isinstance(d, ast.Call)
                and attr_chain(d.func) in
                tuple((n,) for n in _MUTABLE_CTORS))
            if bad:
                out.append(self.finding(
                    "jit-unhashable-default", pf, d.lineno,
                    f"jitted function {fn.name}() has a mutable "
                    "default argument — unhashable as a static arg "
                    "and a retrace-per-call trap otherwise; use None "
                    "or a tuple"))
        return out

    def _check_syncs(self, pf: ParsedFile, fn: ast.FunctionDef,
                     jitted: bool) -> list[Finding]:
        out = []
        taint = _Taint(fn, jitted)
        where = "inside jitted " if jitted else "in "
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            # float(x) / int(x) / bool(x) / np.asarray(x) on device vals
            conv = None
            if len(chain) == 1 and chain[0] in _CONVERTERS:
                conv = chain[0]
            elif chain in (("np", "asarray"), ("np", "array"),
                           ("numpy", "asarray"), ("numpy", "array")):
                conv = ".".join(chain)
            if conv and node.args and taint.tainted(node.args[0]):
                out.append(self.finding(
                    "host-sync", pf, node.lineno,
                    f"{conv}() over a device value {where}"
                    f"{fn.name}() forces a blocking device→host sync "
                    "— keep it on device (jnp) or hoist the transfer "
                    "off the hot path"))
                continue
            # .item() / .tolist() on a tainted receiver
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("item", "tolist") \
                    and taint.tainted(node.func.value):
                out.append(self.finding(
                    "host-sync", pf, node.lineno,
                    f".{node.func.attr}() on a device value {where}"
                    f"{fn.name}() forces a blocking device→host sync"))
        return out
