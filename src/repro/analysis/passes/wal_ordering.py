"""wal-order: no ack-visible mutation may precede its WAL append.

The durability contract (PR 7) is WAL-before-ack: an op the serving
layer buffers (and will acknowledge) must already be in the write-ahead
log, and a sealed-segment artifact may only be written once the WAL
record that pins its cut is durable.  A refactor that swaps the two
lines compiles, passes every non-crash test, and silently breaks the
bit-exact recovery guarantee — exactly the class of bug a kill -9 test
eventually catches and this pass catches immediately.

Rule: in ``serving/ingest.py`` and ``persist/`` (minus ``wal.py``, the
log's own implementation), any function that performs a WAL append must
perform it before — in execution-order AST walk — every ack-visible
mutation in that function:

* buffer growth: ``*pending*.append/extend/insert`` or ``+=``
* durable artifact writes: ``save_segment_file(...)``

Pure drains (rebinding the buffer, slicing it down) are not acks and
are not flagged.  Functions with no WAL call are out of scope — the
in-memory configuration buffers without logging by design.
"""
from __future__ import annotations

import ast

from repro.analysis.base import (Finding, LintPass, ParsedFile,
                                 attr_chain)
from repro.analysis.registry import register

_WAL_METHODS = frozenset({
    "log_pending", "log_drain", "log_ops", "log_advance", "log_seal",
})
_GROW = frozenset({"append", "extend", "insert"})
_ARTIFACT_WRITES = frozenset({"save_segment_file"})


def _is_wal_call(chain: tuple[str, ...]) -> bool:
    if not chain:
        return False
    if chain[-1] in _WAL_METHODS:
        return True
    return (chain[-1] == "append"
            and any(("wal" in part and "pending" not in part)
                    for part in chain[:-1]))


def _is_ack_event(node: ast.AST) -> str | None:
    """A human-readable description when ``node`` makes state ack-visible."""
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if not chain:
            return None
        if chain[-1] in _ARTIFACT_WRITES:
            return f"artifact write {chain[-1]}()"
        if chain[-1] in _GROW and len(chain) >= 2 \
                and "pending" in chain[-2].lower():
            return f"buffer growth {'.'.join(chain)}()"
    if isinstance(node, ast.AugAssign):
        chain = attr_chain(node.target)
        if chain and "pending" in chain[-1].lower():
            return f"buffer growth {'.'.join(chain)} +="
    return None


class _OrderWalker(ast.NodeVisitor):
    """Execution-ordered event collection for one function body."""

    def __init__(self) -> None:
        self.events: list[tuple[str, str, int]] = []  # (kind, desc, line)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass                            # stay out of nested defs

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_ClassDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        desc = _is_ack_event(node)
        if desc:
            self.events.append(("ack", desc, node.lineno))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        chain = attr_chain(node.func)
        if _is_wal_call(chain):
            self.events.append(("wal", ".".join(chain), node.lineno))
        else:
            desc = _is_ack_event(node)
            if desc:
                self.events.append(("ack", desc, node.lineno))
        self.generic_visit(node)


@register
class WalOrderingPass(LintPass):
    name = "wal-ordering"
    description = ("WAL-before-ack: in serving/ingest.py and persist/, "
                   "buffer growth and artifact writes must follow the "
                   "function's WAL append")
    rules = ("wal-order",)

    def applies(self, pf: ParsedFile) -> bool:
        if pf.endswith("serving/ingest.py"):
            return True
        return pf.in_dir("persist") and not pf.endswith("persist/wal.py")

    def check_file(self, pf: ParsedFile) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(pf.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            walker = _OrderWalker()
            for st in node.body:
                walker.visit(st)
            events = walker.events
            first_wal = next((i for i, (k, _, _) in enumerate(events)
                              if k == "wal"), None)
            if first_wal is None:
                continue                # no WAL in this function
            for kind, desc, line in events[:first_wal]:
                if kind == "ack":
                    out.append(self.finding(
                        "wal-order", pf, line,
                        f"{desc} in {node.name}() is reachable before "
                        f"the WAL append at line "
                        f"{events[first_wal][2]} — log first, then "
                        "make the state ack-visible"))
        return out
