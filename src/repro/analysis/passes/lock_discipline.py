"""lock-discipline: static lock-order graph + unlocked shared mutations.

Two rules over the whole analyzed tree:

* ``lock-order`` — build a static lock-acquisition graph.  Lock
  *classes* are (owning python class, attribute) pairs discovered from
  ``self.X = threading.Lock()/RLock()/Condition()`` assignments (plus
  module-level ``X = threading.Lock()``).  Within each method, ``with``
  items and ``.acquire()``/``.release()`` calls maintain a held set;
  acquiring B while holding A adds the edge A→B.  Calls to sibling
  methods propagate the callee's (transitively) acquired locks, so
  ``swap()`` holding ``_swap_lock`` and calling a helper that takes
  ``_lock`` contributes ``_swap_lock→_lock``.  A cycle in the edge
  graph is a potential AB/BA deadlock; a self-edge on a non-reentrant
  ``threading.Lock`` is a guaranteed one.

* ``unlocked-mutation`` — in any class that owns at least one lock,
  mutations of known shared-state attributes (``_delta_cache``, epoch/
  engine pointers, registry maps, pending buffers, caches) must happen
  while some lock is held.  Helper methods whose every intra-class call
  site holds a lock are clean; a lock-free call site (or a lock-free
  public mutation) is flagged.  Classes without locks are skipped —
  single-writer components (the store mutates only on the swap thread)
  are serialized by their OWNER's lock, which is exactly the convention
  this rule encodes.

Static and heuristic by design: the runtime companion
(``repro.analysis.lockdep``) watches the orders that actually happen.
"""
from __future__ import annotations

import ast

from repro.analysis.base import (Finding, LintPass, ParsedFile,
                                 attr_chain)
from repro.analysis.registry import register

#: attributes treated as shared mutable state when their class has a lock
WATCHED_SHARED = frozenset({
    "_delta_cache", "_engine", "_pending", "_queue", "_cache", "_full",
    "_families", "_children", "_w", "_segments", "_replicas",
    "_swap_listeners", "_node_ops_sum",
})

#: method calls that mutate their receiver in place
MUTATORS = frozenset({
    "append", "extend", "insert", "pop", "popitem", "clear", "update",
    "add", "remove", "discard", "setdefault", "move_to_end", "sort",
})

#: ctor-phase methods: the object is not yet shared
EXEMPT_METHODS = frozenset({"__init__", "__post_init__", "__new__"})

_LOCK_FACTORIES = {
    ("threading", "Lock"): "lock",
    ("threading", "RLock"): "rlock",
    ("threading", "Condition"): "rlock",   # RLock-backed by default
}


def _lock_kind(value: ast.AST) -> str | None:
    """'lock'/'rlock' when ``value`` is a threading lock constructor."""
    if not isinstance(value, ast.Call):
        return None
    return _LOCK_FACTORIES.get(attr_chain(value.func))


class _Mutation:
    __slots__ = ("attr", "line", "held")

    def __init__(self, attr: str, line: int, held: bool):
        self.attr, self.line, self.held = attr, line, held


class _Call:
    __slots__ = ("callee", "line", "held_keys")

    def __init__(self, callee: str, line: int, held_keys: tuple):
        self.callee, self.line, self.held_keys = callee, line, held_keys


class _Acquire:
    __slots__ = ("key", "line", "under")

    def __init__(self, key: str, line: int, under: tuple):
        self.key, self.line, self.under = key, line, under


class _MethodFacts:
    def __init__(self) -> None:
        self.acquires: list[_Acquire] = []
        self.calls: list[_Call] = []
        self.mutations: list[_Mutation] = []


class _ClassModel:
    def __init__(self, name: str, pf: ParsedFile):
        self.name = name
        self.pf = pf
        self.locks: dict[str, str] = {}           # attr -> kind
        self.methods: dict[str, _MethodFacts] = {}


class _MethodWalker:
    """Execution-ordered walk of one function body, tracking which lock
    keys are held (with-statements plus linear acquire/release)."""

    def __init__(self, model: _ClassModel, module_locks: dict[str, str],
                 facts: _MethodFacts):
        self.model = model
        self.module_locks = module_locks
        self.facts = facts
        self.held: list[str] = []

    # ------------------------------------------------------ lock keys

    def _key_of(self, expr: ast.AST) -> str | None:
        chain = attr_chain(expr)
        if len(chain) == 2 and chain[0] == "self" \
                and chain[1] in self.model.locks:
            return f"{self.model.name}.{chain[1]}"
        if len(chain) == 1 and chain[0] in self.module_locks:
            return f"<module>.{chain[0]}"
        return None

    def _kind_of(self, key: str) -> str:
        attr = key.split(".", 1)[1]
        if key.startswith("<module>."):
            return self.module_locks.get(attr, "lock")
        return self.model.locks.get(attr, "lock")

    def _acquire(self, key: str, line: int) -> None:
        self.facts.acquires.append(
            _Acquire(key, line, tuple(self.held)))
        self.held.append(key)

    # ----------------------------------------------------- statements

    def walk(self, stmts: list[ast.stmt]) -> None:
        for st in stmts:
            self._stmt(st)

    def _stmt(self, st: ast.stmt) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return                      # nested defs analyzed separately
        if isinstance(st, (ast.With, ast.AsyncWith)):
            entered: list[str] = []
            for item in st.items:
                self._expr(item.context_expr)
                key = self._key_of(item.context_expr)
                if key is not None:
                    self._acquire(key, item.context_expr.lineno)
                    entered.append(key)
            self.walk(st.body)
            for key in reversed(entered):
                if key in self.held:
                    self.held.remove(key)
            return
        if isinstance(st, (ast.If, ast.While)):
            self._expr(st.test)
            self.walk(st.body)
            self.walk(st.orelse)
            return
        if isinstance(st, (ast.For, ast.AsyncFor)):
            self._expr(st.iter)
            self.walk(st.body)
            self.walk(st.orelse)
            return
        if isinstance(st, ast.Try):
            self.walk(st.body)
            for h in st.handlers:
                self.walk(h.body)
            self.walk(st.orelse)
            self.walk(st.finalbody)
            return
        if isinstance(st, ast.Match):
            self._expr(st.subject)
            for case in st.cases:
                self.walk(case.body)
            return
        # flat statement: mutations + calls inside, in one sweep
        self._flat(st)

    def _flat(self, st: ast.stmt) -> None:
        held = bool(self.held)
        for attr, line in _mutations_in(st):
            if attr in WATCHED_SHARED:
                self.facts.mutations.append(_Mutation(attr, line, held))
        self._expr(st)

    def _expr(self, node: ast.AST) -> None:
        """Scan an expression/statement subtree for calls: explicit
        acquire()/release(), and intra-class method calls."""
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            chain = attr_chain(sub.func)
            if len(chain) == 3 and chain[0] == "self" \
                    and chain[2] in ("acquire", "release") \
                    and chain[1] in self.model.locks:
                key = f"{self.model.name}.{chain[1]}"
                if chain[2] == "acquire":
                    self._acquire(key, sub.lineno)
                elif key in self.held:
                    self.held.remove(key)
                continue
            if len(chain) == 2 and chain[0] == "self" \
                    and chain[1] not in self.model.locks:
                self.facts.calls.append(
                    _Call(chain[1], sub.lineno, tuple(self.held)))


def _mutations_in(st: ast.stmt):
    """Yield (attr, line) for every self.<attr> mutation in a flat
    statement: assignment, aug-assign, subscript store, delete, and
    in-place mutator calls."""

    def _target_attrs(t: ast.AST):
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                yield from _target_attrs(el)
            return
        if isinstance(t, (ast.Subscript, ast.Starred)):
            yield from _target_attrs(t.value)
            return
        chain = attr_chain(t)
        if len(chain) == 2 and chain[0] == "self":
            yield chain[1], t.lineno

    if isinstance(st, ast.Assign):
        for t in st.targets:
            yield from _target_attrs(t)
    elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
        if not (isinstance(st, ast.AnnAssign) and st.value is None):
            yield from _target_attrs(st.target)
    elif isinstance(st, ast.Delete):
        for t in st.targets:
            yield from _target_attrs(t)
    for sub in ast.walk(st):
        if isinstance(sub, ast.Call):
            chain = attr_chain(sub.func)
            if len(chain) == 3 and chain[0] == "self" \
                    and chain[2] in MUTATORS:
                yield chain[1], sub.lineno


@register
class LockDisciplinePass(LintPass):
    name = "lock-discipline"
    description = ("static lock-order graph (AB/BA inversions, "
                   "self-deadlocks) + shared-state mutations outside "
                   "any lock in lock-owning classes")
    rules = ("lock-order", "unlocked-mutation")

    def run(self, files: list[ParsedFile]) -> list[Finding]:
        models: list[_ClassModel] = []
        for pf in files:
            models.extend(self._collect(pf))
        out: list[Finding] = []
        out.extend(self._check_order(models))
        for model in models:
            out.extend(self._check_mutations(model))
        return out

    # ------------------------------------------------------- collection

    def _collect(self, pf: ParsedFile) -> list[_ClassModel]:
        module_locks: dict[str, str] = {}
        for st in pf.tree.body:
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name):
                kind = _lock_kind(st.value)
                if kind:
                    module_locks[st.targets[0].id] = kind
        models = []
        for node in ast.walk(pf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            model = _ClassModel(node.name, pf)
            methods = [m for m in node.body
                       if isinstance(m, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
            # sweep 1: lock attribute discovery (any method, any depth)
            for m in methods:
                for sub in ast.walk(m):
                    value = None
                    target = None
                    if isinstance(sub, ast.Assign) \
                            and len(sub.targets) == 1:
                        target, value = sub.targets[0], sub.value
                    elif isinstance(sub, ast.AnnAssign):
                        target, value = sub.target, sub.value
                    if value is None:
                        continue
                    kind = _lock_kind(value)
                    chain = attr_chain(target)
                    if kind and len(chain) == 2 and chain[0] == "self":
                        model.locks[chain[1]] = kind
            # sweep 2: per-method facts
            for m in methods:
                facts = _MethodFacts()
                walker = _MethodWalker(model, module_locks, facts)
                walker.walk(m.body)
                model.methods[m.name] = facts
            models.append(model)
        return models

    # ------------------------------------------------------- lock order

    def _check_order(self, models: list[_ClassModel]) -> list[Finding]:
        # transitive closure of per-method acquired locks via self-calls
        closure: dict[tuple[str, str], set[str]] = {}
        for model in models:
            for mname, facts in model.methods.items():
                closure[(model.name, mname)] = {
                    a.key for a in facts.acquires}
        changed = True
        while changed:
            changed = False
            for model in models:
                for mname, facts in model.methods.items():
                    mine = closure[(model.name, mname)]
                    for call in facts.calls:
                        callee = closure.get((model.name, call.callee))
                        if callee and not callee <= mine:
                            mine |= callee
                            changed = True

        edges: dict[str, dict[str, tuple[ParsedFile, int]]] = {}
        kinds: dict[str, str] = {}

        def _edge(a: str, b: str, pf: ParsedFile, line: int) -> None:
            edges.setdefault(a, {}).setdefault(b, (pf, line))
            edges.setdefault(b, {})

        for model in models:
            for attr, kind in model.locks.items():
                kinds[f"{model.name}.{attr}"] = kind
            for facts in model.methods.values():
                for acq in facts.acquires:
                    for held in acq.under:
                        _edge(held, acq.key, model.pf, acq.line)
                for call in facts.calls:
                    for lk in closure.get((model.name, call.callee), ()):
                        for held in call.held_keys:
                            # held == lk is a re-entry self-edge; the
                            # self-edge check below flags it only for
                            # non-reentrant Lock kinds
                            _edge(held, lk, model.pf, call.line)

        out: list[Finding] = []
        # self-edges on non-reentrant locks: guaranteed self-deadlock
        for a, succ in edges.items():
            if a in succ and kinds.get(a, "lock") == "lock":
                pf, line = succ[a]
                out.append(self.finding(
                    "lock-order", pf, line,
                    f"nested acquisition of non-reentrant lock {a} "
                    "(self-deadlock; use an RLock or restructure)"))
        # cycles across distinct locks: potential AB/BA inversion
        for cyc in _cycles(edges):
            members = set(cyc)
            wits = []
            anchor: tuple[ParsedFile, int] | None = None
            for a in cyc:
                for b, (pf, line) in sorted(edges[a].items()):
                    if b in members and b != a:
                        wits.append(
                            f"{a}->{b} at {pf.module_key()}:{line}")
                        if anchor is None:
                            anchor = (pf, line)
            if anchor is None:
                continue
            out.append(self.finding(
                "lock-order", anchor[0], anchor[1],
                "potential lock-order inversion between "
                + ", ".join(cyc) + ": " + " ; ".join(wits)))
        return out

    # ------------------------------------------- unlocked shared state

    def _check_mutations(self, model: _ClassModel) -> list[Finding]:
        if not model.locks:
            return []
        out: list[Finding] = []
        locks_txt = ", ".join(sorted(model.locks))
        dirty: dict[str, list[_Mutation]] = {}
        for mname, facts in model.methods.items():
            if mname in EXEMPT_METHODS:
                continue
            unlocked = [mu for mu in facts.mutations if not mu.held]
            if unlocked:
                dirty[mname] = unlocked
        for mname, muts in dirty.items():
            # every intra-class call site holding a lock launders the
            # helper clean; a lock-free call site is the finding
            sites = [(caller, c) for caller, f in model.methods.items()
                     for c in f.calls if c.callee == mname]
            if sites and all(c.held_keys for _, c in sites):
                continue
            bad_sites = [(caller, c) for caller, c in sites
                         if not c.held_keys]
            if bad_sites and mname.startswith("_"):
                for caller, c in bad_sites:
                    attrs = ", ".join(sorted({mu.attr for mu in muts}))
                    out.append(self.finding(
                        "unlocked-mutation", model.pf, c.line,
                        f"{model.name}.{caller} calls {mname}() which "
                        f"mutates shared {attrs!r} without holding any "
                        f"of this class's locks ({locks_txt})"))
                continue
            for mu in muts:
                out.append(self.finding(
                    "unlocked-mutation", model.pf, mu.line,
                    f"{model.name}.{mname} mutates shared "
                    f"{mu.attr!r} outside any lock (class owns "
                    f"{locks_txt})"))
        return out


def _cycles(edges: dict[str, dict[str, tuple]]) -> list[list[str]]:
    """Distinct simple cycles (as SCC member lists, length ≥ 2)."""
    index: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    counter = [0]
    sccs: list[list[str]] = []

    def strongconnect(v: str) -> None:
        # iterative Tarjan
        work = [(v, iter(edges.get(v, ())))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(edges.get(w, ()))))
                    advanced = True
                    break
                if w in on_stack:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == node:
                        break
                if len(scc) > 1:
                    sccs.append(sorted(scc))

    for v in sorted(edges):
        if v not in index:
            strongconnect(v)
    return sccs
