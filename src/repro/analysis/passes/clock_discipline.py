"""clock: library code times through ``repro.obs.clock``, nothing else.

One sanctioned timer (``repro.obs.clock.now`` — swappable in tests, one
place to change) keeps every histogram, trace span and swap-phase
measurement on the same clock.  Bare ``time.perf_counter()`` was
ci_lint's original grep rule; this pass is its AST-accurate port, also
covering ``time.time()`` (wall clock drifts under NTP — wrong for
durations and unorderable across hosts) and ``datetime.now()``/
``utcnow()``.  Scope: ``src/repro`` outside ``obs/`` (the module that
defines the clock is the one place allowed to touch the primitives);
scripts and benchmarks are standalone tools and stay free.
"""
from __future__ import annotations

import ast

from repro.analysis.base import (Finding, LintPass, ParsedFile,
                                 attr_chain)
from repro.analysis.registry import register

_TIME_FUNCS = frozenset({"perf_counter", "perf_counter_ns", "time"})
_DT_CHAINS = (
    ("datetime", "now"), ("datetime", "utcnow"),
    ("datetime", "datetime", "now"), ("datetime", "datetime", "utcnow"),
    ("date", "today"), ("datetime", "date", "today"),
)


@register
class ClockDisciplinePass(LintPass):
    name = "clock-discipline"
    description = ("bare time.perf_counter()/time.time()/datetime.now() "
                   "in src/repro outside obs/ — use repro.obs.clock.now()")
    rules = ("clock",)

    def applies(self, pf: ParsedFile) -> bool:
        parts = pf.relparts
        if "repro" not in parts:
            return False
        after = parts[parts.index("repro") + 1:]
        return "obs" not in after

    def check_file(self, pf: ParsedFile) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Call):
                chain = attr_chain(node.func)
                if chain[:1] == ("time",) and len(chain) == 2 \
                        and chain[1] in _TIME_FUNCS:
                    out.append(self.finding(
                        "clock", pf, node.lineno,
                        f"bare {'.'.join(chain)}() — time through "
                        "repro.obs.clock.now() (one clock, swappable "
                        "in tests)"))
                elif chain in _DT_CHAINS:
                    out.append(self.finding(
                        "clock", pf, node.lineno,
                        f"{'.'.join(chain)}() — wall-clock reads in "
                        "library code; use repro.obs.clock.now() for "
                        "durations (stamp wall time at the edges only)"))
            elif isinstance(node, ast.ImportFrom) \
                    and node.module == "time":
                bad = [a.name for a in node.names
                       if a.name in _TIME_FUNCS]
                if bad:
                    out.append(self.finding(
                        "clock", pf, node.lineno,
                        f"from time import {', '.join(bad)} — aliased "
                        "timers dodge the clock rule; use "
                        "repro.obs.clock.now()"))
        return out
