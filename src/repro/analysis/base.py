"""graphlint core: findings, parsed files, suppressions, the pass base.

The repo's correctness story rests on conventions no type checker sees:
WAL-before-ack, drain-logged swaps, frozen-epoch immutability, lock-
guarded registries, device values staying on device through the hot
path.  ``graphlint`` makes those conventions mechanical — each pass is
a small AST analysis that understands ONE invariant and flags code that
can break it.  Zero dependencies: everything here is ``ast`` + stdlib.

Suppression: a finding is silenced by a comment on the flagged line
(or on a comment-only line directly above it)::

    self.t_host = np.asarray(delta.t)  # graphlint: ignore[host-sync] one-time planning copy

The bracket names the RULE id (or the pass name, or ``*``); text after
the bracket is the required justification.  Suppressed findings are
still counted and reported by the CLI — a suppression is a documented
exception, not a deletion.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
import tokenize

__all__ = [
    "Finding", "ParsedFile", "LintPass", "Suppression",
    "attr_chain", "call_name", "parse_file", "parse_source",
]

_SUPPRESS_RE = re.compile(
    r"#\s*graphlint:\s*ignore\[([^\]]*)\]\s*(.*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str           # rule id, e.g. "lock-order" (suppression key)
    path: str           # path as given to the driver
    line: int           # 1-based
    message: str
    severity: str = "error"      # "error" | "warning"
    pass_name: str = ""          # owning pass (alternate suppression key)

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.severity}"
                f"[{self.rule}] {self.message}")


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: tuple[str, ...]       # rule ids / pass names / "*"
    reason: str

    def matches(self, finding: Finding) -> bool:
        return any(r in ("*", finding.rule, finding.pass_name)
                   for r in self.rules)


class ParsedFile:
    """One source file: text, AST, and the suppression map.

    ``relparts`` is the normalized path split on separators — what
    passes scope on (suffix / component matching, so fixture trees in
    temp dirs scope exactly like the real repo layout).
    """

    def __init__(self, path: str, text: str, tree: ast.AST):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = tree
        self.relparts = tuple(
            p for p in re.split(r"[\\/]+", os.path.normpath(path)) if p)
        self.suppressions = _collect_suppressions(text)

    # ------------------------------------------------------------ helpers

    def suppression_for(self, finding: Finding) -> Suppression | None:
        sup = self.suppressions.get(finding.line)
        if sup is not None and sup.matches(finding):
            return sup
        return None

    def in_dir(self, *names: str) -> bool:
        """True when any of ``names`` appears as a path component."""
        return any(n in self.relparts for n in names)

    def endswith(self, suffix: str) -> bool:
        """Suffix match on path components: ``endswith("serving/ingest.py")``."""
        want = tuple(p for p in suffix.split("/") if p)
        return self.relparts[-len(want):] == want

    def module_key(self) -> str:
        """Last two components — 'serving/ingest.py' — for messages."""
        return "/".join(self.relparts[-2:])


def _collect_suppressions(text: str) -> dict[int, Suppression]:
    """Map line -> Suppression.  A comment-only line's suppression also
    covers the next non-blank line (for statements too long to carry an
    end-of-line comment)."""
    out: dict[int, Suppression] = {}
    pending: Suppression | None = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        stripped = raw.strip()
        m = _SUPPRESS_RE.search(raw)
        if m:
            rules = tuple(r.strip() for r in m.group(1).split(",")
                          if r.strip())
            sup = Suppression(lineno, rules, m.group(2).strip())
            out[lineno] = sup
            if stripped.startswith("#"):
                pending = sup          # standalone: covers next stmt line
            continue
        if pending is not None and stripped:
            out.setdefault(lineno, dataclasses.replace(pending,
                                                       line=lineno))
            pending = None
    return out


def parse_source(path: str, text: str) -> ParsedFile:
    return ParsedFile(path, text, ast.parse(text, filename=path))


def parse_file(path: str) -> ParsedFile:
    with tokenize.open(path) as fh:    # honors coding declarations
        return parse_source(path, fh.read())


# --------------------------------------------------------------- AST utils

def attr_chain(node: ast.AST) -> tuple[str, ...]:
    """('self', '_wal', 'append') for ``self._wal.append`` — empty tuple
    when the expression isn't a pure Name/Attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def call_name(node: ast.Call) -> tuple[str, ...]:
    """The callee's attribute chain (may be empty for computed calls)."""
    return attr_chain(node.func)


class LintPass:
    """Base pass: subclass, set ``name``/``description``, implement
    ``check_file`` (or override ``run`` for cross-file analyses) and
    register with ``repro.analysis.registry.register``.  ``rules``
    names every rule id the pass can emit (the CLI catalog)."""

    name: str = ""
    description: str = ""
    rules: tuple[str, ...] = ()

    def applies(self, pf: ParsedFile) -> bool:
        return True

    def check_file(self, pf: ParsedFile) -> list[Finding]:
        return []

    def run(self, files: list[ParsedFile]) -> list[Finding]:
        out: list[Finding] = []
        for pf in files:
            if self.applies(pf):
                out.extend(self.check_file(pf))
        return out

    # helper so passes stamp their own name consistently
    def finding(self, rule: str, pf: ParsedFile, line: int,
                message: str, severity: str = "error") -> Finding:
        return Finding(rule=rule, path=pf.path, line=line,
                       message=message, severity=severity,
                       pass_name=self.name)
