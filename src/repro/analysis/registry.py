"""Pass registry: passes self-register at import, the driver resolves
names (``--select``) against it.  Importing ``repro.analysis.passes``
pulls in every built-in pass exactly once."""
from __future__ import annotations

from repro.analysis.base import LintPass

__all__ = ["register", "all_passes", "create_passes", "rule_catalog"]

_PASSES: dict[str, type[LintPass]] = {}


def register(cls: type[LintPass]) -> type[LintPass]:
    if not cls.name:
        raise ValueError(f"pass {cls.__name__} has no name")
    if _PASSES.get(cls.name) not in (None, cls):
        raise ValueError(f"duplicate pass name {cls.name!r}")
    _PASSES[cls.name] = cls
    return cls


def _load_builtin() -> None:
    # registration side effect; idempotent
    import repro.analysis.passes  # noqa: F401


def all_passes() -> dict[str, type[LintPass]]:
    _load_builtin()
    return dict(_PASSES)


def create_passes(select: list[str] | None = None) -> list[LintPass]:
    """Instantiate passes — all of them, or the ``select`` subset (by
    pass name or by a rule id a pass owns)."""
    avail = all_passes()
    if not select:
        return [cls() for cls in avail.values()]
    out: list[LintPass] = []
    for name in select:
        cls = avail.get(name)
        if cls is None:
            cls = next((c for c in avail.values() if name in c.rules),
                       None)
        if cls is None:
            known = sorted(avail)
            raise KeyError(f"unknown pass/rule {name!r} (known passes: "
                           f"{', '.join(known)})")
        if cls not in [type(p) for p in out]:
            out.append(cls())
    return out


def rule_catalog() -> list[tuple[str, str, str]]:
    """(pass name, rule id, description) rows for --list / docs."""
    rows = []
    for name, cls in sorted(all_passes().items()):
        for rule in cls.rules:
            rows.append((name, rule, cls.description))
    return rows
