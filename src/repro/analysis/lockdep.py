"""Runtime lock-order sanitizer (the dynamic half of graphlint).

The static ``lock-discipline`` pass proves ordering over the lock
graph it can see; this module validates the orders that actually
happen at runtime.  When enabled, ``threading.Lock``/``RLock`` are
replaced with thin wrappers that record, per thread, the chain of
locks currently held and fold every observed *held → acquiring* pair
into a global order graph keyed by lock *class* (the source location
that created the lock — all locks born at one ``threading.Lock()``
call site are instances of one class, mirroring how Linux lockdep
groups locks).  The moment an acquisition would close a cycle in that
graph — thread 1 took A then B, thread 2 now holds B and asks for A —
``LockOrderError`` is raised *before* the inner acquire, so the test
fails deterministically instead of deadlocking intermittently.

Opt in per process::

    from repro.analysis import lockdep
    lockdep.enable()          # patch threading.Lock / threading.RLock
    ...
    lockdep.disable()         # restore + clear the order graph

or for test runs: ``pytest --lockdep`` / ``GRAPHLINT_LOCKDEP=1``
(see ``tests/conftest.py``).

Notes on fidelity:

* RLock re-entry is not an edge (same-class self-acquire while the
  same instance is already held by this thread is legal re-entry).
* A non-reentrant Lock re-acquired by its holder is an immediate
  self-deadlock; reported as a one-node cycle.
* Same-class nesting of *distinct* instances (e.g. two registry
  entries created at one call site, locked pairwise) is tolerated: a
  self-edge on a class is only an error for same-instance Lock
  re-entry, since instance-level order can be consistent (by address,
  by id) even when class-level order is trivially cyclic.
* ``threading.Condition()`` with no argument builds its RLock via the
  patched factory and works unchanged: the wrapper exposes
  ``acquire/release/locked/__enter__/__exit__`` plus the
  ``_is_owned/_acquire_restore/_release_save`` trio Condition uses,
  with ``wait()``'s release-reacquire kept visible to the bookkeeping
  (held chains stay truthful across a wait).
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional, Set, Tuple

__all__ = [
    "LockOrderError", "enable", "disable", "enabled", "reset",
    "order_graph", "TrackedLock",
]

# the *real* primitives, captured at import before any patching
_RealLock = threading.Lock
_RealRLock = threading.RLock

# site key -> ordinal, so lock-class names are stable and readable
_SiteKey = Tuple[str, int]


class LockOrderError(RuntimeError):
    """An acquisition would create a cycle in the observed lock order."""


class _State:
    """Global sanitizer state (order graph + patch bookkeeping)."""

    def __init__(self) -> None:
        # guards the order graph; a real lock, never tracked
        self.graph_lock = _RealLock()
        # class -> class edges; value maps successor -> witness string
        self.order: Dict[str, Dict[str, str]] = {}
        self.enabled = False
        self.local = threading.local()

    def held(self) -> list:
        chain = getattr(self.local, "chain", None)
        if chain is None:
            chain = self.local.chain = []
        return chain


_STATE = _State()


def _site_name(depth_hint: int = 2) -> str:
    """Lock class = the source line that constructed it."""
    import sys
    f = sys._getframe(depth_hint)
    # walk out of this module so the class names a caller line
    here = os.path.dirname(os.path.abspath(__file__))
    while f is not None and os.path.dirname(
            os.path.abspath(f.f_code.co_filename)) == here:
        f = f.f_back
    if f is None:  # pragma: no cover - interpreter-internal creation
        return "<unknown>"
    fn = os.path.relpath(f.f_code.co_filename, os.getcwd()) \
        if f.f_code.co_filename.startswith(os.getcwd()) \
        else os.path.basename(f.f_code.co_filename)
    return f"{fn}:{f.f_lineno}"


def _path_exists(order: Dict[str, Dict[str, str]],
                 src: str, dst: str) -> Optional[list]:
    """DFS: return a class path src -> ... -> dst if one exists."""
    stack = [(src, [src])]
    seen: Set[str] = set()
    while stack:
        node, path = stack.pop()
        if node == dst:
            return path
        if node in seen:
            continue
        seen.add(node)
        for nxt in order.get(node, ()):  # noqa: PERF102 - need keys
            stack.append((nxt, path + [nxt]))
    return None


class TrackedLock:
    """Drop-in ``threading.Lock``/``RLock`` with order tracking."""

    __slots__ = ("_inner", "_reentrant", "_cls", "_owner", "_count")

    def __init__(self, reentrant: bool, cls: Optional[str] = None):
        self._inner = _RealRLock() if reentrant else _RealLock()
        self._reentrant = reentrant
        self._cls = cls if cls is not None else _site_name()
        self._owner: Optional[int] = None
        self._count = 0

    # ------------------------------------------------------------- core
    def _check_before_acquire(self, blocking: bool = True) -> None:
        st = _STATE
        if not st.enabled:
            return
        me = threading.get_ident()
        chain = st.held()
        if self._owner == me:
            if self._reentrant:
                return  # legal re-entry, no new edge
            if blocking:
                raise LockOrderError(
                    f"self-deadlock: thread re-acquiring non-"
                    f"reentrant Lock [{self._cls}] it already holds")
            return  # try-acquire just fails, it can't deadlock
        if not chain:
            return
        with st.graph_lock:
            for held in chain:
                if held is self:
                    continue
                a, b = held._cls, self._cls
                if a == b:
                    # distinct same-class instances: instance-level
                    # order may be consistent; don't edge the class
                    # onto itself (would always cycle)
                    continue
                back = _path_exists(st.order, b, a)
                if back is not None and blocking:
                    first = st.order.get(b, {}).get(
                        back[1] if len(back) > 1 else a, "?")
                    raise LockOrderError(
                        "lock-order inversion: acquiring "
                        f"[{b}] while holding [{a}], but the reverse "
                        f"order {' -> '.join(back)} was already "
                        f"observed (first at {first})")
                st.order.setdefault(a, {}).setdefault(
                    b, f"thread {me}")

    def _note_acquired(self) -> None:
        me = threading.get_ident()
        if self._owner == me:
            self._count += 1
            return
        self._owner = me
        self._count = 1
        if _STATE.enabled:
            _STATE.held().append(self)

    def _note_released(self) -> None:
        self._count -= 1
        if self._count > 0:
            return
        self._owner = None
        self._count = 0
        chain = _STATE.held()
        if self in chain:
            chain.remove(self)

    # -------------------------------------------------- Lock interface
    def acquire(self, blocking: bool = True, timeout: float = -1):
        self._check_before_acquire(blocking)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._note_acquired()
        return got

    def release(self) -> None:
        self._note_released()
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._owner is not None

    # --------------------------------- Condition(RLock) compatibility
    def _is_owned(self) -> bool:
        return self._owner == threading.get_ident()

    def _release_save(self):
        """Condition.wait(): drop the lock wholesale, report depth."""
        count = self._count
        self._count = 1  # force _note_released to fully drop
        self._note_released()
        for _ in range(count):
            self._inner.release()
        return count

    def _acquire_restore(self, count: int) -> None:
        for _ in range(count):
            self._inner.acquire()
        self._note_acquired()
        self._count = count

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        kind = "RLock" if self._reentrant else "Lock"
        return f"<TrackedLock {kind} [{self._cls}] owner={self._owner}>"


def _make_lock() -> TrackedLock:
    return TrackedLock(reentrant=False)


def _make_rlock() -> TrackedLock:
    return TrackedLock(reentrant=True)


def enable() -> None:
    """Patch ``threading.Lock``/``RLock`` and start tracking."""
    if _STATE.enabled:
        return
    reset()
    threading.Lock = _make_lock  # type: ignore[misc,assignment]
    threading.RLock = _make_rlock  # type: ignore[misc,assignment]
    _STATE.enabled = True


def disable() -> None:
    """Restore the real primitives and clear the order graph."""
    threading.Lock = _RealLock  # type: ignore[misc]
    threading.RLock = _RealRLock  # type: ignore[misc]
    _STATE.enabled = False
    reset()


def enabled() -> bool:
    return _STATE.enabled


def reset() -> None:
    """Forget every observed edge (between tests)."""
    with _STATE.graph_lock:
        _STATE.order.clear()
    _STATE.local = threading.local()


def order_graph() -> Dict[str, Dict[str, str]]:
    """Snapshot of the observed order graph (class -> successors)."""
    with _STATE.graph_lock:
        return {k: dict(v) for k, v in _STATE.order.items()}
