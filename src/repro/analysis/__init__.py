"""graphlint: repo-native static analysis + runtime sanitizers.

The stack's correctness invariants — WAL-before-ack, frozen-epoch
immutability, lock-guarded shared state, device values staying on
device — hold by convention; this package checks them mechanically.

* ``repro.analysis.driver.analyze_paths`` — run every registered pass
  over a file tree (what ``scripts/graphlint.py`` and CI call).
* ``repro.analysis.registry`` — the pass registry (``@register``).
* ``repro.analysis.lockdep`` — the opt-in runtime lock-order sanitizer
  (enable with ``pytest --lockdep`` or ``GRAPHLINT_LOCKDEP=1``).
"""
from repro.analysis.base import Finding, LintPass, ParsedFile
from repro.analysis.driver import Report, analyze_files, analyze_paths
from repro.analysis.registry import all_passes, create_passes, register

__all__ = [
    "Finding", "LintPass", "ParsedFile", "Report",
    "analyze_files", "analyze_paths",
    "all_passes", "create_passes", "register",
]
