"""graphlint driver: collect files, run passes, apply suppressions.

The CLI (``scripts/graphlint.py``) and the CI gate both come through
``analyze_paths``; tests drive ``analyze_files`` with in-memory
sources.  A file that fails to parse yields a single ``parse-error``
finding instead of aborting the run — the syntax gate proper stays
ruff/compileall's job (``scripts/ci_lint.py``).
"""
from __future__ import annotations

import dataclasses
import os

from repro.analysis.base import Finding, ParsedFile, parse_file
from repro.analysis.registry import create_passes

__all__ = ["Report", "analyze_paths", "analyze_files", "collect_files"]

_SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", ".pytest_cache",
              "node_modules", ".venv"}


@dataclasses.dataclass
class Report:
    """Outcome of one analysis run."""

    findings: list[Finding]              # active (unsuppressed)
    suppressed: list[tuple[Finding, str]]  # (finding, reason)
    files: int

    @property
    def ok(self) -> bool:
        return not self.findings

    def counts_by_rule(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def suppressed_by_rule(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f, _reason in self.suppressed:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def render_text(self, *, verbose_suppressed: bool = False) -> str:
        lines = [f.render() for f in
                 sorted(self.findings, key=lambda f: (f.path, f.line))]
        if verbose_suppressed:
            for f, reason in sorted(self.suppressed,
                                    key=lambda fr: (fr[0].path,
                                                    fr[0].line)):
                lines.append(f"{f.path}:{f.line}: suppressed[{f.rule}]"
                             f" {reason or '(no reason given)'}")
        n_sup = len(self.suppressed)
        sup_counts = self.suppressed_by_rule()
        sup_txt = ("" if not n_sup else " (" + ", ".join(
            f"{r}: {n}" for r, n in sorted(sup_counts.items())) + ")")
        lines.append(
            f"graphlint: {len(self.findings)} finding"
            f"{'s' if len(self.findings) != 1 else ''}, "
            f"{n_sup} suppressed{sup_txt}, {self.files} files")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "suppressed": [dict(dataclasses.asdict(f), reason=r)
                           for f, r in self.suppressed],
            "files": self.files,
            "ok": self.ok,
        }


def collect_files(paths: list[str]) -> list[str]:
    """Expand files/directories into a sorted .py file list."""
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for dirpath, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            out.extend(os.path.join(dirpath, f) for f in sorted(files)
                       if f.endswith(".py"))
    # stable order, duplicates dropped
    seen: set[str] = set()
    uniq = []
    for p in out:
        if p not in seen:
            seen.add(p)
            uniq.append(p)
    return uniq


def analyze_files(files: list[ParsedFile],
                  select: list[str] | None = None,
                  parse_errors: list[Finding] | None = None) -> Report:
    passes = create_passes(select)
    raw: list[Finding] = list(parse_errors or [])
    for ps in passes:
        raw.extend(ps.run(files))
    by_path = {pf.path: pf for pf in files}
    active: list[Finding] = []
    suppressed: list[tuple[Finding, str]] = []
    for f in raw:
        pf = by_path.get(f.path)
        sup = pf.suppression_for(f) if pf is not None else None
        if sup is not None:
            suppressed.append((f, sup.reason))
        else:
            active.append(f)
    return Report(findings=active, suppressed=suppressed,
                  files=len(files))


def analyze_paths(paths: list[str],
                  select: list[str] | None = None) -> Report:
    files: list[ParsedFile] = []
    parse_errors: list[Finding] = []
    for path in collect_files(paths):
        try:
            files.append(parse_file(path))
        except SyntaxError as exc:
            parse_errors.append(Finding(
                rule="parse-error", path=path, line=exc.lineno or 1,
                message=f"file does not parse: {exc.msg}",
                pass_name="driver"))
    return analyze_files(files, select, parse_errors)
