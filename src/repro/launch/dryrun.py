import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes, with no array allocation (everything is
ShapeDtypeStructs), and extract the roofline inputs:

  compiled.cost_analysis()    → per-device FLOPs / bytes accessed
  compiled.memory_analysis()  → per-device HBM footprint
  compiled.as_text()          → collective operand bytes (parsed)

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]

Results land in benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json —
the EXPERIMENTS.md tables are generated from these.
"""
import argparse
import dataclasses
import json
from repro.obs import clock
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import SHAPES, ShardingConfig, TrainConfig
from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (collective_bytes, model_flops,
                                   roofline_terms, scan_aware_metrics)
from repro.models import api
from repro.runtime.steps import (init_train_state, make_decode_step,
                                 make_prefill_step, make_train_step)
from repro.sharding import (logical_rules, mesh_context, param_specs,
                            resolve, spec)

RESULTS = os.path.join(os.path.dirname(__file__),
                       "../../../benchmarks/results/dryrun")


# ---------------------------------------------------------------------------
# Sharding specs for the dry-run inputs
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    out = []
    for p in path:
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                out.append(str(getattr(p, attr)))
                break
        else:
            out.append(str(p))
    return "/".join(out)


def batch_sharding(tree, mesh):
    with mesh_context(mesh):
        def one(leaf):
            parts = [resolve("batch", leaf.shape[0])]
            parts += [None] * (len(leaf.shape) - 1)
            return NamedSharding(mesh, P(*parts))
        return jax.tree.map(one, tree)


def cache_sharding(tree, mesh):
    """Stacked decode caches: [layer-groups, batch, ...].  Batch shards
    over (pod, data) when divisible; otherwise (long_500k: batch=1) the
    KV sequence dim shards over data (flash-decode style)."""
    with mesh_context(mesh):
        def one(path, leaf):
            name = _path_str(path)
            dims = [None] * len(leaf.shape)
            if len(leaf.shape) < 2:
                return NamedSharding(mesh, P(*dims))
            b = leaf.shape[1]
            ax = resolve("batch", b)
            dims[1] = ax
            if ax is None and (name.endswith("/k") or name.endswith("/v")
                               or name.endswith("/xk")
                               or name.endswith("/xv")):
                dims[2] = resolve("kv_seq", leaf.shape[2])
            # shard heads/state over model where divisible
            if name.endswith(("/k", "/v", "/xk", "/xv")) \
                    and len(leaf.shape) == 5:
                dims[3] = resolve("model", leaf.shape[3])
            if name.endswith("/state") and len(leaf.shape) == 5:
                dims[2] = resolve("model", leaf.shape[2])
            return NamedSharding(mesh, P(*dims))
        return jax.tree_util.tree_map_with_path(one, tree)


def state_sharding(state_shapes, mesh):
    with mesh_context(mesh):
        specs = param_specs(state_shapes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

# Named sharding-rule experiments for §Perf hillclimbing. Values
# override sharding.LOGICAL_RULES for the duration of one cell.
RULESETS: dict[str, dict] = {
    # Small models: give the model axis to the batch (pure DP-256),
    # ZeRO-3 everything over both axes. Kills the unsharded-attention
    # blowup when n_heads doesn't divide the model axis. Axis order
    # (data, model, pod): batch 256 = data×model exactly on both
    # meshes; pod (multi-pod) goes to ZeRO instead.
    "dp_all": {"batch": ("data", "model", "pod"), "model": (),
               "expert": (), "fsdp": ("pod", "data", "model"),
               "moe_fsdp": ("pod", "data", "model")},
    # Big MoE: true expert parallelism — expert weights sharded over
    # (pod, model) and NOT gathered (no ZeRO on expert weights);
    # dispatch buffers shard capacity over data. Dense params keep
    # ZeRO-3 over (pod, data).
    "ep_moe": {"expert": ("pod", "model"), "moe_fsdp": (),
               "moe_cap": ("data",), "fsdp": ("pod", "data")},
    # Small-expert-count MoE (mixtral: 8 experts on a 16-way axis):
    # keep experts whole, TP the per-expert FF dim over model, shard
    # dispatch capacity over data. No ZeRO on expert weights.
    "moe_tp": {"moe_ff": ("model",), "moe_cap": ("data",),
               "moe_fsdp": ()},
    # dp_all + expert-parallel dispatch (combined experiment)
    "dp_all_moe": {"batch": ("pod", "data", "model"), "model": (),
                   "fsdp": ("data", "model"),
                   "expert": ("model",), "moe_fsdp": (),
                   "moe_cap": ("data",)},
}


# Per-arch production defaults (hillclimb winners — EXPERIMENTS §Perf).
# --rules overrides; "baseline" forces the naive GSPMD configuration.
DEFAULT_RULES: dict[str, str | None] = {
    "smollm-360m": "dp_all",      # 15 heads don't divide model=16: TP off
    "whisper-small": "dp_all",    # 12 heads
    "internvl2-1b": "dp_all",     # 14 heads
    "gemma-2b": "dp_all",         # 8 heads
    "mixtral-8x7b": "moe_tp",     # 8 experts: TP the expert FF instead
    "kimi-k2-1t-a32b": "ep_moe",  # 384 experts: EP, never gather weights
}


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             fsdp_pod: bool | None = None, rules_name: str | None = None,
             remat: str | None = None, attn_impl: str = "xla"):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    if shape_name == "long_500k" and not cfg.is_subquadratic():
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped":
                "full-attention arch; long_500k needs sub-quadratic "
                "attention (DESIGN.md §5)"}

    big = cfg.name in ("kimi-k2-1t-a32b", "jamba-1.5-large-398b")
    fsdp_pod = big if fsdp_pod is None else fsdp_pod
    tcfg = TrainConfig(global_batch=shape.global_batch,
                       seq_len=shape.seq_len,
                       opt_state_dtype="int8" if big else "float32")
    scfg = ShardingConfig(fsdp=True, fsdp_pod=fsdp_pod,
                          remat=remat or "block", attn_impl=attn_impl)
    rules = {}
    if fsdp_pod:
        rules["fsdp"] = ("pod", "data")
    if rules_name is None:
        rules_name = DEFAULT_RULES.get(arch)
    if rules_name and rules_name != "baseline":
        rules.update(RULESETS[rules_name])
    t0 = clock.now()

    with logical_rules(**rules):
        if shape.kind == "train":
            step = make_train_step(cfg, tcfg, scfg)
            state_shapes = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg))
            batch_shapes = api.input_specs(cfg, shape)
            in_sh = (state_sharding(state_shapes, mesh),
                     batch_sharding(batch_shapes, mesh))
            args = (state_shapes, batch_shapes)
            fn = step
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, impl=attn_impl)
            params_shapes = jax.eval_shape(
                lambda: api.init_params(jax.random.PRNGKey(0), cfg,
                                        jnp.bfloat16))
            batch_shapes = api.input_specs(cfg, shape)
            in_sh = (state_sharding(params_shapes, mesh),
                     batch_sharding(batch_shapes, mesh))
            args = (params_shapes, batch_shapes)
            fn = step
        else:  # decode
            step = make_decode_step(cfg)
            params_shapes = jax.eval_shape(
                lambda: api.init_params(jax.random.PRNGKey(0), cfg,
                                        jnp.bfloat16))
            cache_shapes = jax.eval_shape(
                lambda: api.init_decode_caches(cfg, shape.global_batch,
                                               shape.seq_len))
            io_shapes = api.input_specs(cfg, shape)
            in_sh = (state_sharding(params_shapes, mesh),
                     cache_sharding(cache_shapes, mesh),
                     batch_sharding({"token": io_shapes["token"]},
                                    mesh)["token"],
                     NamedSharding(mesh, P()))
            args = (params_shapes, cache_shapes, io_shapes["token"],
                    io_shapes["pos"])
            fn = step

        with mesh_context(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            t_lower = clock.now() - t0
            compiled = lowered.compile()
            t_compile = clock.now() - t0 - t_lower

    from repro.models.blocks import n_groups as _ng
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    sa = scan_aware_metrics(hlo, default_trips=_ng(cfg))

    flops = float(sa["flops"])
    bytes_acc = float(sa["bytes"])
    terms = roofline_terms(flops, bytes_acc, sa["coll_bytes"])
    raw_terms = roofline_terms(float(cost.get("flops", 0.0)),
                               float(cost.get("bytes accessed", 0.0)),
                               coll["total"])
    mf = model_flops(cfg, shape)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # scan-aware (primary; while bodies × trip count)
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": float(sa["coll_bytes"]),
        "roofline": terms,
        # raw cost_analysis (loop bodies counted once) for reference
        "raw_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collective": coll,
            "roofline": raw_terms,
        },
        "memory_analysis": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if mem is not None and hasattr(mem, k)},
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else None,
    }
    return result


def save_result(res: dict, tag: str = "") -> str:
    mesh_dir = res.get("mesh", "16x16") + (f"__{tag}" if tag else "")
    d = os.path.abspath(os.path.join(RESULTS, mesh_dir))
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{res['arch']}__{res['shape']}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--rules", default=None,
                    choices=list(RULESETS) + ["baseline"])
    ap.add_argument("--remat", default=None,
                    choices=["none", "block", "full"])
    ap.add_argument("--attn", default="xla",
                    choices=["xla", "xla_flash"])
    args = ap.parse_args()
    if not args.tag:
        parts = [p for p in (args.rules,
                             args.attn if args.attn != "xla" else None,
                             args.remat) if p]
        args.tag = "_".join(parts)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for (a, s) in cells:
        mesh_dir = ("2x16x16" if args.multi_pod else "16x16") + \
            (f"__{args.tag}" if args.tag else "")
        out = os.path.abspath(os.path.join(
            RESULTS, mesh_dir, f"{a}__{s}.json"))
        if args.skip_done and os.path.exists(out):
            print(f"[skip] {a} × {s}")
            continue
        print(f"[cell] {a} × {s} multi_pod={args.multi_pod} "
              f"rules={args.rules} remat={args.remat}", flush=True)
        try:
            res = run_cell(a, s, multi_pod=args.multi_pod,
                           rules_name=args.rules, remat=args.remat,
                           attn_impl=args.attn)
            path = save_result(res, args.tag)
            if "skipped" in res:
                print(f"  -> skipped: {res['skipped']}")
            else:
                r = res["roofline"]
                print(f"  -> ok in {res['compile_s']}s compile | "
                      f"compute {r['compute_s']:.3e}s memory "
                      f"{r['memory_s']:.3e}s coll {r['collective_s']:.3e}s"
                      f" dominant={r['dominant']} ({path})", flush=True)
        except Exception as e:
            print(f"  -> FAIL {type(e).__name__}: {e}")
            traceback.print_exc()
            res = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}"}
            save_result(res, args.tag)


if __name__ == "__main__":
    main()
