"""Roofline analysis from compiled dry-run artifacts.

Hardware model (TPU v5e-like, per chip):
  peak bf16 compute  : 197 TFLOP/s
  HBM bandwidth      : 819 GB/s
  ICI                : ~50 GB/s per link

Terms (per-device program — cost_analysis of the SPMD-partitioned
module is already per-device):
  compute    = flops / PEAK_FLOPS
  memory     = bytes_accessed / HBM_BW
  collective = collective_operand_bytes / ICI_BW

collective bytes are parsed from the compiled per-device HLO: the sum
of *operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (two-pass parse: instruction table →
operand lookup).
"""
from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^)=]*?\)?)\s*"
                       r"([\w\-]+)\(", re.M)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum of operand bytes per collective kind, from compiled HLO."""
    # pass 1: instruction table name -> result bytes
    table: dict[str, int] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        name, type_str, _op = m.group(1), m.group(2), m.group(3)
        table[name] = shape_bytes(type_str)

    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        # operands: %refs inside the call parens on this line
        line_start = m.end()
        line_end = hlo_text.find("\n", line_start)
        args = hlo_text[line_start:line_end]
        args = args.split(")")[0]
        operand_bytes = 0
        for ref in re.findall(r"%([\w\.\-]+)", args):
            operand_bytes += table.get(ref, 0)
        if operand_bytes == 0:  # operands not resolvable: use result
            operand_bytes = shape_bytes(type_str)
        out[kind] += operand_bytes
        counts[kind] += 1
    out_total = sum(out.values())
    return {"per_kind": out, "counts": counts, "total": out_total}


# ---------------------------------------------------------------------------
# Scan-aware HLO analysis
# ---------------------------------------------------------------------------
#
# XLA's HloCostAnalysis counts a while-loop body ONCE — with scan-over-
# layers that understates per-step work by n_layers×.  We therefore
# re-derive the roofline inputs from the compiled HLO text:
#   * per-computation dot FLOPs (2 · prod(result dims) · prod(contract)),
#   * per-computation top-level bytes (fusion-internal ops excluded —
#     fusions count as one op with operand+result bytes, matching the
#     HBM-traffic model),
#   * per-computation collective operand bytes,
# then roll up: entry ×1, while bodies × trip count (parsed from the
# loop-condition constant), computations called by fusions/reducers ×0.

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->",
                      re.M)
_FULL_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^)=]*?\)?)\s*([\w\-]+)"
    r"\((.*)$", re.M)


def _split_computations(text: str) -> dict[str, str]:
    """computation name -> body text."""
    comps = {}
    cur = None
    buf: list[str] = []
    for line in text.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*->.*\{\s*$",
                     line)
        if m:
            if cur is not None:
                comps[cur] = "\n".join(buf)
            cur = m.group(2)
            if m.group(1):
                comps["__entry__"] = cur
            buf = []
        elif cur is not None:
            buf.append(line)
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


def _dot_flops(body: str, table: dict[str, int],
               shapes: dict[str, list[tuple[str, list[int]]]]) -> float:
    flops = 0.0
    for m in _FULL_INSTR_RE.finditer(body):
        name, type_str, op, rest = m.groups()
        if op != "dot":
            continue
        res_dims = 1
        for _dt, dims in _SHAPE_RE.findall(type_str):
            for d in (dims.split(",") if dims else []):
                res_dims *= int(d)
        lhs = re.search(r"%([\w\.\-]+)", rest)
        cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
        contract = 1
        if lhs and cdims and lhs.group(1) in shapes:
            lshape = shapes[lhs.group(1)]
            if lshape:
                dims = lshape[0][1]
                for ci in (cdims.group(1).split(",")
                           if cdims.group(1) else []):
                    ci = int(ci)
                    if ci < len(dims):
                        contract *= dims[ci]
        flops += 2.0 * res_dims * contract
    return flops


def _fusion_io_profiles(comps: dict[str, str], table) -> dict:
    """For every computation, the *effective* IO profile when called as
    a fusion:
      params: per-parameter effective read bytes — a parameter consumed
        only through ``dynamic-slice`` counts as the slice (XLA streams
        the slice; charging a 61-layer stacked buffer per scan
        iteration would inflate memory by n_layers×);
      out: effective written bytes — a ``dynamic-update-slice`` root is
        aliased in place, so traffic is the update operand, not the
        whole buffer.
    """
    out = {}
    for cname, body in comps.items():
        params: dict[int, int] = {}
        pnames: dict[str, int] = {}
        root_eff = None
        for m in _FULL_INSTR_RE.finditer(body):
            name, type_str, op, rest = m.groups()
            if op == "parameter":
                idx_m = re.match(r"\s*(\d+)", rest)
                if idx_m:
                    i = int(idx_m.group(1))
                    params[i] = shape_bytes(type_str)
                    pnames[name] = i
        # downgrade params only used via dynamic-slice
        uses: dict[int, list] = {i: [] for i in params}
        for m in _FULL_INSTR_RE.finditer(body):
            name, type_str, op, rest = m.groups()
            if op == "parameter":
                continue
            for ref in re.findall(r"%([\w\.\-]+)", rest.split(")")[0]):
                if ref in pnames:
                    uses[pnames[ref]].append((op, shape_bytes(type_str)))
        eff = dict(params)
        for i, us in uses.items():
            if us and all(op == "dynamic-slice" for op, _ in us):
                eff[i] = sum(b for _, b in us)
        # root DUS → effective out = update operand
        rm = re.search(r"ROOT\s+%?([\w\.\-]+)\s*=\s*(\(?[^)=]*?\)?)\s*"
                       r"([\w\-]+)\((.*)$", body, re.M)
        if rm and rm.group(3) == "dynamic-update-slice":
            ops_refs = re.findall(r"%([\w\.\-]+)",
                                  rm.group(4).split(")")[0])
            if len(ops_refs) >= 2:
                # update operand: local name → look in body table
                upd = ops_refs[1]
                for m in _FULL_INSTR_RE.finditer(body):
                    if m.group(1) == upd:
                        root_eff = 2 * shape_bytes(m.group(2))
                        break
                if root_eff is None and upd in pnames:
                    root_eff = 2 * params[pnames[upd]]
        out[cname] = {"params": eff, "out": root_eff}
    return out


def _comp_metrics(body: str, table, shapes, fusion_io=None) -> dict:
    """Top-level bytes / dot flops / collective bytes of one
    computation (fusion bodies are separate computations — not here).
    Fusion calls use the effective IO profile of the fused computation
    (_fusion_io_profiles); top-level dynamic-(update-)slice ops count
    slice traffic only."""
    fusion_io = fusion_io or {}
    bytes_acc = 0
    coll = 0
    for m in _FULL_INSTR_RE.finditer(body):
        name, type_str, op, rest = m.groups()
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast"):
            continue
        out_b = shape_bytes(type_str)
        refs = re.findall(r"%([\w\.\-]+)", rest.split(")")[0])
        in_b = sum(table.get(r, 0) for r in refs)
        total = out_b + in_b
        if op == "fusion":
            cm = re.search(r"calls=%?([\w\.\-]+)", rest)
            prof = fusion_io.get(cm.group(1)) if cm else None
            if prof:
                eff_in = sum(
                    prof["params"].get(i, table.get(r, 0))
                    for i, r in enumerate(refs))
                eff_out = prof["out"] if prof["out"] is not None \
                    else out_b
                total = eff_in + eff_out
        elif op == "dynamic-slice":
            total = 2 * out_b  # read slice + write slice
        elif op == "dynamic-update-slice":
            big = max((table.get(r, 0) for r in refs), default=0)
            total = max(out_b + in_b - big - out_b, 0)
        bytes_acc += max(total, 0)
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                coll += in_b if in_b else out_b
                break
    return {"bytes": bytes_acc,
            "dot_flops": _dot_flops(body, table, shapes),
            "coll": coll}


def _trip_count(while_line: str, cond_body: str) -> int | None:
    """Trip count — prefer XLA's own ``known_trip_count`` backend
    config on the while instruction, fall back to the loop-condition
    comparison constant."""
    m = re.search(r'known_trip_count\\?":\s*\{\\?"n\\?":\s*\\?"(\d+)',
                  while_line)
    if m:
        return int(m.group(1))
    consts = re.findall(r"constant\((\d+)\)", cond_body)
    if re.search(r"compare\(", cond_body) and consts:
        return int(consts[-1])
    return None


def scan_aware_metrics(text: str, default_trips: int = 1) -> dict:
    """Whole-module {flops, bytes, coll_bytes} with while bodies scaled
    by their trip counts."""
    # instruction table across the whole module (names are unique)
    table: dict[str, int] = {}
    shapes: dict[str, list] = {}
    for m in _FULL_INSTR_RE.finditer(text):
        name, type_str = m.group(1), m.group(2)
        table[name] = shape_bytes(type_str)
        sh = []
        for dt, dims in _SHAPE_RE.findall(type_str):
            sh.append((dt, [int(d) for d in dims.split(",")]
                       if dims else []))
        shapes[name] = sh

    comps = _split_computations(text)
    entry = comps.pop("__entry__", None)

    # callee roles
    fused: set[str] = set()
    whiles: list[tuple[str, str]] = []   # (body, cond)
    for body in comps.values():
        for m in re.finditer(r"calls=%?([\w\.\-]+)", body):
            fused.add(m.group(1))
        for m in re.finditer(r"to_apply=%?([\w\.\-]+)", body):
            fused.add(m.group(1))
        for m in re.finditer(
                r"while\([^)]*\), condition=%?([\w\.\-]+), "
                r"body=%?([\w\.\-]+)", body):
            whiles.append((m.group(2), m.group(1)))

    # multipliers: start at entry ×1, propagate through while nesting
    mult: dict[str, float] = {}
    if entry in comps:
        mult[entry] = 1.0

    def visit(name: str, factor: float):
        if name not in comps:
            return
        body = comps[name]
        for line in body.splitlines():
            m = re.search(
                r"while\([^)]*\), condition=%?([\w\.\-]+), "
                r"body=%?([\w\.\-]+)", line)
            if not m:
                continue
            cond, wbody = m.group(1), m.group(2)
            trips = _trip_count(line, comps.get(cond, "")) \
                or default_trips
            mult[wbody] = mult.get(wbody, 0.0) + factor * trips
            visit(wbody, factor * trips)

    if entry in comps:
        mult[entry] = 1.0
        visit(entry, 1.0)

    fusion_io = _fusion_io_profiles(
        {k: v for k, v in comps.items() if k in fused}, table)

    total = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
    per_comp = {}
    for name, body in comps.items():
        f = mult.get(name, 0.0)
        if name == entry:
            f = 1.0
        if f == 0.0 or name in fused:
            continue
        met = _comp_metrics(body, table, shapes, fusion_io)
        per_comp[name] = {"mult": f, **met}
        total["flops"] += f * met["dot_flops"]
        total["bytes"] += f * met["bytes"]
        total["coll_bytes"] += f * met["coll"]
    total["per_comp"] = per_comp
    return total


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> dict[str, float]:
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = coll_bytes / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    bound = max(compute, memory, collective)
    frac = compute / bound if bound > 0 else 0.0
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant,
            "roofline_fraction": frac}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D (training) or 2·N_active·D (fwd),
    N_active = active params (MoE: top_k of E experts; decode: per
    generated token), PLUS the analytic attention-matmul term
    (2·2·L_attn·H·hd·S²·B·½ fwd; ×3 train) — 6ND alone badly
    understates attention-heavy small-d models at long S."""
    from repro.models.blocks import layer_kinds, group_size, n_groups

    d = cfg.d_model
    act = 0
    emb = cfg.vocab * d
    kinds = layer_kinds(cfg)
    per_layer = []
    for (mixer, ffn) in kinds:
        n = 0
        if mixer == "attn":
            hd = cfg.hd()
            n += d * cfg.n_heads * hd * 2          # wq, wo
            n += d * cfg.n_kv_heads * hd * 2       # wk, wv
        else:
            d_in = cfg.d_inner()
            nst = cfg.ssm_state
            n += d * (2 * d_in + 2 * nst + cfg.ssm_nheads())
            n += d_in * d
        mats = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
        if ffn == "moe":
            n += cfg.top_k * mats * d * cfg.d_ff
        elif ffn == "mlp":
            n += mats * d * cfg.d_ff
        per_layer.append(n)
    act = sum(per_layer) * n_groups(cfg)
    if cfg.family == "encdec":
        hd = cfg.hd()
        enc = cfg.n_enc_layers * (d * cfg.n_heads * hd * 2
                                  + d * cfg.n_kv_heads * hd * 2
                                  + 2 * d * cfg.d_ff)
        # decoder cross-attention params
        act += enc + cfg.n_layers * (d * cfg.n_heads * hd * 2
                                     + d * cfg.n_kv_heads * hd * 2)
    n_active = act + emb  # unembed ~ emb (tied or not: one matmul)

    # analytic attention matmul flops (QK^T + PV), causal halved,
    # sliding window capped
    n_attn_layers = sum(1 for (m, _) in kinds if m == "attn") \
        * n_groups(cfg)
    if cfg.family == "encdec":
        n_attn_layers = cfg.n_layers + cfg.n_enc_layers  # + cross below
    s = shape.seq_len
    eff = min(s, cfg.window) if cfg.window else s
    hd = cfg.hd() if cfg.n_heads else 0
    attn_fwd_per_seq = (2.0 * 2 * n_attn_layers * cfg.n_heads * hd
                        * s * eff * 0.5)
    if cfg.family == "encdec":
        attn_fwd_per_seq += (2.0 * 2 * cfg.n_layers * cfg.n_heads * hd
                             * s * cfg.enc_seq)

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return (6.0 * n_active * tokens
                + 3.0 * attn_fwd_per_seq * shape.global_batch)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return (2.0 * n_active * tokens
                + attn_fwd_per_seq * shape.global_batch)
    # decode: per token — attention reads S keys once
    attn_dec = 2.0 * 2 * n_attn_layers * cfg.n_heads * hd * eff
    return (2.0 * n_active + attn_dec) * shape.global_batch
