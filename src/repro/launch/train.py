"""Training driver: data-parallel/jit train loop with delta-based
checkpointing, historical metric logging, failure recovery, and
straggler-policy hooks.

CPU-scale usage (the e2e example wraps this):
  python -m repro.launch.train --arch smollm-360m --steps 200 \
      --reduced --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
from repro.obs import clock

import jax
import jax.numpy as jnp

from repro.checkpoint import (DeltaCheckpointStore, DeltaPolicy, HistoryLog,
                              tensor_measures)
from repro.config import ShardingConfig, TrainConfig, reduced
from repro.configs import ARCHS, get_config
from repro.data import SyntheticLM
from repro.runtime import (FailureInjector, InjectedFailure, TrainState,
                           init_train_state, make_train_step,
                           run_with_recovery)
from repro.runtime.stragglers import StragglerPolicy


def train(cfg, tcfg: TrainConfig, scfg: ShardingConfig, *,
          ckpt_dir: str | None = None, ckpt_every: int = 20,
          policy: DeltaPolicy | None = None,
          injector: FailureInjector | None = None,
          history: HistoryLog | None = None,
          log_every: int = 10, straggler: StragglerPolicy | None = None,
          log_tensor_norms: bool = False):
    """Returns (final TrainState, HistoryLog, DeltaCheckpointStore|None).

    Recovery contract: if any step raises, re-enter with the store's
    latest state (runtime/failures.py) — this function does exactly
    that internally when a checkpoint store is present.
    """
    data = SyntheticLM(cfg, tcfg.global_batch, tcfg.seq_len,
                       seed=tcfg.seed)
    step_fn = jax.jit(make_train_step(cfg, tcfg, scfg))
    store = (DeltaCheckpointStore(ckpt_dir, policy)
             if ckpt_dir else None)
    history = history or HistoryLog()
    template = None

    def loop(start_step: int) -> TrainState:
        nonlocal template
        if start_step == 0 or store is None or \
                store.latest_step() is None:
            state = init_train_state(jax.random.PRNGKey(tcfg.seed), cfg,
                                     tcfg)
        else:
            if template is None:
                template = jax.eval_shape(
                    lambda: init_train_state(jax.random.PRNGKey(tcfg.seed),
                                             cfg, tcfg))
            state = store.restore(store.latest_step(), template)
            start_step = int(jax.device_get(state.step))
        for step in range(start_step, tcfg.total_steps):
            if injector is not None:
                injector.check(step)
            t0 = clock.now()
            batch = data.batch_at(step)
            state, metrics = step_fn(state, batch)
            dt_ms = (clock.now() - t0) * 1e3
            if step % log_every == 0 or step == tcfg.total_steps - 1:
                m = {k: float(jax.device_get(v))
                     for k, v in metrics.items()}
                m["step_ms"] = dt_ms
                if log_tensor_norms:
                    m.update(tensor_measures(state.params))
                history.record(step, m)
            if store is not None and step % ckpt_every == 0:
                store.save(step, state)
            if straggler is not None:
                straggler.observe(dt_ms, tcfg.microbatches)
        if store is not None:
            store.save(tcfg.total_steps - 1, state)
        return state

    if store is not None:
        from repro.runtime.failures import run_with_recovery
        state = run_with_recovery(loop, store, template)
    else:
        state = loop(0)
    return state, history, store


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--policy", default="periodic",
                    choices=["periodic", "opcount", "similarity"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       param_dtype="float32")
    scfg = ShardingConfig()
    t0 = clock.now()
    state, history, store = train(
        cfg, tcfg, scfg, ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every, policy=DeltaPolicy(kind=args.policy))
    first = history.rows["loss"][0]
    last = history.rows["loss"][-1]
    print(f"trained {args.steps} steps in {clock.now()-t0:.1f}s | "
          f"loss {first:.4f} -> {last:.4f}")
    if store is not None:
        print("checkpoint storage:", store.storage_bytes())


if __name__ == "__main__":
    main()
