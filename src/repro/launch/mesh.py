"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  The dry-run builds these over
512 forced host devices; real launches build them over the slice's TPU
devices — same shapes, same axis names.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) over 256 chips.
    Multi-pod: (pod=2, data=16, model=16) over 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh over however many (host) devices a test forced."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
