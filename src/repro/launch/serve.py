"""Historical-query serving driver (the paper's workload).

Builds a temporal graph store from the synthetic evolving-graph
generator, shards the current snapshot over the available devices, and
serves batches of mixed historical queries with the plan matrix of
paper Table 2 (+ the distributed batched hybrid plan for point-degree
queries).

  python -m repro.launch.serve --nodes 2000 --queries 64
"""
from __future__ import annotations

import argparse
from repro.obs import clock

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as D
from repro.core.generate import EvolutionParams, build_store
from repro.core.plans import Query


def serve_batch(store, queries: list[Query], *, indexed: bool = True):
    out = []
    for q in queries:
        out.append(store.query(q, indexed=indexed and q.measure == "degree"))
    return [jax.device_get(x) for x in out]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=1000)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    t0 = clock.now()
    store = build_store(args.nodes,
                        EvolutionParams(m_attach=4, lam_extra=1.0,
                                        lam_remove=1.0), seed=args.seed)
    print(f"built store in {clock.now()-t0:.1f}s:", store.stats())

    mesh = D.graph_mesh()
    g = D.shard_graph(store.current, mesh)
    d = store.delta()

    # batched distributed point-degree queries (hybrid plan)
    vs = jnp.asarray(rng.integers(0, args.nodes, args.queries)
                     .astype(np.int32))
    ts = jnp.asarray(rng.integers(1, store.t_cur, args.queries)
                     .astype(np.int32))
    t0 = clock.now()
    deg = D.dist_batch_point_degree(mesh, g, d, vs, ts, store.t_cur)
    deg.block_until_ready()
    t_batch = clock.now() - t0
    print(f"served {args.queries} point-degree queries in "
          f"{t_batch*1e3:.1f} ms "
          f"({t_batch/args.queries*1e6:.0f} us/query)")

    # mixed single queries through the plan matrix
    mixed = [
        Query("point", "node", "degree", t_k=int(ts[0]), v=int(vs[0])),
        Query("diff", "node", "degree", t_k=int(store.t_cur * 0.25),
              t_l=int(store.t_cur * 0.75), v=int(vs[1])),
        Query("agg", "node", "degree", t_k=int(store.t_cur * 0.5),
              t_l=int(store.t_cur * 0.5) + 8, v=int(vs[2]), agg="mean"),
        Query("point", "global", "num_edges", t_k=int(store.t_cur * 0.5)),
        Query("diff", "global", "avg_degree", t_k=int(store.t_cur * 0.3),
              t_l=int(store.t_cur * 0.9)),
    ]
    t0 = clock.now()
    res = serve_batch(store, mixed)
    print(f"mixed plans in {(clock.now()-t0)*1e3:.1f} ms:",
          [np.round(np.asarray(r), 3).tolist() for r in res])


if __name__ == "__main__":
    main()
