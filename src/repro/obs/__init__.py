"""``repro.obs`` — zero-dependency observability for the whole stack.

Three pieces, one import surface:

* ``metrics`` — thread-safe ``MetricsRegistry`` (counters, gauges,
  log-bucket histograms) with Prometheus text exposition and a JSON
  snapshot; a process-global default plus chainable per-session /
  per-component instances.
* ``trace`` — ``trace_span`` context managers into a bounded ring
  buffer with Chrome ``trace_event`` export; free when disabled.
* ``slowlog`` — threshold-triggered slow-query records with full plan
  attribution.

See README "Observability" for the metrics catalog and quickstarts.
"""
from repro.obs import clock
from repro.obs.metrics import (BYTE_BUCKETS, COUNT_BUCKETS,
                               LATENCY_BUCKETS, MetricsRegistry,
                               NullRegistry, default_registry, timed)
from repro.obs.slowlog import SlowQueryLog
from repro.obs.trace import (NULL_SPAN, Tracer, active_tracer,
                             install_tracer, trace_span,
                             uninstall_tracer)

__all__ = [
    "clock", "MetricsRegistry", "NullRegistry", "default_registry",
    "LATENCY_BUCKETS", "BYTE_BUCKETS", "COUNT_BUCKETS", "timed",
    "Tracer", "trace_span", "install_tracer", "uninstall_tracer",
    "active_tracer", "NULL_SPAN", "SlowQueryLog",
]
