"""The one sanctioned monotonic clock for instrumentation.

Every timing measurement in ``src/repro`` goes through ``now()`` (or,
better, through ``obs.trace.trace_span`` / ``obs.metrics.timed``, which
use it).  ``scripts/ci_lint.py`` rejects bare ``time.perf_counter()``
calls outside this package: scattering raw clock reads is how the
pre-obs codebase grew three incompatible ad-hoc stats surfaces, and
funneling through one symbol keeps all timing swappable (tests can
monkeypatch ``clock.now``) and greppable.

Scheduling deadlines (frontend drain deadlines, backoff sleeps) use the
same clock — they are comparisons against instrumented timestamps, so
mixing clock sources would skew shed/deadline decisions.
"""
from __future__ import annotations

import time

#: Monotonic, high-resolution, cheap.  An alias (not a wrapper def) so
#: ``now()`` costs exactly one C call on the ingest hot path.
now = time.perf_counter

__all__ = ["now"]
