"""Structured tracing: bounded span ring buffer, Chrome trace export.

``trace_span(name, **attrs)`` is the only API instrumented code uses.
Its cost contract is the whole design:

* **No tracer installed** (the default): ``trace_span`` returns one
  shared no-op singleton — a module-global ``None`` check plus a
  constant return, no allocation, no clock read.  Tracing that is off
  costs a dict lookup per span site, nothing more
  (tests/test_obs.py pins the singleton identity).
* **Tracer installed**: spans record (name, start, duration, thread,
  attrs) into a bounded ``deque`` ring — old events fall off the back,
  a long-running session never grows without bound.

Export is the Chrome ``trace_event`` JSON format (complete ``"X"``
events carrying ``ts``/``dur`` in microseconds): load the dump in
``chrome://tracing`` / Perfetto and one query renders as a nested
timeline of plan → anchor-select → window-delta materialize → device
dispatch → measure; one epoch swap as drain → WAL append/fsync → seal
→ checkpoint → engine flip → publish.  Nesting needs no explicit
parent ids — same-thread events nest by time containment, which the
with-statement discipline guarantees.

One process-wide tracer slot (not per-session): spans fire on frontend
scheduler threads, swap threads and replica sync loops that have no
session handle, and Chrome's timeline is per (pid, tid) anyway.
``GraphSession.enable_tracing`` installs, ``dump_trace`` exports.
"""
from __future__ import annotations

import json
import os
import threading
from collections import deque

from repro.obs import clock

__all__ = ["Tracer", "trace_span", "install_tracer", "uninstall_tracer",
           "active_tracer", "NULL_SPAN"]

_INSTALLED: "Tracer | None" = None


class _NullSpan:
    """The disabled-tracing span: a shared, stateless no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        pass


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._t0 = clock.now()
        return self

    def set(self, **attrs):
        """Attach attributes discovered mid-span (group counts, cache
        hits, ...)."""
        self.attrs.update(attrs)

    def __exit__(self, exc_type, exc, tb):
        t1 = clock.now()
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        self._tracer._record(self.name, self._t0, t1 - self._t0,
                             self.attrs)
        return False


def trace_span(name: str, /, **attrs):
    """A context manager timing one named phase.  Free when no tracer
    is installed (returns the shared ``NULL_SPAN``)."""
    t = _INSTALLED
    if t is None:
        return NULL_SPAN
    return _Span(t, name, attrs)


def install_tracer(tracer: "Tracer") -> "Tracer":
    """Make ``tracer`` the process-wide span sink (replacing any
    previous one)."""
    global _INSTALLED
    _INSTALLED = tracer
    return tracer


def uninstall_tracer(tracer: "Tracer | None" = None) -> None:
    """Remove the active sink.  With ``tracer`` given, only if it IS
    the active one — lets two scopes disable independently without one
    clobbering the other's tracer."""
    global _INSTALLED
    if tracer is None or _INSTALLED is tracer:
        _INSTALLED = None


def active_tracer() -> "Tracer | None":
    return _INSTALLED


class Tracer:
    """Bounded in-memory span ring with Chrome ``trace_event`` export.

    ``capacity`` bounds memory: each completed span is one small dict;
    when the ring is full the oldest falls off.  ``seq`` increments per
    recorded span so consumers (the slow-query log) can slice "what
    happened since" without copying the ring.
    """

    def __init__(self, capacity: int = 16384):
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._t0 = clock.now()
        self.seq = 0

    def _record(self, name: str, t0: float, dur: float,
                attrs: dict) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "cat": "repro",
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "ts": (t0 - self._t0) * 1e6,     # µs, Chrome's unit
            "dur": dur * 1e6,
            "args": attrs,
        }
        with self._lock:
            self.seq += 1
            ev["seq"] = self.seq
            self._events.append(ev)

    # ------------------------------------------------------------- reading

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def events_since(self, seq: int) -> list[dict]:
        """Spans recorded after sequence number ``seq`` (oldest may be
        gone if the ring wrapped)."""
        with self._lock:
            return [e for e in self._events if e["seq"] > seq]

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    # ------------------------------------------------------------- export

    def chrome_trace(self) -> dict:
        """The ``chrome://tracing`` / Perfetto JSON object."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.chrome_trace(), fh)
        os.replace(tmp, path)
        return path
