"""Slow-query log: threshold-triggered span dumps with plan attribution.

The engine times every ``evaluate_many`` call; a call slower than
``threshold_ms`` lands one entry here carrying the *full plan
attribution* — per-group (plan, layout, shard mode, batch size), the
call's reconstruction-cache traffic, and (when a tracer is installed)
the spans recorded during the call, so a slow production query explains
itself without re-running anything.

The log is a bounded ring (oldest entries fall off) and recording is
two comparisons on the fast path — a fast call never builds an entry.
"""
from __future__ import annotations

import threading
from collections import deque

__all__ = ["SlowQueryLog"]


class SlowQueryLog:
    """Bounded ring of slow-call records.

    ``threshold_ms`` gates recording; ``record`` takes a zero-arg entry
    builder so the (comparatively expensive) attribution dict is only
    materialized for calls that actually crossed the threshold.
    """

    def __init__(self, threshold_ms: float = 250.0, capacity: int = 64):
        self.threshold_ms = float(threshold_ms)
        self.capacity = int(capacity)
        self._entries: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.recorded = 0

    def should_record(self, seconds: float) -> bool:
        return seconds * 1e3 >= self.threshold_ms

    def record(self, seconds: float, entry_fn) -> bool:
        """Record iff ``seconds`` crosses the threshold; ``entry_fn()``
        builds the attribution payload lazily.  Returns whether an
        entry landed."""
        if not self.should_record(seconds):
            return False
        entry = dict(entry_fn())
        entry["seconds"] = float(seconds)
        with self._lock:
            self.recorded += 1
            self._entries.append(entry)
        return True

    def entries(self) -> list[dict]:
        with self._lock:
            return list(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
