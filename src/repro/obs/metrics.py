"""Thread-safe metrics registry: counters, gauges, log-bucket histograms.

The registry is the single accounting surface for the whole stack —
engine group dispatch, reconstruction-cache traffic, segment residency,
serving watermarks, WAL fsyncs, checkpoints, replica sync, routing.
Design constraints, in order:

1. **Hot-path cheap.**  Instrumented components resolve their metric
   children ONCE (at construction) and then pay one lock acquire plus
   one add per event.  Family/label resolution (two dict lookups) is
   reserved for per-group / per-sync frequency call sites.
2. **No lost increments.**  Every child value carries its own
   ``threading.Lock``; ``inc``/``observe``/``set`` are atomic under it.
   The ingest thread, the frontend scheduler, the swap thread and a
   replica sync loop can hammer one counter concurrently and the total
   is exact (tests/test_obs.py pins this).
3. **Zero dependencies.**  Prometheus text exposition and the JSON
   snapshot are rendered by hand; nothing here imports outside the
   standard library.

Registries chain: ``MetricsRegistry(parent=...)`` propagates counter
increments and histogram observations (and gauge writes, last-writer-
wins) to the same-named child of the parent.  That is how per-instance
stats views stay exact — each ``MicroBatchFrontend`` / ``ReadReplica``
gets a private leaf registry whose children also feed the session- or
process-level aggregate, so ``replica.stats.syncs`` is *this* replica's
count while ``graphtop`` watches the fleet total.

**Reset semantics** (the overflow story): counters are monotonic for
the lifetime of their registry, nothing more.  Per-epoch engine
counters reset because every epoch swap builds a fresh engine; per-
instance views reset because each instance owns a fresh leaf registry;
the process-global default registry is monotonic until ``reset()`` —
Python integers never overflow, so the only real hazard is *unbounded
label sets*, which the instrumentation avoids by keeping label values
from small closed vocabularies (plan names, layouts, phases, record
types — never query times or node ids).
"""
from __future__ import annotations

import bisect
import json
import threading
from repro.obs import clock
from repro.obs.trace import trace_span

__all__ = [
    "MetricsRegistry", "NullRegistry", "default_registry",
    "LATENCY_BUCKETS", "BYTE_BUCKETS", "COUNT_BUCKETS", "timed",
]

# Fixed log-spaced bucket ladders.  Fixed (not adaptive) so histograms
# merge across registries/processes by simple bucket-wise addition.
#: seconds: 1 µs .. ~67 s in powers of two, + overflow
LATENCY_BUCKETS = tuple(1e-6 * (1 << i) for i in range(27))
#: bytes: 64 B .. 4 GB in powers of four, + overflow
BYTE_BUCKETS = tuple(64 * (4 ** i) for i in range(14))
#: dimensionless counts (batch sizes, record counts): 1 .. 64k pow2
COUNT_BUCKETS = tuple(float(1 << i) for i in range(17))


def _label_key(labels: dict) -> str:
    """Canonical flat key: 'a=x,b=y' sorted by label name ('' = bare)."""
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels))


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


class _Counter:
    """Monotonic counter child.  ``value`` only ever grows (use a gauge
    for anything that can fall); ``inc`` propagates to the same-named
    parent child so leaf registries aggregate upward."""

    __slots__ = ("value", "_lock", "_parent")
    kind = "counter"

    def __init__(self, parent=None):
        self.value = 0
        self._lock = threading.Lock()
        self._parent = parent

    def inc(self, n: int | float = 1) -> None:
        with self._lock:
            self.value += n
        if self._parent is not None:
            self._parent.inc(n)


class _Gauge:
    """Point-in-time value.  ``set`` (and ``set_max``, the high-water
    helper behind ``max_batch_seen``-style stats) propagate last-writer-
    wins to the parent."""

    __slots__ = ("value", "_lock", "_parent")
    kind = "gauge"

    def __init__(self, parent=None):
        self.value = 0
        self._lock = threading.Lock()
        self._parent = parent

    def set(self, v) -> None:
        with self._lock:
            self.value = v
        if self._parent is not None:
            self._parent.set(v)

    def inc(self, n=1) -> None:
        with self._lock:
            self.value += n
            v = self.value
        if self._parent is not None:
            self._parent.set(v)

    def dec(self, n=1) -> None:
        self.inc(-n)

    def set_max(self, v) -> None:
        with self._lock:
            if v > self.value:
                self.value = v
            v = self.value
        if self._parent is not None:
            self._parent.set_max(v)


class _Histogram:
    """Fixed log-bucket histogram child: per-bucket counts (plus one
    overflow slot), running sum/count/min/max."""

    __slots__ = ("buckets", "counts", "sum", "count", "min", "max",
                 "_lock", "_parent")
    kind = "histogram"

    def __init__(self, buckets, parent=None):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()
        self._parent = parent

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
        if self._parent is not None:
            self._parent.observe(v)

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the bucket the
        q-th observation falls in) — what graphtop prints as p50/p95."""
        with self._lock:
            total, counts = self.count, list(self.counts)
        if total == 0:
            return 0.0
        rank = q * total
        acc = 0
        for i, n in enumerate(counts):
            acc += n
            if acc >= rank and n:
                return (self.buckets[i] if i < len(self.buckets)
                        else float("inf"))
        return float("inf")

    def state(self) -> dict:
        with self._lock:
            return {"count": self.count, "sum": self.sum,
                    "min": self.min if self.count else 0.0,
                    "max": self.max if self.count else 0.0,
                    "buckets": list(self.counts)}


class _NullChild:
    """Shared no-op child: every mutator is a pass.  What the overhead
    benchmark binds to measure the instrumentation floor."""

    __slots__ = ()
    kind = "null"
    value = 0
    count = 0
    sum = 0.0

    def inc(self, n=1):
        pass

    def dec(self, n=1):
        pass

    def set(self, v):
        pass

    def set_max(self, v):
        pass

    def observe(self, v):
        pass

    def quantile(self, q):
        return 0.0

    def state(self):
        return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                "buckets": []}


_NULL_CHILD = _NullChild()


class _Family:
    """One named metric: kind + help + labeled children."""

    __slots__ = ("name", "kind", "help", "buckets", "_children", "_lock")

    def __init__(self, name: str, kind: str, help_: str, buckets=None):
        self.name = name
        self.kind = kind
        self.help = help_
        self.buckets = buckets
        self._children: dict[str, object] = {}
        self._lock = threading.Lock()

    def child(self, labels: dict, parent_child=None):
        key = _label_key(labels)
        with self._lock:
            c = self._children.get(key)
            if c is None:
                if self.kind == "counter":
                    c = _Counter(parent_child)
                elif self.kind == "gauge":
                    c = _Gauge(parent_child)
                else:
                    c = _Histogram(self.buckets, parent_child)
                self._children[key] = c
            return c


class MetricsRegistry:
    """Counters, gauges and histograms under one namespace.

    ``parent`` chains registries (see module docstring).  All three
    accessors are create-or-get: the first call fixes the metric's kind
    and help string, later calls with the same name return the same
    family (a kind mismatch raises — one name, one meaning).
    """

    def __init__(self, parent: "MetricsRegistry | None" = None):
        self.parent = parent
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ creation

    def _family(self, name: str, kind: str, help_: str,
                buckets=None) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, kind, help_, buckets)
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"not {kind}")
            return fam

    def counter(self, name: str, help: str = "", **labels) -> _Counter:
        fam = self._family(name, "counter", help)
        pc = (self.parent.counter(name, help, **labels)
              if self.parent is not None else None)
        return fam.child(labels, pc)

    def gauge(self, name: str, help: str = "", **labels) -> _Gauge:
        fam = self._family(name, "gauge", help)
        pc = (self.parent.gauge(name, help, **labels)
              if self.parent is not None else None)
        return fam.child(labels, pc)

    def histogram(self, name: str, help: str = "", *,
                  buckets=LATENCY_BUCKETS, **labels) -> _Histogram:
        fam = self._family(name, "histogram", help, tuple(buckets))
        pc = (self.parent.histogram(name, help, buckets=buckets, **labels)
              if self.parent is not None else None)
        return fam.child(labels, pc)

    # ------------------------------------------------------------- reading

    def get(self, name: str, **labels):
        """Current value of one series (counter/gauge: number;
        histogram: state dict) or None if never touched."""
        fam = self._families.get(name)
        if fam is None:
            return None
        c = fam._children.get(_label_key(labels))
        if c is None:
            return None
        return c.state() if fam.kind == "histogram" else c.value

    def snapshot(self) -> dict:
        """JSON-able dump: ``{"counters"|"gauges"|"histograms":
        {name: {label_key: value-or-state}}}`` — the payload behind
        ``GraphSession.metrics()`` and graphtop."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            fams = list(self._families.values())
        for fam in fams:
            with fam._lock:
                children = dict(fam._children)
            if fam.kind == "histogram":
                out["histograms"][fam.name] = {
                    k: dict(c.state(),
                            buckets=[[b, n] for b, n in
                                     zip(list(fam.buckets) + ["+Inf"],
                                         c.state()["buckets"])])
                    for k, c in children.items()}
            else:
                out[fam.kind + "s"][fam.name] = {
                    k: c.value for k, c in children.items()}
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4): HELP/TYPE headers,
        cumulative ``_bucket{le=...}`` plus ``_sum``/``_count`` for
        histograms."""
        lines: list[str] = []
        with self._lock:
            fams = sorted(self._families.values(), key=lambda f: f.name)
        for fam in fams:
            with fam._lock:
                children = dict(fam._children)
            if not children:
                continue
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, c in sorted(children.items()):
                pairs = ([p.split("=", 1) for p in key.split(",")]
                         if key else [])
                base = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
                if fam.kind == "histogram":
                    st = c.state()
                    acc = 0
                    for b, n in zip(list(fam.buckets) + ["+Inf"],
                                    st["buckets"]):
                        acc += n
                        le = b if b == "+Inf" else repr(float(b))
                        lbl = (base + "," if base else "") + f'le="{le}"'
                        lines.append(
                            f"{fam.name}_bucket{{{lbl}}} {acc}")
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{fam.name}_sum{suffix} {st['sum']}")
                    lines.append(f"{fam.name}_count{suffix} {st['count']}")
                else:
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{fam.name}{suffix} {c.value}")
        return "\n".join(lines) + ("\n" if lines else "")

    def dump_json(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.snapshot(), fh)
        import os
        os.replace(tmp, path)
        return path

    # ------------------------------------------------------------ lifecycle

    def reset(self) -> None:
        """Drop every family.  Held child references keep working but
        are orphaned (their writes no longer appear in snapshots) —
        intended for test isolation and tool restarts, not for live
        components."""
        with self._lock:
            self._families.clear()


class NullRegistry(MetricsRegistry):
    """A registry whose children do nothing: ``metrics off`` for the
    overhead benchmark and for callers that want the instrumented code
    paths with zero accounting cost.  Snapshots are empty."""

    def __init__(self):
        super().__init__(parent=None)

    def counter(self, name, help="", **labels):
        return _NULL_CHILD

    def gauge(self, name, help="", **labels):
        return _NULL_CHILD

    def histogram(self, name, help="", *, buckets=LATENCY_BUCKETS,
                  **labels):
        return _NULL_CHILD


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry: what every component binds to when
    no explicit registry is passed down (and what graphtop watches)."""
    return _DEFAULT


class timed:
    """Time a block into a histogram child AND (when a tracer is
    installed) emit a trace span of the same name — the standard way to
    instrument a phase so wall-clock analysis and aggregate latency
    stay in sync:

        with timed(self._m_fsync, "wal.fsync"):
            os.fsync(fd)
    """

    __slots__ = ("_hist", "_name", "_attrs", "_span", "_t0", "seconds")

    def __init__(self, hist, name: str, **attrs):
        self._hist = hist
        self._name = name
        self._attrs = attrs
        self.seconds = 0.0

    def __enter__(self):
        self._span = trace_span(self._name, **self._attrs)
        self._span.__enter__()
        self._t0 = clock.now()
        return self

    def __exit__(self, *exc):
        self.seconds = clock.now() - self._t0
        if self._hist is not None:
            self._hist.observe(self.seconds)
        return self._span.__exit__(*exc)
