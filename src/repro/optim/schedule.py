"""Linear-warmup + cosine-decay learning-rate schedule."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def lr_schedule(step, cfg: TrainConfig):
    s = step.astype(jnp.float32)
    warm = cfg.lr * s / max(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * cfg.lr * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < cfg.warmup_steps, warm, cos)
