"""AdamW from scratch, with selectable optimizer-state dtype.

State dtypes (TrainConfig.opt_state_dtype):
  float32  — standard
  bfloat16 — halves optimizer HBM (needed to fit the ≥398B configs;
             DESIGN.md §5)
  int8     — block-quantized m/v (per-tensor absmax scale kept in f32);
             6 bytes/param total with bf16 params — the kimi-k2 1T
             budget
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """int8 tensor with a per-tensor f32 scale."""
    q: jax.Array
    scale: jax.Array

    @staticmethod
    def quantize(x: jax.Array) -> "QTensor":
        a = jnp.max(jnp.abs(x)) / 127.0
        a = jnp.where(a > 0, a, 1.0)
        return QTensor(q=jnp.clip(jnp.round(x / a), -127, 127)
                       .astype(jnp.int8), scale=a.astype(jnp.float32))

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


def _store(x, dtype: str):
    if dtype == "int8":
        return QTensor.quantize(x)
    return x.astype(jnp.bfloat16 if dtype == "bfloat16" else jnp.float32)


def _load(x):
    if isinstance(x, QTensor):
        return x.dequantize()
    return x.astype(jnp.float32)


def adamw_init(params, cfg: TrainConfig) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: _store(jnp.zeros(p.shape, jnp.float32),
                         cfg.opt_state_dtype), params)
    zeros2 = jax.tree.map(
        lambda p: _store(jnp.zeros(p.shape, jnp.float32),
                         cfg.opt_state_dtype), params)
    return AdamWState(step=jnp.int32(0), m=zeros, v=zeros2)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: TrainConfig,
                 lr: jax.Array):
    """One AdamW step (with global-norm clipping). Returns
    (new_params, new_state, stats)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    # m/v leaves may be QTensor pytrees — map over params as the
    # structure reference and fetch m/v leaves via treedef transfer.
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g32 = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * _load(m) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * _load(v) + (1 - cfg.b2) * g32 * g32
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (upd + cfg.weight_decay * p32)
        new_p.append(p32.astype(p.dtype))
        new_m.append(_store(m32, cfg.opt_state_dtype))
        new_v.append(_store(v32, cfg.opt_state_dtype))

    params2 = jax.tree.unflatten(treedef, new_p)
    state2 = AdamWState(step=step, m=jax.tree.unflatten(treedef, new_m),
                        v=jax.tree.unflatten(treedef, new_v))
    return params2, state2, {"grad_norm": gnorm, "lr": lr}
