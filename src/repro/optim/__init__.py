from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.compress import (int8_compress, int8_decompress,
                                  compressed_psum)
from repro.optim.schedule import lr_schedule

__all__ = ["AdamWState", "adamw_init", "adamw_update", "lr_schedule",
           "int8_compress", "int8_decompress", "compressed_psum"]
