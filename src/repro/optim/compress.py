"""Gradient compression for the DP all-reduce.

int8 symmetric quantization with *error feedback*: the quantization
residual is carried to the next step so the compressed reduction stays
unbiased over time.  Used by the runtime's microbatch accumulation loop
when TrainConfig.grad_compression == 'int8' — the reduce then moves 4×
fewer bytes over DP links (roofline: collective term / 4 on the grad
all-reduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(x: jax.Array):
    a = jnp.max(jnp.abs(x)) / 127.0
    a = jnp.where(a > 0, a, 1.0)
    q = jnp.clip(jnp.round(x / a), -127, 127).astype(jnp.int8)
    return q, a.astype(jnp.float32)


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad: jax.Array, err: jax.Array):
    """Returns (q, scale, new_err). grad+err is quantized; the residual
    becomes the next step's error feedback."""
    g = grad.astype(jnp.float32) + err
    q, scale = int8_compress(g)
    new_err = g - int8_decompress(q, scale)
    return q, scale, new_err


def compressed_psum(grads, errs, axis_name: str):
    """psum int8-compressed grads inside shard_map (per-leaf scales are
    psum-maxed first so dequantization is consistent across shards)."""
    def one(g, e):
        q, scale, new_e = compress_with_feedback(g, e)
        # shared scale: use the max across participants
        smax = jax.lax.pmax(scale, axis_name)
        # requantize against shared scale to keep the sum exact in int32
        gq = jnp.clip(jnp.round((g.astype(jnp.float32) + e) / smax),
                      -127, 127).astype(jnp.int32)
        total = jax.lax.psum(gq, axis_name)
        out = total.astype(jnp.float32) * smax
        new_e = (g.astype(jnp.float32) + e) - (
            gq.astype(jnp.float32) * smax)
        return out, new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(errs)
    outs, new_errs = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return jax.tree.unflatten(td, list(outs)), \
        jax.tree.unflatten(td, list(new_errs))
