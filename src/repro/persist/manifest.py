"""Anchor manifest + sealed-segment files: the checkpointed half.

On-disk layout of a durable store root::

    root/
      MANIFEST.json          # atomic (tmp + rename): config, segment
                             # list, anchor times, current WAL seq
      wal_00000001.log       # the replayable tail (persist.wal)
      segments/seg_000000.npy  # one (5, n_ops) int32 block per sealed
                               # segment: op / u / v / slot / t rows

Sealed segments are immutable, so their files are written exactly once
(atomically, at ``seal_tail`` time) and thereafter only *referenced* by
successive manifests — a checkpoint costs one small JSON rename, never
a data rewrite.  This is the same snapshot-plus-chain shape as
``checkpoint/deltastore.py`` (manifest names the chain, files hold the
arrays); segments use a bare ``.npy`` rather than its npz envelope so
recovery can ``np.load(..., mmap_mode="r")`` them — ``Segment`` wraps
the mmap rows without a copy and the residency pass (`spill`/`delta`)
then pages them in lazily.

Crash ordering (see ``StorePersistence.checkpoint``): the new WAL is
written and fsync'd first, the manifest rename flips second, the old
WAL is deleted last.  Any prefix of that sequence recovers: a manifest
always names a WAL that exists and whose base record matches it.
"""
from __future__ import annotations

import io
import json
import os
import zlib

import numpy as np

MANIFEST = "MANIFEST.json"
SEGMENT_DIR = "segments"
VERSION = 1


class SegmentCorruptError(ValueError):
    """A segment block that fails shape, dtype, or CRC32 validation."""

CONFIG_KEYS = ("n_cap", "e_cap", "layout", "segmented", "segment_min_ops",
               "enforce_invertible")


def wal_name(seq: int) -> str:
    return f"wal_{seq:08d}.log"


def segment_name(index: int) -> str:
    return os.path.join(SEGMENT_DIR, f"seg_{index:06d}.npy")


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return                           # platform without dir fds
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes) -> None:
    """tmp + fsync + rename (+ directory fsync): the file is either the
    old content or the complete new content, never a torn middle."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        fh.write(data)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")


def segment_block_crc(block: np.ndarray) -> int:
    """CRC32 of a (5, n) int32 segment block's raw bytes — the stamp
    recorded per segment entry in the manifest."""
    return zlib.crc32(np.ascontiguousarray(block, np.int32).tobytes())


def save_segment_file(path: str, cols: dict[str, np.ndarray]) -> int:
    """Write one sealed segment's columns as a (5, n) int32 ``.npy``
    block, atomically.  Returns the crc32 of the block bytes (recorded
    in the manifest for integrity checks)."""
    block = np.stack([np.ascontiguousarray(cols[c], np.int32)
                      for c in ("op", "u", "v", "slot", "t")])
    buf = io.BytesIO()
    np.save(buf, block)
    atomic_write_bytes(path, buf.getvalue())
    return segment_block_crc(block)


def _check_block(block: np.ndarray, ctx: str,
                 expected_crc: int | None) -> np.ndarray:
    if block.ndim != 2 or block.shape[0] != 5 or block.dtype != np.int32:
        raise SegmentCorruptError(
            f"{ctx}: not a (5, n) int32 segment block "
            f"(got {block.dtype}{block.shape})")
    if expected_crc is not None:
        got = segment_block_crc(block)
        if got != int(expected_crc):
            raise SegmentCorruptError(
                f"{ctx}: crc32 mismatch (stamped {int(expected_crc)}, "
                f"content {got}) — the block is corrupt")
    return block


def load_segment_file(path: str, *, mmap: bool = True,
                      expected_crc: int | None = None
                      ) -> dict[str, np.ndarray]:
    """Columns of a sealed segment, mmap-backed by default — rows of
    the C-ordered (5, n) block are themselves contiguous int32, so
    ``Segment`` adopts them without copying and only touched pages are
    ever read.

    ``expected_crc`` re-checks the manifest's CRC32 stamp against the
    content (reading every page through the mmap once — recovery's
    rebuild pass touches them all anyway); a mismatch raises
    ``SegmentCorruptError`` instead of serving silently wrong history.
    """
    block = np.load(path, mmap_mode="r" if mmap else None)
    _check_block(block, path, expected_crc)
    return dict(zip(("op", "u", "v", "slot", "t"), block))


def segment_block_from_bytes(data: bytes, *, ctx: str = "<bytes>",
                             expected_crc: int | None = None) -> np.ndarray:
    """Parse + validate a fetched segment payload WITHOUT touching the
    filesystem — the replica's fetch path verifies bytes before they
    are ever written locally.  Raises ``SegmentCorruptError`` on a
    torn/corrupt payload (np.load failures included)."""
    try:
        block = np.load(io.BytesIO(data))
    except Exception as exc:             # torn npy header / short body
        raise SegmentCorruptError(f"{ctx}: unreadable segment payload "
                                  f"({exc})") from exc
    return _check_block(block, ctx, expected_crc)


def segment_file_crc(path: str) -> int:
    """CRC32 stamp recomputed from a segment file on disk."""
    return segment_block_crc(np.load(path, mmap_mode="r"))


def write_manifest(root: str, manifest: dict) -> None:
    manifest = dict(manifest, version=VERSION)
    atomic_write_bytes(os.path.join(root, MANIFEST),
                       (json.dumps(manifest, indent=1, sort_keys=True)
                        + "\n").encode())


def read_manifest(root: str) -> dict | None:
    path = os.path.join(root, MANIFEST)
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        manifest = json.load(fh)
    if manifest.get("version") != VERSION:
        raise ValueError(f"{path}: unsupported manifest version "
                         f"{manifest.get('version')!r}")
    return manifest
