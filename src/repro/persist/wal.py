"""CRC-framed, fsync'd write-ahead log for the open tail segment.

The durability contract of the segmented store splits cleanly in two:
sealed segments are immutable and checkpointed as compact arrays
(``persist.manifest``), while everything newer — the open tail, the
serving layer's pending buffer, advance/seal events — lives only in
process memory.  This module makes that volatile half replayable: every
mutation event is appended here as one framed record *before* the
caller acknowledges it, so a ``kill -9`` at any byte loses only work
that was never acknowledged.

Framing.  A log file starts with an 8-byte magic, then records:

    [u32 length][u32 crc32(payload)][payload]

``payload[0]`` is the record type; the rest is type-specific (packed
little-endian scalars + raw ``int32`` columns — same host-array core
that ``checkpoint/io.py`` serializes, minus the npz envelope, because
records must be appendable and individually checksummed).  A torn tail
(partial write at the crash point) or a corrupt CRC terminates replay
at the last intact record; ``WriteAheadLog`` opened in repair mode
truncates the garbage so post-recovery appends extend a clean log.

Record types and their replay semantics (``persist.recovery``):

* ``REC_TAIL`` — rotation base record: the open-tail columns plus the
  store's scalar cursor state at checkpoint time.  Always the first
  record of a WAL file.
* ``REC_OPS`` — ops *accepted* by ``TemporalGraphStore.ingest``
  (including the remNode -> remEdge expansions); replayed through
  ``ingest`` they are accepted verbatim.
* ``REC_ADVANCE`` — ``advance_to(t)``.
* ``REC_SEAL`` — ``seal_tail(t, force=...)``; replay tolerates the
  no-op case where a replayed advance (with the same policy attached)
  already made the identical cut.
* ``REC_PENDING`` — ops appended to a serving-layer pending buffer
  (``LiveGraphStore.append`` logs them BEFORE buffering).
* ``REC_DRAIN`` — an epoch swap's drain intent, written before the
  swap feeds the first ``n`` pending ops through ``ingest``/
  ``advance_to`` (whose own records are suppressed — the drain record
  subsumes them).  Replay re-executes the drain deterministically, so
  a crash mid-swap recovers either side of the flip bit-exactly.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from typing import Iterable, Iterator

import numpy as np

from repro.obs import clock
from repro.obs.metrics import default_registry
from repro.obs.trace import trace_span

MAGIC = b"GDWAL001"
_HEADER = struct.Struct("<II")          # length, crc32
_MAX_RECORD = 1 << 30                   # sanity bound on a length field

REC_OPS = 1
REC_ADVANCE = 2
REC_SEAL = 3
REC_PENDING = 4
REC_DRAIN = 5
REC_TAIL = 6

REC_NAMES = {REC_OPS: "ops", REC_ADVANCE: "advance", REC_SEAL: "seal",
             REC_PENDING: "pending", REC_DRAIN: "drain", REC_TAIL: "tail"}


# --------------------------------------------------------------- encoding

def _encode_op_rows(ops) -> bytes:
    """(op, u, v, t) rows as u32 count + raw int32 columns."""
    arr = np.asarray([(o.op, o.u, o.v, o.t) for o in ops], np.int32)
    arr = arr.reshape(-1, 4)            # empty batch -> (0, 4)
    return struct.pack("<I", arr.shape[0]) + arr.tobytes()


def _decode_op_rows(buf: bytes, off: int) -> tuple[np.ndarray, int]:
    (n,) = struct.unpack_from("<I", buf, off)
    off += 4
    arr = np.frombuffer(buf, np.int32, count=4 * n, offset=off)
    return arr.reshape(n, 4), off + 16 * n


def encode_ops(rtype: int, ops) -> bytes:
    return bytes([rtype]) + _encode_op_rows(ops)


def encode_advance(t: int) -> bytes:
    return bytes([REC_ADVANCE]) + struct.pack("<q", int(t))


def encode_seal(t_seal: int, k: int, force: bool) -> bytes:
    return bytes([REC_SEAL]) + struct.pack("<qIB", int(t_seal), int(k),
                                           int(bool(force)))


def encode_drain(n: int, target: int) -> bytes:
    return bytes([REC_DRAIN]) + struct.pack("<Iq", int(n), int(target))


def encode_tail(t_cur: int, ops_since_mat: int, t_last_mat: int,
                cols: dict[str, np.ndarray]) -> bytes:
    """Rotation base record: scalar cursors + the open-tail columns
    (op, u, v, slot, t) as one (5, n) int32 block."""
    n = len(cols["op"])
    block = np.stack([np.asarray(cols[c], np.int32)
                      for c in ("op", "u", "v", "slot", "t")])
    return (bytes([REC_TAIL])
            + struct.pack("<qqqI", int(t_cur), int(ops_since_mat),
                          int(t_last_mat), n)
            + block.tobytes())


def decode(payload: bytes):
    """payload -> (rtype, fields-dict).  Raises on malformed payloads
    (a CRC-intact record can still be from a future format version)."""
    rtype = payload[0]
    if rtype in (REC_OPS, REC_PENDING):
        rows, _ = _decode_op_rows(payload, 1)
        return rtype, {"rows": rows}
    if rtype == REC_ADVANCE:
        (t,) = struct.unpack_from("<q", payload, 1)
        return rtype, {"t": t}
    if rtype == REC_SEAL:
        t, k, force = struct.unpack_from("<qIB", payload, 1)
        return rtype, {"t": t, "k": k, "force": bool(force)}
    if rtype == REC_DRAIN:
        n, target = struct.unpack_from("<Iq", payload, 1)
        return rtype, {"n": n, "target": target}
    if rtype == REC_TAIL:
        t_cur, osm, tlm, n = struct.unpack_from("<qqqI", payload, 1)
        off = 1 + struct.calcsize("<qqqI")
        block = np.frombuffer(payload, np.int32, count=5 * n,
                              offset=off).reshape(5, n)
        cols = dict(zip(("op", "u", "v", "slot", "t"), block))
        return rtype, {"t_cur": t_cur, "ops_since_mat": osm,
                       "t_last_mat": tlm, "cols": cols}
    raise ValueError(f"unknown WAL record type {rtype}")


# ----------------------------------------------------------------- reading

def iter_frames(buf: bytes, start: int | None = None
                ) -> Iterator[tuple[bytes, int]]:
    """Yield (payload, end_offset) for every intact frame of a WAL
    byte buffer, starting at byte offset ``start`` (default: right
    after the magic; ``start`` must sit on a frame boundary).  Stops at
    the first torn or corrupt frame.  This is the incremental consumer
    used by read replicas: re-fetch the (append-only) log bytes, keep
    the consumed offset, decode only what is new."""
    if buf[:len(MAGIC)] != MAGIC:
        return
    off = len(MAGIC) if start is None else max(int(start), len(MAGIC))
    while off + _HEADER.size <= len(buf):
        length, crc = _HEADER.unpack_from(buf, off)
        end = off + _HEADER.size + length
        if length > _MAX_RECORD or end > len(buf):
            return                       # torn tail
        payload = buf[off + _HEADER.size:end]
        if zlib.crc32(payload) != crc:
            return                       # corrupt record: stop here
        yield payload, end
        off = end


def scan_bytes(buf: bytes) -> tuple[list[bytes], int]:
    """Every intact record payload of a WAL byte buffer, plus the
    offset of the first byte past the last intact record."""
    out: list[bytes] = []
    off = len(MAGIC) if buf[:len(MAGIC)] == MAGIC else 0
    for payload, end in iter_frames(buf):
        out.append(payload)
        off = end
    return out, off


def scan(path: str) -> tuple[list[bytes], int]:
    """Read every intact record payload; returns (payloads, n_valid_bytes).

    Replay stops at the first torn or corrupt record — a crash mid-
    ``append`` leaves exactly one partial record at the tail, and
    everything before it was fsync'd whole.  ``n_valid_bytes`` is the
    offset repair should truncate to."""
    with open(path, "rb") as fh:
        buf = fh.read()
    return scan_bytes(buf)


def read_records(path: str) -> Iterator[tuple[int, dict]]:
    """Decoded (rtype, fields) for every intact record."""
    payloads, _ = scan(path)
    for p in payloads:
        yield decode(p)


# ----------------------------------------------------------------- writing

class WriteAheadLog:
    """Append-only framed log.  ``append`` is atomic under an internal
    lock (serving appends PENDING records from request threads while
    the swap thread logs drain/seal events) and, with ``fsync=True``
    (the default), durable before it returns."""

    def __init__(self, path: str, *, fsync: bool = True,
                 repair: bool = True, metrics=None):
        self.path = path
        self.fsync = bool(fsync)
        self._lock = threading.Lock()
        reg = default_registry() if metrics is None else metrics
        self._m_appends = {
            rt: reg.counter("wal_appends_total",
                            "WAL records appended", type=name)
            for rt, name in REC_NAMES.items()}
        self._m_bytes = reg.counter("wal_bytes_total",
                                    "WAL bytes written (frames incl. "
                                    "headers)")
        self._m_fsync = reg.histogram("wal_fsync_seconds",
                                      "flush+fsync latency per append")
        exists = os.path.exists(path)
        if exists and repair:
            _, valid = scan(path)
            if valid < os.path.getsize(path):
                with open(path, "r+b") as fh:
                    fh.truncate(max(valid, 0))
        self._fh = open(path, "ab")
        if self._fh.tell() == 0:
            # no fsync yet: a magic-only log holds no promises, and the
            # first append's fsync covers the whole fd anyway (rotation
            # would otherwise pay a wasted sync per checkpoint)
            self._fh.write(MAGIC)
            self._fh.flush()

    def _flush(self) -> None:
        t0 = clock.now()
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self._m_fsync.observe(clock.now() - t0)

    def append(self, payload: bytes) -> None:
        with trace_span("wal.append", type=REC_NAMES.get(payload[0]),
                        bytes=len(payload)):
            with self._lock:
                self._fh.write(_HEADER.pack(len(payload),
                                            zlib.crc32(payload)))
                self._fh.write(payload)
                self._flush()
            m = self._m_appends.get(payload[0])
            if m is not None:
                m.inc()
            self._m_bytes.inc(_HEADER.size + len(payload))

    def sync(self) -> None:
        with self._lock:
            self._flush()

    def close(self, sync: bool = True) -> None:
        """``sync=False`` skips the final fsync — for a log that is
        about to be deleted (checkpoint rotation), syncing it first is
        a pure waste of a disk round-trip."""
        with self._lock:
            if not self._fh.closed:
                self._fh.flush()
                if self.fsync and sync:
                    os.fsync(self._fh.fileno())
                self._fh.close()

    # convenience encoders ------------------------------------------------
    def log_ops(self, ops: Iterable) -> None:
        self.append(encode_ops(REC_OPS, ops))

    def log_pending(self, ops: Iterable) -> None:
        self.append(encode_ops(REC_PENDING, ops))

    def log_advance(self, t: int) -> None:
        self.append(encode_advance(t))

    def log_seal(self, t_seal: int, k: int, force: bool) -> None:
        self.append(encode_seal(t_seal, k, force))

    def log_drain(self, n: int, target: int) -> None:
        self.append(encode_drain(n, target))
