"""Durability layer: WAL'd open tail + checkpointed sealed segments.

``open_store(root)`` opens (or creates) a durable store root and is
the crash-recovery entry point; ``StorePersistence`` is the hook
object a durable store carries as ``store.persist``.  See
``persist.wal`` for the record framing and ``persist.manifest`` for
the on-disk layout.  Most callers want neither directly —
``repro.api.GraphSession(path=...)`` wires the whole stack.
"""
from repro.persist.manifest import (SegmentCorruptError, load_segment_file,
                                    read_manifest, save_segment_file,
                                    segment_block_from_bytes,
                                    segment_file_crc, segment_name,
                                    wal_name, write_manifest)
from repro.persist.recovery import Recovered, StorePersistence, open_store
from repro.persist.wal import (REC_ADVANCE, REC_DRAIN, REC_OPS, REC_PENDING,
                               REC_SEAL, REC_TAIL, WriteAheadLog,
                               iter_frames, read_records, scan, scan_bytes)

__all__ = [
    "open_store", "Recovered", "StorePersistence", "WriteAheadLog",
    "read_records", "scan", "scan_bytes", "iter_frames",
    "read_manifest", "write_manifest", "save_segment_file",
    "load_segment_file", "segment_file_crc", "segment_block_from_bytes",
    "SegmentCorruptError", "wal_name", "segment_name",
    "REC_OPS", "REC_ADVANCE", "REC_SEAL", "REC_PENDING", "REC_DRAIN",
    "REC_TAIL",
]
