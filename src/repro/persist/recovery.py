"""Durability glue + crash recovery for ``TemporalGraphStore``.

``StorePersistence`` is the object a durable store carries as
``store.persist``: the store's ingest/advance/seal paths call its
``log_*``/``on_seal`` hooks (no-ops while ``replaying``), the serving
layer logs pending/drain events through it, and ``checkpoint`` rotates
the WAL behind an atomically renamed manifest.

``open_store`` is the recovery entry point.  On a fresh root it
creates the store and the initial (manifest, WAL) pair; on an existing
root it rebuilds the exact pre-crash store:

1. manifest -> config, sealed-segment files (mmap'd — cold history is
   paged in on demand), anchor times, current WAL.
2. WAL base record (``REC_TAIL``) -> open-tail columns + cursors;
   then one vectorized pass over segments+tail rebuilds the host
   mirror, the edge-slot registry, and ``current`` by reconstructing
   from the empty graph over the full delta — exact by the same LWW
   reconstruction property every query relies on (Theorem 1 with the
   empty anchor), so recovered query results are bit-identical to a
   from-scratch store's.
3. the remaining records replay through the store's own public
   ``ingest``/``advance_to``/``seal_tail`` (all deterministic given
   identical state), and pending/drain records rebuild the serving
   buffer, which the caller hands back to ``LiveGraphStore``.

Replay is idempotent with respect to the policy question: if the same
materialization policy is attached, replayed advances re-materialize
and re-seal exactly as the original run did and the following seal
records no-op; with no policy, the seal records make the identical
cuts themselves.  Either way the segment files written before the
crash match the segments replay produces, byte for byte.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterable

import numpy as np

from repro.core.delta import ADD_EDGE, ADD_NODE, REM_NODE
from repro.obs import clock
from repro.obs.metrics import default_registry
from repro.obs.trace import trace_span
from repro.persist import manifest as mf
from repro.persist import wal as walmod
from repro.persist.wal import WriteAheadLog


@dataclasses.dataclass
class Recovered:
    """What ``open_store`` hands back: the rebuilt store (persistence
    attached and live) plus the serving-layer pending ops that were
    WAL-durable but not yet drained at the crash — feed them to
    ``LiveGraphStore(store=..., pending=...)``."""

    store: object
    pending: list


class StorePersistence:
    """WAL + manifest lifecycle for one durable store root."""

    def __init__(self, root: str, *, fsync: bool = True, metrics=None):
        self.root = root
        self.fsync = bool(fsync)
        self.metrics = default_registry() if metrics is None else metrics
        self._m_ckpt = self.metrics.counter(
            "persist_checkpoints_total", "WAL rotations completed")
        self._m_ckpt_s = self.metrics.histogram(
            "persist_checkpoint_seconds",
            "checkpoint duration (base record + manifest rename)")
        self.replaying = False
        self.closed = False
        # the epoch swap drains pending ops through ingest/advance_to;
        # its REC_DRAIN record subsumes both, so their own records are
        # suppressed for the duration (seal records are NOT — replay
        # without the policy attached still needs the cuts)
        self._suspend_store_log = False
        self.wal_seq = 1
        self.wal: WriteAheadLog | None = None
        # CRC32 stamp per segment index, recorded at the (single) write
        # of each immutable file and carried into every manifest — the
        # fetch/open side re-verifies content against it
        self._seg_crcs: dict[int, int] = {}
        os.makedirs(os.path.join(root, mf.SEGMENT_DIR), exist_ok=True)

    # ------------------------------------------------------------- plumbing
    def _wal_path(self, seq: int) -> str:
        return os.path.join(self.root, mf.wal_name(seq))

    def _clean_stray_wals(self) -> None:
        """Delete WAL files other than the manifest-named one: an older
        seq survives a crash between the manifest rename and the old
        log's unlink (its content is subsumed by the new base record);
        a newer seq survives a crash *before* the rename (its content
        was derived from state the current WAL still replays to)."""
        keep = mf.wal_name(self.wal_seq)
        for name in os.listdir(self.root):
            if name.startswith("wal_") and name != keep:
                try:
                    os.remove(os.path.join(self.root, name))
                except OSError:
                    pass
            elif name.endswith(".tmp"):
                try:
                    os.remove(os.path.join(self.root, name))
                except OSError:
                    pass
        seg_dir = os.path.join(self.root, mf.SEGMENT_DIR)
        for name in os.listdir(seg_dir):
            if name.endswith(".tmp"):    # crashed mid-atomic-write
                try:
                    os.remove(os.path.join(seg_dir, name))
                except OSError:
                    pass

    # ---------------------------------------------------------- WAL hooks
    def log_ops(self, ops: Iterable) -> None:
        if not (self.replaying or self._suspend_store_log or self.closed):
            self.wal.log_ops(ops)

    def log_advance(self, t: int) -> None:
        if not (self.replaying or self._suspend_store_log or self.closed):
            self.wal.log_advance(t)

    def log_pending(self, ops: Iterable) -> None:
        if not (self.replaying or self.closed):
            self.wal.log_pending(ops)

    def log_drain(self, n: int, target: int) -> None:
        if not (self.replaying or self.closed):
            self.wal.log_drain(n, target)

    def suspend_store_log(self):
        """Context manager for the swap's drained ingest/advance."""
        persist = self

        class _Suspend:
            def __enter__(self):
                persist._suspend_store_log = True

            def __exit__(self, *exc):
                persist._suspend_store_log = False

        return _Suspend()

    def on_seal(self, store, segment, index: int, t_seal: int, k: int,
                force: bool) -> None:
        """Sealed-segment write hook: WAL the cut first, then persist
        the segment's compact host arrays once (atomic, immutable
        thereafter).  The record-before-file order matters: a file may
        only exist once the log pins the cut that produced it, so the
        write-if-missing check can trust any file it finds (a crash
        between the two leaves a record without a file, and replaying
        that record regenerates the identical segment and writes it
        here).  The reverse order could strand a stale orphan file that
        a post-recovery seal with a *different* cut would then adopt."""
        if self.closed:
            return
        if not self.replaying:
            self.wal.log_seal(t_seal, k, force)
        path = os.path.join(self.root, mf.segment_name(index))
        if not os.path.exists(path):
            self._seg_crcs[index] = segment.save(path)

    # ------------------------------------------------------------ rotation
    def _manifest_dict(self, store, wal_seq: int) -> dict:
        segments = []
        for i, s in enumerate(store._segments):
            path = os.path.join(self.root, mf.segment_name(i))
            if not os.path.exists(path):      # pre-attach segments
                self._seg_crcs[i] = s.save(path)
            if i not in self._seg_crcs:       # e.g. replay found the file
                self._seg_crcs[i] = mf.segment_file_crc(path)
            segments.append({"file": mf.segment_name(i),
                             "n_ops": int(s.n_ops),
                             "t_min": int(s.t_min), "t_max": int(s.t_max),
                             "crc32": int(self._seg_crcs[i])})
        return {
            "config": {"n_cap": int(store.n_cap), "e_cap": int(store.e_cap),
                       "layout": store.layout,
                       "segmented": bool(store.segmented),
                       "segment_min_ops": int(store.segment_min_ops),
                       "enforce_invertible": bool(store.enforce_invertible)},
            "t_sealed": int(store._t_sealed),
            "segments": segments,
            "anchors": [int(t) for t in store.materialized.times],
            "wal_seq": int(wal_seq),
        }

    def checkpoint(self, store, pending: Iterable = ()) -> None:
        """Rotate the WAL behind a fresh manifest: (1) write the next
        WAL with a base record capturing the open tail + the serving
        pending buffer, fsync'd; (2) atomically rename the manifest to
        point at it; (3) drop the old WAL.  A crash between any two
        steps leaves a consistent (manifest, WAL) pair — recovery
        ignores WAL files the manifest doesn't name."""
        if self.closed:
            return
        t0 = clock.now()
        with trace_span("persist.checkpoint", seq=self.wal_seq + 1):
            next_seq = self.wal_seq + 1
            new_wal = WriteAheadLog(self._wal_path(next_seq),
                                    fsync=self.fsync, repair=False,
                                    metrics=self.metrics)
            tail = store._tail_host()
            new_wal.append(walmod.encode_tail(
                store.t_cur, store._ops_since_mat, store._t_last_mat,
                tail))
            pending = list(pending)
            if pending:
                new_wal.log_pending(pending)
            mf.write_manifest(self.root,
                              self._manifest_dict(store, next_seq))
            old, self.wal, self.wal_seq = self.wal, new_wal, next_seq
            if old is not None:
                old.close(sync=False)    # it is deleted on the next line
                try:
                    os.remove(old.path)
                except OSError:
                    pass
        self._m_ckpt.inc()
        self._m_ckpt_s.observe(clock.now() - t0)

    def close(self) -> None:
        if self.wal is not None:
            self.wal.close()
        self.closed = True


# ------------------------------------------------------------------ rebuild

def _last_index_per(key: np.ndarray, size: int) -> np.ndarray:
    """Index of the last occurrence of each key value (or -1)."""
    out = np.full(size, -1, np.int64)
    if key.size:
        np.maximum.at(out, key, np.arange(key.size, dtype=np.int64))
    return out


def _rebuild_host_state(store, anchor_times: Iterable[int]) -> None:
    """One vectorized pass over segments + tail -> host mirror, slot
    registry, ``current``, and materialized anchors.

    The log IS the state: node liveness is the last node-op per id,
    edge validity the last edge-op per slot, the registry's canonical
    endpoints the first op that touched the slot, and every snapshot
    (``current`` included) is the LWW reconstruction from the empty
    graph over (0, t] — the exactness property the whole query engine
    is built on, which is what makes recovered results bit-identical
    rather than merely similar."""
    import jax.numpy as jnp

    from repro.core.graph import DenseGraph
    from repro.core.reconstruct import reconstruct_dense, reconstruct_edge

    ops, u, v, slot = store._op, store._u, store._v, store._slot

    node_sel = (ops == ADD_NODE) | (ops == REM_NODE)
    n_idx, n_op = u[node_sel], ops[node_sel]
    last = _last_index_per(n_idx, store.n_cap)
    nodes = np.zeros(store.n_cap, bool)
    seen = last >= 0
    nodes[seen] = n_op[last[seen]] == ADD_NODE
    store._nodes = nodes

    edge_sel = ~node_sel
    e_slot, e_op = slot[edge_sel], ops[edge_sel]
    e_u, e_v = u[edge_sel], v[edge_sel]
    n_slots = int(e_slot.max()) + 1 if e_slot.size else 0
    first = np.full(n_slots, np.iinfo(np.int64).max, np.int64)
    if e_slot.size:
        np.minimum.at(first, e_slot,
                      np.arange(e_slot.size, dtype=np.int64))
    # slots are assigned densely in first-touch order, so every slot
    # below the max has a first occurrence
    eu = np.minimum(e_u[first], e_v[first]).astype(np.int64)
    ev = np.maximum(e_u[first], e_v[first]).astype(np.int64)
    e_last = _last_index_per(e_slot, n_slots)
    emask = e_op[e_last] == ADD_EDGE
    store._eu_l = [int(x) for x in eu]
    store._ev_l = [int(x) for x in ev]
    store._emask_l = [bool(x) for x in emask]
    store._next_edge_slot = n_slots
    store._edge_slots = {(int(a), int(b)): i
                         for i, (a, b) in enumerate(zip(eu, ev))}
    store._adj_host = {(int(a), int(b)): bool(m)
                       for a, b, m in zip(eu, ev, emask)}
    store._invalidate()

    if store.log_len and store.t_cur > 0:
        delta = store.delta()
        if store.layout == "edge":
            reg = store.edge_graph()
            empty = dataclasses.replace(
                reg, nodes=jnp.zeros_like(reg.nodes),
                emask=jnp.zeros_like(reg.emask))
            store.current = reconstruct_edge(empty, delta, 0, store.t_cur)
        else:
            empty = DenseGraph(
                nodes=jnp.zeros((store.n_cap,), bool),
                adj=jnp.zeros((store.n_cap, store.n_cap), bool))
            store.current = reconstruct_dense(empty, delta, 0, store.t_cur)
            for t_a in sorted(int(t) for t in anchor_times):
                store.materialized.add(
                    t_a, reconstruct_dense(empty, delta, 0, t_a))


def _ops_from_rows(rows: np.ndarray) -> list:
    from repro.core.store import Op
    return [Op(int(o), int(a), int(b), int(t)) for o, a, b, t in rows]


def _replay(store, records, pending: list) -> None:
    """Feed post-checkpoint WAL records through the store's public
    mutation API.  Every step is deterministic given identical state
    (ingest's legality filtering included), so divergence can only
    mean a corrupted-but-CRC-valid log — fail loudly."""
    counts: dict[int, int] = {}
    for rtype, rec in records:
        counts[rtype] = counts.get(rtype, 0) + 1
        if rtype == walmod.REC_OPS:
            batch = _ops_from_rows(rec["rows"])
            n = store.ingest(batch)
            if n != len(batch):
                raise RuntimeError(
                    f"WAL replay diverged: {len(batch) - n} logged ops "
                    "rejected on replay")
        elif rtype == walmod.REC_ADVANCE:
            store.advance_to(int(rec["t"]))
        elif rtype == walmod.REC_SEAL:
            store.seal_tail(int(rec["t"]), force=rec["force"])
        elif rtype == walmod.REC_PENDING:
            pending.extend(_ops_from_rows(rec["rows"]))
        elif rtype == walmod.REC_DRAIN:
            batch, target = pending[:rec["n"]], int(rec["target"])
            del pending[:rec["n"]]
            store.ingest(batch)          # legality re-derived, as at runtime
            store.advance_to(target)
        elif rtype == walmod.REC_TAIL:
            raise RuntimeError("WAL has a base record past the first "
                               "position — rotation wrote a corrupt log")
    reg = default_registry()
    for rtype, n in counts.items():
        reg.counter("persist_recovery_records_total",
                    "WAL records replayed during recovery",
                    type=walmod.REC_NAMES[rtype]).inc(n)


def open_store(root: str, *, n_cap: int | None = None,
               e_cap: int | None = None, layout: str | None = None,
               policy=None, segment_min_ops: int | None = None,
               segment_device_budget: int | None = None,
               enforce_invertible: bool | None = None,
               fsync: bool = True, verify: bool = False,
               readonly: bool = False, metrics=None) -> Recovered:
    """Open (or create) a durable store root.

    Fresh root: builds a ``TemporalGraphStore`` from the keyword
    config (``n_cap`` required), attaches persistence, and writes the
    initial (manifest, WAL) pair.  Existing root: the manifest's
    config wins (explicit ``n_cap``/``layout`` arguments are checked
    against it — catching an accidental open of somebody else's root —
    and the rest are ignored); ``policy`` and
    ``segment_device_budget`` are runtime attachments, never persisted.

    Segment files whose manifest entry carries a ``crc32`` stamp are
    re-verified against it at open — a bit-flipped block raises
    ``SegmentCorruptError`` instead of serving silently wrong history.
    ``verify=True`` additionally cross-checks each file's (n_ops,
    t_min, t_max) against its manifest entry; the WAL is CRC-framed
    per record regardless.

    ``readonly=True`` is the replica open: it recovers the exact state
    the artifacts describe (manifest -> segments -> WAL-prefix replay,
    torn tails tolerated) but attaches NO persistence — the WAL is
    never repaired, truncated, or reopened for append, no stray-file
    cleanup runs, and the returned store has ``persist=None`` so its
    mutation paths log nothing.  The root may be another process's
    live directory or a replica's local mirror of one.
    """
    from repro.core.segments import Segment, build_merged_nodes
    from repro.core.store import TemporalGraphStore

    manifest = mf.read_manifest(root) if os.path.isdir(root) else None
    if manifest is None:
        if readonly:
            raise ValueError(f"{root!r} has no manifest — a readonly "
                             "open cannot create a store")
        if n_cap is None:
            raise ValueError(f"{root!r} has no manifest and no n_cap was "
                             "given to create a fresh store")
        os.makedirs(root, exist_ok=True)
        store = TemporalGraphStore(
            n_cap, e_cap=e_cap, policy=policy,
            enforce_invertible=(True if enforce_invertible is None
                                else enforce_invertible),
            layout=layout or "dense",
            segment_min_ops=(64 if segment_min_ops is None
                             else segment_min_ops),
            segment_device_budget=segment_device_budget)
        persist = StorePersistence(root, fsync=fsync, metrics=metrics)
        persist.wal = WriteAheadLog(persist._wal_path(1), fsync=fsync,
                                    repair=False, metrics=persist.metrics)
        persist.wal.append(walmod.encode_tail(0, 0, 0, store._tail_host()))
        mf.write_manifest(root, persist._manifest_dict(store, 1))
        store.persist = persist
        return Recovered(store=store, pending=[])

    cfg = manifest["config"]
    for name, given in (("n_cap", n_cap), ("layout", layout),
                        ("e_cap", e_cap)):
        if given is not None and given != cfg[name]:
            raise ValueError(f"{root}: manifest has {name}={cfg[name]!r}, "
                             f"open asked for {given!r}")
    store = TemporalGraphStore(
        cfg["n_cap"], e_cap=cfg["e_cap"], policy=policy,
        enforce_invertible=cfg["enforce_invertible"], layout=cfg["layout"],
        segmented=cfg["segmented"], segment_min_ops=cfg["segment_min_ops"],
        segment_device_budget=segment_device_budget)

    for entry in manifest["segments"]:
        seg = Segment.load(os.path.join(root, entry["file"]),
                           expected_crc=entry.get("crc32"))
        if verify and (seg.n_ops != entry["n_ops"]
                       or seg.t_min != entry["t_min"]
                       or seg.t_max != entry["t_max"]):
            raise ValueError(f"{entry['file']}: content does not match "
                             "its manifest entry")
        store._segments.append(seg)
    store._t_sealed = int(manifest["t_sealed"])
    build_merged_nodes(store._segments, store._merged)

    wal_seq = int(manifest["wal_seq"])
    wal_path = os.path.join(root, mf.wal_name(wal_seq))
    records = list(walmod.read_records(wal_path)) \
        if os.path.exists(wal_path) else []
    if not records or records[0][0] != walmod.REC_TAIL:
        raise RuntimeError(f"{wal_path}: missing or torn base record — "
                           "the manifest names a WAL that never became "
                           "durable")
    base = records[0][1]
    store._op_l = [int(x) for x in base["cols"]["op"]]
    store._u_l = [int(x) for x in base["cols"]["u"]]
    store._v_l = [int(x) for x in base["cols"]["v"]]
    store._slot_l = [int(x) for x in base["cols"]["slot"]]
    store._t_l = [int(x) for x in base["cols"]["t"]]
    store.t_cur = int(base["t_cur"])
    store._ops_since_mat = int(base["ops_since_mat"])
    store._t_last_mat = int(base["t_last_mat"])

    _rebuild_host_state(store, manifest["anchors"])

    pending: list = []
    if readonly:
        # no persistence attached: replay through the public mutation
        # API exactly as below (store.persist is None, so nothing
        # logs), leave the artifacts byte-untouched
        _replay(store, records[1:], pending)
        return Recovered(store=store, pending=pending)

    persist = StorePersistence(root, fsync=fsync, metrics=metrics)
    persist.wal_seq = wal_seq
    for i, entry in enumerate(manifest["segments"]):
        if entry.get("crc32") is not None:
            persist._seg_crcs[i] = int(entry["crc32"])
    persist.replaying = True
    try:
        store.persist = persist
        _replay(store, records[1:], pending)
    finally:
        persist.replaying = False
    # reopen the WAL for appends (truncating any torn tail the scan
    # stopped at) only now, so a failed replay never modifies the log
    persist.wal = WriteAheadLog(wal_path, fsync=fsync, repair=True,
                                metrics=persist.metrics)
    persist._clean_stray_wals()
    return Recovered(store=store, pending=pending)
