"""One-program incremental time-sweep (`evolve`) executor.

A sweep query asks for a measure at every sample time

    t_lo, t_lo + stride, ..., t_lo + (B-1)·stride  (≤ t_hi)

Point-query serving pays B reconstructions whose delta windows overlap
almost entirely; DeltaGraph (arXiv 1207.5777) observes the shared path
should be paid once.  Here the whole sweep is ONE device program:

1. reconstruct SG_{t_lo} from the group anchor (the only LWW pass),
2. scatter every in-sweep op into per-sample integer NET counts
   (``sweep_nets``) — op at time t lands in sample ceil((t-t_lo)/stride),
   the first sample that observes it,
3. a ``lax.scan`` alternates apply-net / measure: carry is the exact
   integer state (degrees, node validity, node count, edge count), each
   step emits the registered measure.

Bit-exactness vs B point queries is *not* approximate: the store's
transition log is legal (``GraphStore._apply_host`` refuses double-adds
and ghost-removes), so signed per-sample net counts reproduce the true
integer state at every sample, and every SWEEP measure is a fixed f32
expression of those integers — copied verbatim from ``core.queries``,
so the floats are bit-identical too.

The NET scatter is why the sweep-window delta operand must be LEAF
segments, never merged-tree nodes: the LWW collapse drops superseded
ops, which leaves LWW reconstruction invariant but corrupts signed
counts.  (The anchor→t_lo operand ``d_rec`` is a pure LWW input and
may be tree-covered.)  See ``core.segments``.

``SWEEP_MEASURES`` is the registry: measures expressible as a pure
function of the swept integer state.  Everything else falls back to B
independent point queries in ``store.evolve``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, ADD_NODE, Delta
from repro.core.graph import EdgeGraph
from repro.core.queries import DEGREE_DIST_BINS, _degree_histogram
from repro.core.reconstruct import reconstruct_dense, reconstruct_edge

# Measures the incremental executor supports on BOTH layouts: pure
# functions of (degrees, node validity, num_nodes, num_edges).
SWEEP_MEASURES = ("degree", "num_nodes", "num_edges", "density",
                  "avg_degree", "degree_distribution")


def sweep_nets(delta: Delta, t_lo, t_last, stride: int, num_buckets: int,
               n_cap: int):
    """Per-sample signed NET counts from the sweep-window ops.

    An op at time t is first observed by sample k = ceil((t-t_lo)/stride)
    (samples sit at t_lo + k·stride; windows are half-open (·, ·]).
    Sample 0 *is* t_lo, so k ≥ 1 for every in-window op and row 0 is
    always zero — the scan's init carry is the state at t_lo.

    Returns (deg_net i32[B,N], node_net i32[B,N], ne_net i32[B],
    nn_net i32[B]).
    """
    win = delta.valid_mask() & delta.window_mask(t_lo, t_last)
    # guard the bucket arithmetic against T_PAD overflow: padding rows
    # carry weight 0 anyway, so pin them to sample 1
    t = jnp.where(win, delta.t, t_lo + 1)
    k = jnp.clip((t - t_lo + stride - 1) // stride, 0, num_buckets - 1)
    sign = jnp.where((delta.op == ADD_EDGE) | (delta.op == ADD_NODE), 1, -1)
    is_e = delta.is_edge_op()
    we = jnp.where(win & is_e, sign, 0).astype(jnp.int32)
    wn = jnp.where(win & ~is_e, sign, 0).astype(jnp.int32)
    deg_net = (jnp.zeros((num_buckets, n_cap), jnp.int32)
               .at[k, delta.u].add(we).at[k, delta.v].add(we))
    node_net = jnp.zeros((num_buckets, n_cap), jnp.int32).at[k, delta.u].add(wn)
    ne_net = jnp.zeros((num_buckets,), jnp.int32).at[k].add(we)
    nn_net = jnp.zeros((num_buckets,), jnp.int32).at[k].add(wn)
    return deg_net, node_net, ne_net, nn_net


def measure_from_state(measure: str, scope: str, v, deg, nodes_i, nn, ne):
    """The registered measure as a function of the swept integer state.

    Expressions are verbatim from ``core.queries`` (both layouts share
    them there too) — this is what makes sweep samples bit-equal to
    point queries, f32 measures included.
    """
    if scope == "node":
        if measure == "degree":
            return deg[v]
        raise ValueError(f"measure {measure!r} is not sweepable")
    if measure == "num_nodes":
        return nn
    if measure == "num_edges":
        return ne
    if measure == "density":
        n = nn.astype(jnp.float32)
        e = ne.astype(jnp.float32)
        return jnp.where(n > 1, 2.0 * e / (n * (n - 1.0)), 0.0)
    if measure == "avg_degree":
        n = jnp.maximum(nn, 1).astype(jnp.float32)
        return 2.0 * ne.astype(jnp.float32) / n
    if measure == "degree_distribution":
        return _degree_histogram(deg, nodes_i.astype(bool), DEGREE_DIST_BINS)
    raise ValueError(f"measure {measure!r} is not sweepable")


def sweep_scan(measure: str, scope: str, v, deg0, nodes0, nn0, ne0, nets):
    """apply-net / measure alternation: one scan step per sample."""

    def step(carry, net):
        deg, nod, nn, ne = carry
        deg_net, node_net, ne_net, nn_net = net
        carry = (deg + deg_net, nod + node_net, nn + nn_net, ne + ne_net)
        out = measure_from_state(measure, scope, v, *carry)
        return carry, out

    _, outs = jax.lax.scan(step, (deg0, nodes0.astype(jnp.int32),
                                  nn0, ne0), nets)
    return outs


@functools.partial(jax.jit, static_argnames=("measure", "scope", "stride",
                                             "num_buckets"))
def batch_evolve(anchor, d_rec: Delta, d_net: Delta, t_anchor,
                 t_los, widths, vs, *, measure: str, scope: str,
                 stride: int, num_buckets: int):
    """The engine's sweep-group entry point: one program for Q sweeps.

    ``anchor``/``d_rec``/``t_anchor`` reconstruct each query's start
    state (``d_rec`` may be merged-tree-covered — LWW only);
    ``d_net`` is the LEAF delta covering every query's sweep window.
    ``t_los``/``widths``/``vs`` are i32[Q]; all queries in the group
    share (measure, scope, stride) by the planner's group key.

    Output: [Q, num_buckets] (i32 or f32 per measure), or
    [Q, num_buckets, bins] for degree_distribution.  Samples past a
    query's width repeat its last state — callers slice ``[:width]``.
    """
    n_cap = anchor.n_cap
    edge_layout = isinstance(anchor, EdgeGraph)

    def one(t_lo, width, v):
        if edge_layout:
            g = reconstruct_edge(anchor, d_rec, t_anchor, t_lo)
        else:
            g = reconstruct_dense(anchor, d_rec, t_anchor, t_lo)
        t_last = t_lo + (width - 1) * stride
        nets = sweep_nets(d_net, t_lo, t_last, stride, num_buckets, n_cap)
        return sweep_scan(measure, scope, v, g.degrees(), g.nodes,
                          g.num_nodes(), g.num_edges(), nets)

    return jax.vmap(one)(t_los, widths, vs)
