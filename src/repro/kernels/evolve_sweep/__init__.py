from repro.kernels.evolve_sweep.ops import (SWEEP_MEASURES, batch_evolve,
                                            measure_from_state, sweep_nets,
                                            sweep_scan)
from repro.kernels.evolve_sweep.ref import evolve_ref
from repro.kernels.evolve_sweep.sweep import (bucket_sweep_events,
                                              sweep_degree_series,
                                              sweep_series_tiles)

__all__ = ["SWEEP_MEASURES", "batch_evolve", "sweep_nets", "sweep_scan",
           "measure_from_state", "evolve_ref", "bucket_sweep_events",
           "sweep_degree_series", "sweep_series_tiles"]
