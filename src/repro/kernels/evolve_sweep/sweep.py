"""Pallas TPU kernel: forward degree sweep over node tiles.

The forward twin of ``kernels.degree_series``: that kernel walks BACK
from the frontier degrees (hybrid plan); a sweep walks FORWARD from the
reconstructed degrees at t_lo, with samples every ``stride`` time units
instead of every unit:

  deg(v, t_lo + b·stride) = deg0(v) + Σ_{b' ≤ b} net[b', v]

Grid: 1-D over node tiles.  ``bucket_sweep_events`` builds the same
dense per-tile event blocks i32[T, cap, 4] ([local_node, sample, sign,
valid]) as ``degree_series.ops.bucket_node_events``, but buckets by
first-observing sample ceil((t − t_lo)/stride).  Kernel: scatter the
per-(sample, node) nets into VMEM, then a forward running sum.

This is the tiled specialization of the sweep executor for the
node-degree measure; ``ops.batch_evolve`` is the general (all-measure,
both-layout, vmappable) path and the two are asserted bit-equal in
``tests/test_evolve.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.delta import ADD_EDGE, Delta


@functools.partial(jax.jit,
                   static_argnames=("n", "stride", "num_buckets", "tile",
                                    "cap"))
def bucket_sweep_events(delta: Delta, n: int, t_lo, t_last, stride: int,
                        num_buckets: int, tile: int, cap: int):
    """Dense per-node-tile sweep event blocks i32[T, cap, 4].

    Each in-window edge op (t in (t_lo, t_last]) yields one event per
    endpoint at sample ceil((t − t_lo)/stride); entries are
    [local_node, sample, sign, valid]."""
    m = delta.capacity
    tcount = n // tile
    e = (delta.valid_mask() & delta.is_edge_op()
         & (delta.t > t_lo) & (delta.t <= t_last))
    sign = jnp.where(delta.op == ADD_EDGE, 1, -1)
    t = jnp.where(e, delta.t, t_lo + 1)          # T_PAD overflow guard
    b = jnp.clip((t - t_lo + stride - 1) // stride, 0, num_buckets - 1)

    nodes = jnp.concatenate([delta.u, delta.v])
    ee = jnp.concatenate([e, e]) & (nodes < n)
    signs = jnp.concatenate([sign, sign])
    bs = jnp.concatenate([b, b])

    tile_id = jnp.where(ee, nodes // tile, tcount)
    order = jnp.argsort(tile_id, stable=True)
    tid_s = tile_id[order]
    seg_start = jnp.searchsorted(tid_s, jnp.arange(tcount + 1))
    pos = jnp.arange(2 * m) - seg_start[tid_s]
    overflow = jnp.any((pos >= cap) & (tid_s < tcount))
    keep = (tid_s < tcount) & (pos < cap)
    entries = jnp.stack([nodes[order] % tile, bs[order], signs[order],
                         jnp.ones_like(pos)], axis=1)
    blocks = jnp.zeros((tcount + 1, cap, 4), jnp.int32)
    blocks = blocks.at[jnp.where(keep, tid_s, tcount),
                       jnp.clip(pos, 0, cap - 1)].set(
        jnp.where(keep[:, None], entries, 0))
    return blocks[:tcount], overflow


def _kernel(ops_ref, deg_ref, out_ref, net_ref, *, cap: int,
            num_buckets: int):
    net_ref[...] = jnp.zeros_like(net_ref)

    def scatter(j, _):
        ln = ops_ref[0, j, 0]
        b = ops_ref[0, j, 1]
        sign = ops_ref[0, j, 2]
        valid = ops_ref[0, j, 3]
        cur = pl.load(net_ref, (pl.ds(b, 1), pl.ds(ln, 1)))
        pl.store(net_ref, (pl.ds(b, 1), pl.ds(ln, 1)),
                 cur + jnp.where(valid > 0, sign, 0).reshape(1, 1))
        return 0

    jax.lax.fori_loop(0, cap, scatter, 0)

    def fwd(b, acc):
        acc = acc + net_ref[b, :]
        out_ref[b, :] = deg_ref[0, :] + acc
        return acc

    jax.lax.fori_loop(0, num_buckets, fwd,
                      jnp.zeros_like(net_ref[0, :]), unroll=False)


@functools.partial(jax.jit,
                   static_argnames=("tile", "cap", "num_buckets",
                                    "interpret"))
def sweep_series_tiles(deg0: jax.Array, tile_ops: jax.Array,
                       tile: int = 256, cap: int = 1024,
                       num_buckets: int = 64,
                       interpret: bool = True) -> jax.Array:
    """deg0: i32[N]; tile_ops: i32[T, cap, 4] → i32[num_buckets, N]."""
    n = deg0.shape[0]
    assert n % tile == 0
    grid = (n // tile,)
    return pl.pallas_call(
        functools.partial(_kernel, cap=cap, num_buckets=num_buckets),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((num_buckets, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_buckets, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((num_buckets + 1, tile), jnp.int32)],
        interpret=interpret,
    )(tile_ops, deg0.reshape(1, n))


def sweep_degree_series(deg0: jax.Array, delta: Delta, t_lo, t_last,
                        stride: int, num_buckets: int, tile: int = 256,
                        cap: int = 1024, interpret: bool = True):
    """i32[num_buckets, N]: every node's degree at each sweep sample.

    Row b holds deg(·, t_lo + b·stride); rows past the last real sample
    repeat it (no later events scatter there)."""
    n = deg0.shape[0]
    pad = (-n) % tile
    deg = jnp.pad(deg0, (0, pad)) if pad else deg0
    blocks, overflow = bucket_sweep_events(delta, n + pad, t_lo, t_last,
                                           stride, num_buckets, tile, cap)
    out = sweep_series_tiles(deg, blocks, tile=tile, cap=cap,
                             num_buckets=num_buckets, interpret=interpret)
    return out[:, :n], overflow
