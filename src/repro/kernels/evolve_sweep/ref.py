"""Reference sweep: B independent point reconstructions + measures.

This is the semantics ``batch_evolve`` must bit-match — exactly what a
client pays today by issuing B point queries.  Used by the parity
tests and by ``benchmarks/bench_sweep.py`` as the baseline side of the
speedup measurement.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.delta import Delta
from repro.core.graph import EdgeGraph
from repro.core.queries import (EDGE_GLOBAL_MEASURES, EDGE_NODE_MEASURES,
                                GLOBAL_MEASURES, NODE_MEASURES)
from repro.core.reconstruct import reconstruct_dense, reconstruct_edge


def evolve_ref(anchor, delta: Delta, t_anchor, t_lo, t_hi, stride: int,
               measure: str, scope: str, v=None):
    """One reconstruction per sample — the O(B · window) baseline."""
    edge_layout = isinstance(anchor, EdgeGraph)
    if edge_layout:
        table = EDGE_NODE_MEASURES if scope == "node" else EDGE_GLOBAL_MEASURES
    else:
        table = NODE_MEASURES if scope == "node" else GLOBAL_MEASURES
    fn = table[measure]
    outs = []
    for t in range(int(t_lo), int(t_hi) + 1, int(stride)):
        if edge_layout:
            g = reconstruct_edge(anchor, delta, t_anchor, t)
        else:
            g = reconstruct_dense(anchor, delta, t_anchor, t)
        outs.append(fn(g, v) if scope == "node" else fn(g))
    return jnp.stack(outs)
