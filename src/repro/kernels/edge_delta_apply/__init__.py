from repro.kernels.edge_delta_apply.edge_delta_apply import (
    edge_delta_apply_tiles)
from repro.kernels.edge_delta_apply.ops import (bucket_slot_ops,
                                               edge_delta_apply,
                                               edge_delta_apply_slot_block)
from repro.kernels.edge_delta_apply.ref import edge_delta_apply_ref

__all__ = ["edge_delta_apply", "edge_delta_apply_ref",
           "edge_delta_apply_tiles", "edge_delta_apply_slot_block",
           "bucket_slot_ops"]
