"""Pallas TPU kernel: slot-block LWW delta application to an edge
registry.

The edge-slot analogue of ``kernels/delta_apply`` (DESIGN.md §2.1): the
persistent edge-slot validity mask ``emask[E]`` is tiled 1-D over the
slot axis; ops.py pre-resolves the window's edge ops to slot ids,
buckets them *by destination slot tile* and pre-orders them so that a
plain sequential overwrite inside each tile realizes last-writer-wins
for either reconstruction direction:

  forward  — ops ascending in time, write value = (op == addEdge)
  backward — ops descending in time, write value = (op == remEdge)
             (the "first op after t′ decides" rule, Definition 5)

Each grid instance owns one VMEM slot tile and replays only its own op
segment (dense (CAP, 4) int32 block: [local_slot, value, valid, 0]),
so total work is O(window ops + tiles·pad) and state is O(E) — no N²
anywhere.  Unlike the dense kernel an edge op contributes ONE entry
(its slot), not two (u,v)/(v,u) mirrors.

VMEM budget per instance: TILE·4 bytes (mask tile, int32) + CAP·4·4
bytes (op block).  Defaults TILE=512, CAP=1024 → ~18 KiB, far under
the ~16 MiB/core VMEM of a v5e; TILE is kept a multiple of 128 to stay
lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ops_ref, mask_ref, out_ref, *, cap: int):
    out_ref[...] = mask_ref[...]

    def body(j, _):
        ls = ops_ref[0, j, 0]
        val = ops_ref[0, j, 1]
        valid = ops_ref[0, j, 2]
        cur = pl.load(out_ref, (pl.ds(0, 1), pl.ds(ls, 1)))
        new = jnp.where(valid > 0, val.astype(jnp.int32), cur[0, 0])
        pl.store(out_ref, (pl.ds(0, 1), pl.ds(ls, 1)),
                 jnp.full((1, 1), new, jnp.int32))
        return 0

    jax.lax.fori_loop(0, cap, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("tile", "cap", "interpret"))
def edge_delta_apply_tiles(emask: jax.Array, tile_ops: jax.Array,
                           tile: int = 512, cap: int = 1024,
                           interpret: bool = True) -> jax.Array:
    """Apply pre-bucketed slot-tile op lists to the edge mask.

    emask:    i32[E] (0/1) — E a multiple of ``tile``.  A full registry
              for a single-device snapshot; one slot shard of a
              slot-sharded mesh (ops.bucket_slot_ops builds matching
              blocks via ``slot0``).
    tile_ops: i32[T, cap, 4] — per-tile [local_slot, value, valid, 0]
    returns:  i32[E]
    """
    e = emask.shape[0]
    assert e % tile == 0, (e, tile)
    grid = (e // tile,)
    out = pl.pallas_call(
        functools.partial(_kernel, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, e), jnp.int32),
        interpret=interpret,
    )(tile_ops, emask.reshape(1, e))
    return out.reshape(e)
