"""Pure-jnp oracle for edge_delta_apply: scatter-argmin/argmax LWW
over slots (independent of ``core.reconstruct.reconstruct_edge`` so
kernel tests cross-check two formulations)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, ADD_NODE, Delta
from repro.core.graph import EdgeGraph


@jax.jit
def edge_delta_apply_ref(anchor: EdgeGraph, delta: Delta, t_anchor,
                         t_query) -> EdgeGraph:
    e = anchor.e_cap
    m = delta.capacity
    forward = t_query >= t_anchor
    t_lo = jnp.minimum(t_anchor, t_query)
    t_hi = jnp.maximum(t_anchor, t_query)
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    idx = jnp.arange(m, dtype=jnp.int32)

    ew = in_win & delta.is_edge_op()
    first = jnp.full((e,), m, jnp.int32).at[delta.slot].min(
        jnp.where(ew, idx, m))
    last = jnp.full((e,), -1, jnp.int32).at[delta.slot].max(
        jnp.where(ew, idx, -1))
    dec_f = last >= 0
    val_f = delta.op[jnp.clip(last, 0)] == ADD_EDGE
    dec_b = first < m
    val_b = delta.op[jnp.clip(first, None, m - 1)] != ADD_EDGE
    dec = jnp.where(forward, dec_f, dec_b)
    val = jnp.where(forward, val_f, val_b)
    emask = jnp.where(dec, val, anchor.emask)

    nw = in_win & delta.is_node_op()
    n = anchor.n_cap
    firstn = jnp.full((n,), m, jnp.int32).at[delta.u].min(
        jnp.where(nw, idx, m))
    lastn = jnp.full((n,), -1, jnp.int32).at[delta.u].max(
        jnp.where(nw, idx, -1))
    dec_n = jnp.where(forward, lastn >= 0, firstn < m)
    val_n = jnp.where(forward,
                      delta.op[jnp.clip(lastn, 0)] == ADD_NODE,
                      delta.op[jnp.clip(firstn, None, m - 1)] != ADD_NODE)
    nodes = jnp.where(dec_n, val_n, anchor.nodes)
    return dataclasses.replace(anchor, nodes=nodes, emask=emask)
