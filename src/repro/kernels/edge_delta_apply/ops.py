"""jit'd wrapper for the edge_delta_apply kernel: window filtering,
slot-tile bucketing, ordering, and the node-mask update (nodes are
N-sized and cheap — they stay on the XLA path, exactly like
``kernels/delta_apply``)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, REM_EDGE, Delta
from repro.core.graph import EdgeGraph
from repro.kernels.delta_apply.ops import _node_mask_lww
from repro.kernels.edge_delta_apply.edge_delta_apply import (
    edge_delta_apply_tiles)


@functools.partial(jax.jit, static_argnames=("e", "tile", "cap", "forward",
                                             "slot0", "n_valid_slots"))
def bucket_slot_ops(delta: Delta, e: int, t_lo, t_hi, tile: int, cap: int,
                    forward: bool, slot0: int = 0,
                    n_valid_slots: int | None = None):
    """Build the dense per-slot-tile op blocks i32[T, cap, 4].

    Every in-window edge op contributes ONE entry under its
    pre-resolved slot id (``delta.slot``, assigned host-side by the
    store) — the 1-D analogue of ``delta_apply.bucket_ops``'s (u,v)
    mirrors.  Entries are ordered so sequential overwrite ==
    last-writer-wins: ascending time for forward, descending for
    backward.  Per-tile overflow beyond ``cap`` is detected and
    returned as a flag.

    ``slot0``/``n_valid_slots`` make the bucketing *shard-safe*: a
    device that owns only slots [slot0, slot0 + n_valid_slots) buckets
    exactly the ops landing in its slot block, with its own tile
    padding — per-shard blocks concatenate to the full grid and the
    kernel runs unchanged on one slot shard.  ``n_valid_slots``
    (default ``e``) caps the kept slots below the tile-padded count so
    the next shard's ops never leak into this shard's pad band.
    """
    m = delta.capacity
    n_valid_slots = e if n_valid_slots is None else n_valid_slots
    tcount = e // tile
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    ee = in_win & delta.is_edge_op()
    val = (delta.op == (ADD_EDGE if forward else REM_EDGE)).astype(jnp.int32)

    order_rank = jnp.arange(m)
    if not forward:
        order_rank = (m - 1) - order_rank  # descending time

    ls = delta.slot - slot0              # slot local to this shard
    ee = ee & (ls >= 0) & (ls < n_valid_slots)
    ls = jnp.clip(ls, 0, max(e - 1, 0))
    tile_id = jnp.where(ee, ls // tile, tcount)
    # sort by (tile, rank): stable two-pass — first by rank, then by tile
    o1 = jnp.argsort(order_rank, stable=True)
    t1 = tile_id[o1]
    o2 = jnp.argsort(t1, stable=True)
    perm = o1[o2]
    tid_s = tile_id[perm]
    # position of each entry within its tile bucket
    seg_start = jnp.searchsorted(tid_s, jnp.arange(tcount + 1))
    pos = jnp.arange(m) - seg_start[tid_s]
    overflow = jnp.any((pos >= cap) & (tid_s < tcount))

    dst_p = jnp.clip(pos, 0, cap - 1)
    entries = jnp.stack([ls[perm] % tile, val[perm],
                         jnp.ones_like(dst_p), jnp.zeros_like(dst_p)],
                        axis=1)
    blocks = jnp.zeros((tcount + 1, cap, 4), jnp.int32)
    keep = (tid_s < tcount) & (pos < cap)
    blocks = blocks.at[jnp.where(keep, tid_s, tcount),
                       dst_p].set(jnp.where(keep[:, None], entries, 0))
    return blocks[:tcount], overflow


def edge_delta_apply_slot_block(nodes: jnp.ndarray, emask_block: jnp.ndarray,
                                delta: Delta, t_anchor: int, t_query: int,
                                slot0: int, tile: int = 512,
                                cap: int = 1024, interpret: bool = True):
    """Kernel-backed LWW reconstruction of one edge-mask *slot block*
    (shard-safe: this is what each device of a slot-sharded mesh runs).

    ``emask_block`` is bool[S] — slots [slot0, slot0 + S) of the global
    registry.  Slot padding to the tile size is applied per block, so
    any shard width that divides into tiles (or pads up to one) works
    without touching other shards' slots.  ``nodes`` is the (full,
    replicated) node mask — N-sized, updated on the XLA path.
    """
    s = emask_block.shape[0]
    pad = (-s) % tile
    forward = bool(t_query >= t_anchor)
    t_lo, t_hi = min(t_anchor, t_query), max(t_anchor, t_query)

    mask = emask_block.astype(jnp.int32)
    if pad:
        mask = jnp.pad(mask, (0, pad))
    blocks, overflow = bucket_slot_ops(delta, s + pad, t_lo, t_hi, tile,
                                       cap, forward, slot0=slot0,
                                       n_valid_slots=s)
    out = edge_delta_apply_tiles(mask, blocks, tile=tile, cap=cap,
                                 interpret=interpret)
    emask_new = out[:s].astype(bool)
    nodes_new = _node_mask_lww(nodes, delta, t_lo, t_hi, forward, 0)
    return nodes_new, emask_new, overflow


def edge_delta_apply(anchor: EdgeGraph, delta: Delta, t_anchor: int,
                     t_query: int, tile: int = 512, cap: int = 1024,
                     interpret: bool = True):
    """Kernel-backed reconstruct_at for EdgeGraph (edge mask on the
    Pallas slot kernel, node mask via XLA scatter).  Returns
    (EdgeGraph, overflow flag)."""
    import dataclasses
    nodes, emask, overflow = edge_delta_apply_slot_block(
        anchor.nodes, anchor.emask, delta, t_anchor, t_query, 0,
        tile=tile, cap=cap, interpret=interpret)
    return dataclasses.replace(anchor, nodes=nodes, emask=emask), overflow
