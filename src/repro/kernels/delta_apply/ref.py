"""Pure-jnp oracle for delta_apply: scatter-argmin/argmax LWW."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, ADD_NODE, Delta
from repro.core.graph import DenseGraph


@jax.jit
def delta_apply_ref(anchor: DenseGraph, delta: Delta, t_anchor,
                    t_query) -> DenseGraph:
    n = anchor.n_cap
    m = delta.capacity
    forward = t_query >= t_anchor
    t_lo = jnp.minimum(t_anchor, t_query)
    t_hi = jnp.maximum(t_anchor, t_query)
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    idx = jnp.arange(m, dtype=jnp.int32)

    e = in_win & delta.is_edge_op()
    first = jnp.full((n, n), m, jnp.int32)
    last = jnp.full((n, n), -1, jnp.int32)
    for (r, c) in ((delta.u, delta.v), (delta.v, delta.u)):
        first = first.at[r, c].min(jnp.where(e, idx, m))
        last = last.at[r, c].max(jnp.where(e, idx, -1))
    dec_f = last >= 0
    val_f = delta.op[jnp.clip(last, 0)] == ADD_EDGE
    dec_b = first < m
    val_b = delta.op[jnp.clip(first, None, m - 1)] != ADD_EDGE
    dec = jnp.where(forward, dec_f, dec_b)
    val = jnp.where(forward, val_f, val_b)
    adj = jnp.where(dec, val, anchor.adj)

    nw = in_win & delta.is_node_op()
    firstn = jnp.full((n,), m, jnp.int32).at[delta.u].min(
        jnp.where(nw, idx, m))
    lastn = jnp.full((n,), -1, jnp.int32).at[delta.u].max(
        jnp.where(nw, idx, -1))
    dec_n = jnp.where(forward, lastn >= 0, firstn < m)
    val_n = jnp.where(forward,
                      delta.op[jnp.clip(lastn, 0)] == ADD_NODE,
                      delta.op[jnp.clip(firstn, None, m - 1)] != ADD_NODE)
    nodes = jnp.where(dec_n, val_n, anchor.nodes)
    return DenseGraph(nodes=nodes, adj=adj)
