"""Pallas TPU kernel: tiled delta application to a dense adjacency.

The TPU-native reconstruction (DESIGN.md §2.2): the adjacency bitmask is
tiled (TN × TN) over a 2-D grid; ops.py pre-buckets the window's edge
ops *by destination tile* (both (u,v) and (v,u) mirrors) and pre-orders
them so that a plain sequential overwrite inside each tile realizes
last-writer-wins for either reconstruction direction:

  forward  — ops ascending in time, write value = (op == addEdge)
  backward — ops descending in time, write value = (op == remEdge)
             (the "first op after t′ decides" rule, Definition 5)

Each grid instance owns one VMEM tile and replays only its own op
segment (dense (CAP, 4) int32 block: [local_u, local_v, value, valid]),
so total work is O(window ops + tiles·pad) with zero cross-tile
dependencies — the parallel reconstruction the paper leaves as future
work.

VMEM budget per instance: TN·TN bytes (adjacency tile, int8/bool) +
CAP·4·4 bytes (op block).  Defaults TN=256, CAP=1024 → ~80 KiB, far
under the ~16 MiB/core VMEM of a v5e; TN is kept a multiple of 128 to
stay lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ops_ref, anchor_ref, out_ref, *, cap: int):
    out_ref[...] = anchor_ref[...]

    def body(j, _):
        lu = ops_ref[0, 0, j, 0]
        lv = ops_ref[0, 0, j, 1]
        val = ops_ref[0, 0, j, 2]
        valid = ops_ref[0, 0, j, 3]
        cur = pl.load(out_ref, (pl.ds(lu, 1), pl.ds(lv, 1)))
        new = jnp.where(valid > 0, val.astype(jnp.int32), cur[0, 0])
        pl.store(out_ref, (pl.ds(lu, 1), pl.ds(lv, 1)),
                 jnp.full((1, 1), new, jnp.int32))
        return 0

    jax.lax.fori_loop(0, cap, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("tile", "cap", "interpret"))
def delta_apply_tiles(anchor_adj: jax.Array, tile_ops: jax.Array,
                      tile: int = 256, cap: int = 1024,
                      interpret: bool = True) -> jax.Array:
    """Apply pre-bucketed tile op lists to the adjacency.

    anchor_adj: i32[R, C] (0/1) — both dims multiples of ``tile``.
    R == C for a full snapshot; R < C for one row shard of a
    row-sharded mesh (ops.bucket_ops builds the matching blocks).
    tile_ops:   i32[Tr, Tc, cap, 4] — per-tile [lu, lv, value, valid]
    returns:    i32[R, C]
    """
    r, c = anchor_adj.shape
    assert r % tile == 0 and c % tile == 0, (r, c, tile)
    grid = (r // tile, c // tile)
    return pl.pallas_call(
        functools.partial(_kernel, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, cap, 4), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        interpret=interpret,
    )(tile_ops, anchor_adj)
