"""jit'd wrapper for the delta_apply kernel: window filtering, tile
bucketing, ordering, and the node-mask update (nodes are N-sized and
cheap — they stay on the XLA path)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE, Delta
from repro.core.graph import DenseGraph
from repro.kernels.delta_apply.delta_apply import delta_apply_tiles


@functools.partial(jax.jit, static_argnames=("n", "tile", "cap", "forward"))
def bucket_ops(delta: Delta, n: int, t_lo, t_hi, tile: int, cap: int,
               forward: bool):
    """Build the dense per-tile op blocks i32[Tr, Tc, cap, 4].

    Every in-window edge op contributes two entries ((u,v) and (v,u)).
    Entries are ordered so sequential overwrite == last-writer-wins:
    ascending time for forward, descending for backward.  Per-tile
    overflow beyond ``cap`` is detected and returned as a flag.
    """
    m = delta.capacity
    tr = n // tile
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    e = in_win & delta.is_edge_op()
    val = (delta.op == (ADD_EDGE if forward else REM_EDGE)).astype(jnp.int32)

    us = jnp.concatenate([delta.u, delta.v])
    vs = jnp.concatenate([delta.v, delta.u])
    ee = jnp.concatenate([e, e])
    vals = jnp.concatenate([val, val])
    order_rank = jnp.concatenate([jnp.arange(m), jnp.arange(m)])
    if not forward:
        order_rank = (m - 1) - order_rank  # descending time

    tile_id = jnp.where(ee, (us // tile) * tr + (vs // tile), tr * tr)
    # sort by (tile, rank): stable two-pass — first by rank, then by tile
    o1 = jnp.argsort(order_rank, stable=True)
    t1 = tile_id[o1]
    o2 = jnp.argsort(t1, stable=True)
    perm = o1[o2]
    tid_s = tile_id[perm]
    # position of each entry within its tile bucket
    seg_start = jnp.searchsorted(tid_s, jnp.arange(tr * tr + 1))
    pos = jnp.arange(2 * m) - seg_start[tid_s]
    overflow = jnp.any((pos >= cap) & (tid_s < tr * tr))

    dst_t = jnp.where(tid_s < tr * tr, tid_s, tr * tr)
    dst_p = jnp.clip(pos, 0, cap - 1)
    entries = jnp.stack([us[perm] % tile, vs[perm] % tile, vals[perm],
                         jnp.ones_like(dst_p)], axis=1)
    blocks = jnp.zeros((tr * tr + 1, cap, 4), jnp.int32)
    keep = (tid_s < tr * tr) & (pos < cap)
    blocks = blocks.at[jnp.where(keep, dst_t, tr * tr),
                       dst_p].set(jnp.where(keep[:, None], entries, 0))
    return blocks[:tr * tr].reshape(tr, tr, cap, 4), overflow


def delta_apply(anchor: DenseGraph, delta: Delta, t_anchor: int,
                t_query: int, tile: int = 256, cap: int = 1024,
                interpret: bool = True) -> DenseGraph:
    """Kernel-backed reconstruct_at for DenseGraph (edge part on the
    Pallas kernel, node mask via XLA scatter)."""
    n = anchor.n_cap
    pad = (-n) % tile
    forward = bool(t_query >= t_anchor)
    t_lo, t_hi = min(t_anchor, t_query), max(t_anchor, t_query)

    adj = anchor.adj.astype(jnp.int32)
    if pad:
        adj = jnp.pad(adj, ((0, pad), (0, pad)))
    blocks, overflow = bucket_ops(delta, n + pad, t_lo, t_hi, tile, cap,
                                  forward)
    out = delta_apply_tiles(adj, blocks, tile=tile, cap=cap,
                            interpret=interpret)
    adj_new = out[:n, :n].astype(bool)

    # node mask: same LWW on the XLA path (N-sized, negligible)
    m = delta.capacity
    idx = jnp.arange(m, dtype=jnp.int32)
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    nwin = in_win & delta.is_node_op()
    first = jnp.full((n,), m, jnp.int32).at[delta.u].min(
        jnp.where(nwin, idx, m))
    last = jnp.full((n,), -1, jnp.int32).at[delta.u].max(
        jnp.where(nwin, idx, -1))
    if forward:
        dec = last >= 0
        val = delta.op[jnp.clip(last, 0)] == ADD_NODE
    else:
        dec = first < m
        val = delta.op[jnp.clip(first, None, m - 1)] != ADD_NODE
    nodes = jnp.where(dec, val, anchor.nodes)
    return DenseGraph(nodes=nodes, adj=adj_new), overflow
