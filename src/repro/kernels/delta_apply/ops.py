"""jit'd wrapper for the delta_apply kernel: window filtering, tile
bucketing, ordering, and the node-mask update (nodes are N-sized and
cheap — they stay on the XLA path)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, ADD_NODE, REM_EDGE, Delta
from repro.core.graph import DenseGraph
from repro.kernels.delta_apply.delta_apply import delta_apply_tiles


@functools.partial(jax.jit, static_argnames=("n", "tile", "cap", "forward",
                                             "n_rows", "row0",
                                             "n_valid_rows"))
def bucket_ops(delta: Delta, n: int, t_lo, t_hi, tile: int, cap: int,
               forward: bool, n_rows: int | None = None, row0: int = 0,
               n_valid_rows: int | None = None):
    """Build the dense per-tile op blocks i32[Tr, Tc, cap, 4].

    Every in-window edge op contributes two entries ((u,v) and (v,u)).
    Entries are ordered so sequential overwrite == last-writer-wins:
    ascending time for forward, descending for backward.  Per-tile
    overflow beyond ``cap`` is detected and returned as a flag.

    ``n_rows``/``row0`` make the bucketing *shard-safe*: a device that
    owns only adjacency rows [row0, row0 + n_rows) buckets exactly the
    entries landing in its row block (columns stay global), with its
    own tile padding — so per-shard blocks concatenate to the full
    grid and the kernel runs unchanged on one row shard.
    ``n_valid_rows`` (default ``n_rows``) caps the *kept* rows below
    the tile-padded count, so ops owned by the next shard never leak
    into this shard's pad band (they would waste cap slots and trip a
    spurious overflow).
    """
    m = delta.capacity
    n_rows = n if n_rows is None else n_rows
    n_valid_rows = n_rows if n_valid_rows is None else n_valid_rows
    tr = n_rows // tile
    tc = n // tile
    nt = tr * tc
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    e = in_win & delta.is_edge_op()
    val = (delta.op == (ADD_EDGE if forward else REM_EDGE)).astype(jnp.int32)

    us = jnp.concatenate([delta.u, delta.v])
    vs = jnp.concatenate([delta.v, delta.u])
    ee = jnp.concatenate([e, e])
    vals = jnp.concatenate([val, val])
    order_rank = jnp.concatenate([jnp.arange(m), jnp.arange(m)])
    if not forward:
        order_rank = (m - 1) - order_rank  # descending time

    lr = us - row0                       # row local to this shard
    ee = ee & (lr >= 0) & (lr < n_valid_rows)
    lr = jnp.clip(lr, 0, max(n_rows - 1, 0))
    tile_id = jnp.where(ee, (lr // tile) * tc + (vs // tile), nt)
    # sort by (tile, rank): stable two-pass — first by rank, then by tile
    o1 = jnp.argsort(order_rank, stable=True)
    t1 = tile_id[o1]
    o2 = jnp.argsort(t1, stable=True)
    perm = o1[o2]
    tid_s = tile_id[perm]
    # position of each entry within its tile bucket
    seg_start = jnp.searchsorted(tid_s, jnp.arange(nt + 1))
    pos = jnp.arange(2 * m) - seg_start[tid_s]
    overflow = jnp.any((pos >= cap) & (tid_s < nt))

    dst_t = jnp.where(tid_s < nt, tid_s, nt)
    dst_p = jnp.clip(pos, 0, cap - 1)
    entries = jnp.stack([lr[perm] % tile, vs[perm] % tile, vals[perm],
                         jnp.ones_like(dst_p)], axis=1)
    blocks = jnp.zeros((nt + 1, cap, 4), jnp.int32)
    keep = (tid_s < nt) & (pos < cap)
    blocks = blocks.at[jnp.where(keep, dst_t, nt),
                       dst_p].set(jnp.where(keep[:, None], entries, 0))
    return blocks[:nt].reshape(tr, tc, cap, 4), overflow


def _node_mask_lww(nodes, delta: Delta, t_lo, t_hi, forward: bool,
                   row0: int = 0):
    """LWW node-mask update for rows [row0, row0 + len(nodes)) — the
    XLA path (N-sized, negligible next to the N² edge part)."""
    n_rows = nodes.shape[0]
    m = delta.capacity
    idx = jnp.arange(m, dtype=jnp.int32)
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    nwin = in_win & delta.is_node_op()
    lu = delta.u - row0
    nwin = nwin & (lu >= 0) & (lu < n_rows)
    lu = jnp.clip(lu, 0, n_rows - 1)
    first = jnp.full((n_rows,), m, jnp.int32).at[lu].min(
        jnp.where(nwin, idx, m))
    last = jnp.full((n_rows,), -1, jnp.int32).at[lu].max(
        jnp.where(nwin, idx, -1))
    if forward:
        dec = last >= 0
        val = delta.op[jnp.clip(last, 0)] == ADD_NODE
    else:
        dec = first < m
        val = delta.op[jnp.clip(first, None, m - 1)] != ADD_NODE
    return jnp.where(dec, val, nodes)


def delta_apply_row_block(nodes_block: jnp.ndarray, adj_block: jnp.ndarray,
                          delta: Delta, t_anchor: int, t_query: int,
                          row0: int, tile: int = 256, cap: int = 1024,
                          interpret: bool = True):
    """Kernel-backed LWW reconstruction of one adjacency *row block*
    (shard-safe: this is what each device of a row-sharded mesh runs).

    ``adj_block`` is bool[R, N] — rows [row0, row0 + R) of the global
    adjacency, columns global.  Row/column padding to the tile size is
    applied per block, so any shard width that divides into tiles (or
    pads up to one) works without touching other shards' rows.
    """
    n_rows, n_cols = adj_block.shape
    pad_r = (-n_rows) % tile
    pad_c = (-n_cols) % tile
    forward = bool(t_query >= t_anchor)
    t_lo, t_hi = min(t_anchor, t_query), max(t_anchor, t_query)

    adj = adj_block.astype(jnp.int32)
    if pad_r or pad_c:
        adj = jnp.pad(adj, ((0, pad_r), (0, pad_c)))
    blocks, overflow = bucket_ops(delta, n_cols + pad_c, t_lo, t_hi, tile,
                                  cap, forward, n_rows=n_rows + pad_r,
                                  row0=row0, n_valid_rows=n_rows)
    out = delta_apply_tiles(adj, blocks, tile=tile, cap=cap,
                            interpret=interpret)
    adj_new = out[:n_rows, :n_cols].astype(bool)
    nodes = _node_mask_lww(nodes_block, delta, t_lo, t_hi, forward, row0)
    return nodes, adj_new, overflow


def delta_apply(anchor: DenseGraph, delta: Delta, t_anchor: int,
                t_query: int, tile: int = 256, cap: int = 1024,
                interpret: bool = True) -> DenseGraph:
    """Kernel-backed reconstruct_at for DenseGraph (edge part on the
    Pallas kernel, node mask via XLA scatter)."""
    nodes, adj_new, overflow = delta_apply_row_block(
        anchor.nodes, anchor.adj, delta, t_anchor, t_query, 0,
        tile=tile, cap=cap, interpret=interpret)
    return DenseGraph(nodes=nodes, adj=adj_new), overflow
