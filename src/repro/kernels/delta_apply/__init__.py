from repro.kernels.delta_apply.delta_apply import delta_apply_tiles
from repro.kernels.delta_apply.ops import bucket_ops, delta_apply
from repro.kernels.delta_apply.ref import delta_apply_ref

__all__ = ["delta_apply", "delta_apply_ref", "delta_apply_tiles",
           "bucket_ops"]
