"""Independent oracle for degree_series: reconstruct a full snapshot at
every bucket time (vmap of the LWW oracle) and take degrees."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import Delta
from repro.core.graph import DenseGraph
from repro.kernels.delta_apply.ref import delta_apply_ref


@functools.partial(jax.jit, static_argnames=("num_buckets",))
def degree_series_ref(current: DenseGraph, delta: Delta, t_k, t_cur,
                      num_buckets: int) -> jax.Array:
    ts = t_k + jnp.arange(num_buckets, dtype=jnp.int32)

    def one(t):
        g = delta_apply_ref(current, delta, t_cur, t)
        return g.degrees()

    return jax.lax.map(one, ts)
