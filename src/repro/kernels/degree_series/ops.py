"""jit'd wrapper: bucket edge-op endpoint events by node tile, run the
degree_series kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, Delta
from repro.core.graph import DenseGraph
from repro.kernels.degree_series.degree_series import degree_series_tiles


@functools.partial(jax.jit,
                   static_argnames=("n", "tile", "cap", "num_buckets"))
def bucket_node_events(delta: Delta, n: int, t_k, num_buckets: int,
                       tile: int, cap: int):
    """Dense per-node-tile event blocks i32[T, cap, 4]:
    [local_node, bucket, sign, valid].  Each in-suffix edge op (t > t_k)
    yields one event per endpoint; bucket = clip(t - t_k, 0, B)."""
    m = delta.capacity
    tcount = n // tile
    e = delta.valid_mask() & delta.is_edge_op() & (delta.t > t_k)
    sign = jnp.where(delta.op == ADD_EDGE, 1, -1)
    b = jnp.clip(delta.t - t_k, 0, num_buckets)

    nodes = jnp.concatenate([delta.u, delta.v])
    ee = jnp.concatenate([e, e])
    signs = jnp.concatenate([sign, sign])
    bs = jnp.concatenate([b, b])

    tile_id = jnp.where(ee, nodes // tile, tcount)
    order = jnp.argsort(tile_id, stable=True)
    tid_s = tile_id[order]
    seg_start = jnp.searchsorted(tid_s, jnp.arange(tcount + 1))
    pos = jnp.arange(2 * m) - seg_start[tid_s]
    overflow = jnp.any((pos >= cap) & (tid_s < tcount))
    keep = (tid_s < tcount) & (pos < cap)
    entries = jnp.stack([nodes[order] % tile, bs[order], signs[order],
                         jnp.ones_like(pos)], axis=1)
    blocks = jnp.zeros((tcount + 1, cap, 4), jnp.int32)
    blocks = blocks.at[jnp.where(keep, tid_s, tcount),
                       jnp.clip(pos, 0, cap - 1)].set(
        jnp.where(keep[:, None], entries, 0))
    return blocks[:tcount], overflow


def degree_series_kernel(current: DenseGraph, delta: Delta, t_k: int,
                         num_buckets: int, tile: int = 256,
                         cap: int = 1024, interpret: bool = True):
    """i32[num_buckets, N]: degrees of every node at t_k + b."""
    n = current.n_cap
    pad = (-n) % tile
    deg = current.degrees()
    if pad:
        deg = jnp.pad(deg, (0, pad))
    blocks, overflow = bucket_node_events(delta, n + pad, t_k, num_buckets,
                                          tile, cap)
    out = degree_series_tiles(deg, blocks, tile=tile, cap=cap,
                              num_buckets=num_buckets, interpret=interpret)
    return out[:, :n], overflow
