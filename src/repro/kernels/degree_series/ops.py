"""jit'd wrapper: bucket edge-op endpoint events by node tile, run the
degree_series kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, Delta
from repro.core.graph import DenseGraph
from repro.kernels.degree_series.degree_series import degree_series_tiles


@functools.partial(jax.jit,
                   static_argnames=("n", "tile", "cap", "num_buckets",
                                    "row0", "n_valid"))
def bucket_node_events(delta: Delta, n: int, t_k, num_buckets: int,
                       tile: int, cap: int, row0: int = 0,
                       n_valid: int | None = None):
    """Dense per-node-tile event blocks i32[T, cap, 4]:
    [local_node, bucket, sign, valid].  Each in-suffix edge op (t > t_k)
    yields one event per endpoint; bucket = clip(t - t_k, 0, B).

    ``row0`` makes the bucketing shard-safe: with ``n`` the *local*
    (tile-padded) node count, only events touching nodes
    [row0, row0 + n_valid) are kept (``n_valid`` defaults to ``n``;
    pass the unpadded count so the next shard's events never leak into
    this shard's pad band) and node ids are shifted to shard-local, so
    each device of a node-sharded mesh builds its own tile blocks and
    the kernel runs unchanged on the shard."""
    m = delta.capacity
    n_valid = n if n_valid is None else n_valid
    tcount = n // tile
    e = delta.valid_mask() & delta.is_edge_op() & (delta.t > t_k)
    sign = jnp.where(delta.op == ADD_EDGE, 1, -1)
    b = jnp.clip(delta.t - t_k, 0, num_buckets)

    nodes = jnp.concatenate([delta.u, delta.v]) - row0
    ee = jnp.concatenate([e, e]) & (nodes >= 0) & (nodes < n_valid)
    nodes = jnp.clip(nodes, 0, max(n - 1, 0))
    signs = jnp.concatenate([sign, sign])
    bs = jnp.concatenate([b, b])

    tile_id = jnp.where(ee, nodes // tile, tcount)
    order = jnp.argsort(tile_id, stable=True)
    tid_s = tile_id[order]
    seg_start = jnp.searchsorted(tid_s, jnp.arange(tcount + 1))
    pos = jnp.arange(2 * m) - seg_start[tid_s]
    overflow = jnp.any((pos >= cap) & (tid_s < tcount))
    keep = (tid_s < tcount) & (pos < cap)
    entries = jnp.stack([nodes[order] % tile, bs[order], signs[order],
                         jnp.ones_like(pos)], axis=1)
    blocks = jnp.zeros((tcount + 1, cap, 4), jnp.int32)
    blocks = blocks.at[jnp.where(keep, tid_s, tcount),
                       jnp.clip(pos, 0, cap - 1)].set(
        jnp.where(keep[:, None], entries, 0))
    return blocks[:tcount], overflow


def degree_series_rows(deg_block: jnp.ndarray, delta: Delta, t_k: int,
                       num_buckets: int, row0: int = 0, tile: int = 256,
                       cap: int = 1024, interpret: bool = True):
    """Shard-safe variant: the series for one node block only.

    ``deg_block`` is i32[R] — current degrees of nodes
    [row0, row0 + R); per-block tile padding, so concatenating shard
    outputs along nodes equals the full-kernel output."""
    n = deg_block.shape[0]
    pad = (-n) % tile
    deg = jnp.pad(deg_block, (0, pad)) if pad else deg_block
    blocks, overflow = bucket_node_events(delta, n + pad, t_k, num_buckets,
                                          tile, cap, row0=row0, n_valid=n)
    out = degree_series_tiles(deg, blocks, tile=tile, cap=cap,
                              num_buckets=num_buckets, interpret=interpret)
    return out[:, :n], overflow


def degree_series_kernel(current: DenseGraph, delta: Delta, t_k: int,
                         num_buckets: int, tile: int = 256,
                         cap: int = 1024, interpret: bool = True):
    """i32[num_buckets, N]: degrees of every node at t_k + b."""
    return degree_series_rows(current.degrees(), delta, t_k, num_buckets,
                              row0=0, tile=tile, cap=cap,
                              interpret=interpret)
