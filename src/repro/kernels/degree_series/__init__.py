from repro.kernels.degree_series.degree_series import degree_series_tiles
from repro.kernels.degree_series.ops import (bucket_node_events,
                                             degree_series_kernel)
from repro.kernels.degree_series.ref import degree_series_ref

__all__ = ["degree_series_kernel", "degree_series_ref",
           "degree_series_tiles", "bucket_node_events"]
