"""Pallas TPU kernel: hybrid-plan degree time series.

Computes degree(v, τ) for every node v in a tile and every time unit τ
in [t_k, t_l] (B buckets) from the current degrees plus the window's
edge ops — the hot loop of the paper's hybrid plan (§3.2.3) evaluated
for *all* nodes at once (batched query serving).

Grid: 1-D over node tiles.  ops.py buckets edge-op endpoint events by
node tile: entry [local_node, bucket, sign, valid]; bucket B is a
virtual tail for ops in (t_l, t_cur].  Kernel: scatter-accumulate the
per-(bucket, node) net counts in VMEM, then a reverse running sum turns
them into the series:

  degree(v, t_k + b) = deg_cur(v) − Σ_{b' > b} net[b', v]

VMEM per instance: (B+2)·TN·4 bytes of scratch + cap·4·4 op block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ops_ref, deg_ref, out_ref, net_ref, *, cap: int,
            num_buckets: int):
    net_ref[...] = jnp.zeros_like(net_ref)

    def scatter(j, _):
        ln = ops_ref[0, j, 0]
        b = ops_ref[0, j, 1]
        sign = ops_ref[0, j, 2]
        valid = ops_ref[0, j, 3]
        cur = pl.load(net_ref, (pl.ds(b, 1), pl.ds(ln, 1)))
        pl.store(net_ref, (pl.ds(b, 1), pl.ds(ln, 1)),
                 cur + jnp.where(valid > 0, sign, 0).reshape(1, 1))
        return 0

    jax.lax.fori_loop(0, cap, scatter, 0)

    def rev(j, acc):
        b = num_buckets - 1 - j
        acc = acc + net_ref[b + 1, :]
        out_ref[b, :] = deg_ref[0, :] - acc
        return acc

    jax.lax.fori_loop(0, num_buckets, rev,
                      jnp.zeros_like(net_ref[0, :]), unroll=False)


@functools.partial(jax.jit,
                   static_argnames=("tile", "cap", "num_buckets",
                                    "interpret"))
def degree_series_tiles(deg_cur: jax.Array, tile_ops: jax.Array,
                        tile: int = 256, cap: int = 1024,
                        num_buckets: int = 64,
                        interpret: bool = True) -> jax.Array:
    """deg_cur: i32[N]; tile_ops: i32[T, cap, 4] → i32[num_buckets, N]."""
    n = deg_cur.shape[0]
    assert n % tile == 0
    grid = (n // tile,)
    return pl.pallas_call(
        functools.partial(_kernel, cap=cap, num_buckets=num_buckets),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((num_buckets, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_buckets, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((num_buckets + 2, tile), jnp.int32)],
        interpret=interpret,
    )(tile_ops, deg_cur.reshape(1, n))
