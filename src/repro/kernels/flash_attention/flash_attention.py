"""Pallas TPU kernel: flash attention forward (causal / sliding-window,
GQA via index-map head folding).

Online-softmax tiling: grid (batch·q_heads, Sq/BQ, Skv/BK) with the KV
dimension innermost; per-instance VMEM scratch carries the running max,
normalizer and accumulator across KV blocks.  Out-of-range blocks
(future blocks under causal masking, expired blocks under a sliding
window) are skipped entirely with ``pl.when`` — the compute volume is
the masked volume, not Sq·Skv.

Block sizes default to (BQ, BK) = (128, 128): MXU-aligned and a VMEM
footprint of ~(2·BQ·D + BK·D + BQ·BK)·4 bytes ≈ 260 KiB at D = 128.

GQA: K/V stay (B·Hkv, Skv, D); the BlockSpec index map folds the query
head onto its KV group (``h // group``), so nothing is materialized at
Hq width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, nk: int, causal: bool, window: int | None,
            scale: float, kv_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = iq * bq
    k_lo = ik * bk
    # Block-level skip: causal ⇒ KV block must start at/before the last
    # query row; window ⇒ KV block must end after the first in-window key.
    needed = jnp.bool_(True)
    if causal:
        needed = needed & (k_lo <= q_lo + bq - 1)
    if window is not None:
        needed = needed & (k_lo + bk - 1 >= q_lo - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len  # padding
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))
        # rows with no unmasked key yet have m == -inf; keep them inert
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isneginf(m_prev[:, 0]), 0.0,
                          jnp.exp(m_prev[:, 0] - m_safe))
        l_new = alpha * l_prev[:, 0] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "group",
                     "kv_len", "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        scale: float = 1.0, bq: int = 128, bk: int = 128,
                        group: int = 1, kv_len: int | None = None,
                        interpret: bool = True) -> jax.Array:
    """q: [BHq, Sq, D]; k/v: [BHkv, Skv, D]; BHq = BHkv · group.

    Sq/Skv must be multiples of bq/bk (ops.py pads); ``kv_len`` is the
    unpadded key length for padding masks.
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nk = skv // bk
    kv_len = kv_len if kv_len is not None else skv
    grid = (bh, sq // bq, nk)
    kernel = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                               window=window, scale=scale, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
