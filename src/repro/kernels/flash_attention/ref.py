"""Pure-jnp oracle: masked softmax attention with GQA/causal/window."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float = 1.0, kv_len: int | None = None):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D] → [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if kv_len is not None:
        mask = mask & (kpos < kv_len)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom > 0, denom, 1.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
