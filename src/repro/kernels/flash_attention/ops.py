"""jit'd wrapper around the flash-attention kernel.

Handles padding to block multiples, GQA head folding, and provides a
``custom_vjp`` whose backward pass is the jnp reference (the kernel is a
forward/inference kernel; training uses either this custom_vjp or the
pure-XLA attention in ``repro/models/attention.py``)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D] → [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    qq = _pad_to(q.reshape(b * hq, sq, d), 1, bq)
    kk = _pad_to(k.reshape(b * hkv, skv, d), 1, bk)
    vv = _pad_to(v.reshape(b * hkv, skv, d), 1, bk)
    out = flash_attention_fwd(qq, kk, vv, causal=causal, window=window,
                              scale=scale, bq=bq, bk=bk, group=group,
                              kv_len=skv, interpret=interpret)
    return out[:, :sq].reshape(b, hq, sq, d)


def _fwd(q, k, v, causal, window, scale, bq, bk, interpret):
    out = flash_attention(q, k, v, causal, window, scale, bq, bk, interpret)
    return out, (q, k, v)


def _bwd(causal, window, scale, bq, bk, interpret, res, g):
    q, k, v = res
    d = q.shape[-1]
    s = scale if scale is not None else d ** -0.5
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window, scale=s), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
