from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd

__all__ = ["ssd_scan", "ssd_ref", "ssd_scan_fwd"]
