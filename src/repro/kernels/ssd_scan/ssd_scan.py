"""Pallas TPU kernel: chunked SSD (Mamba2 state-space dual) forward.

Grid (BH, S/Q) with the sequence-chunk dimension innermost: each
instance advances one (batch·head)'s recurrence by one chunk, carrying
the (P, N) state in VMEM scratch — the inter-chunk recurrence never
touches HBM.  Per chunk, everything is MXU work:

  cum_i   = Σ_{j≤i} a·dt_j                       (within chunk)
  score   = (C B^T) ⊙ exp(cum_i − cum_j) ⊙ [j ≤ i]      (Q × Q)
  y       = score · (dt·x)  +  exp(cum) ⊙ (C · state^T)  (Q × P)
  state'  = exp(cum_Q) · state + (exp(cum_Q − cum) ⊙ dt·x)^T · B

Inputs are pre-fused by ops.py: dtx = dt·x and da = a·dt, with B/C
broadcast per head.  VMEM per instance ≈ (Q·N + Q·P + Q² + P·N)·4 B —
~200 KiB at Q = N = 128, P = 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dtx_ref, da_ref, b_ref, c_ref, o_ref, state_ref, *,
            q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    dtx = dtx_ref[0].astype(jnp.float32)          # (Q, P)
    da = da_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    bb = b_ref[0].astype(jnp.float32)             # (Q, N)
    cb = c_ref[0].astype(jnp.float32)             # (Q, N)

    cum = jnp.cumsum(da)                          # (Q,)
    seg = cum[:, None] - cum[None, :]             # (Q, Q), i minus j
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(jnp.where(tri, seg, 0.0)) * tri

    score = (cb @ bb.T) * decay                   # (Q, Q)
    y = score @ dtx                               # (Q, P)
    state = state_ref[...]                        # (P, N)
    y = y + jnp.exp(cum)[:, None] * (cb @ state.T)

    decay_to_end = jnp.exp(cum[-1] - cum)         # (Q,)
    state_ref[...] = (jnp.exp(cum[-1]) * state
                      + (decay_to_end[:, None] * dtx).T @ bb)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan_fwd(dtx: jax.Array, da: jax.Array, b: jax.Array,
                 c: jax.Array, chunk: int = 128,
                 interpret: bool = True) -> jax.Array:
    """dtx: [BH, S, P]; da: [BH, S, 1]; b/c: [BH, S, N] → y [BH, S, P].

    S must be a multiple of ``chunk`` (ops.py pads with da = 0)."""
    bh, s, p = dtx.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, q=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), dtx.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(dtx, da, b, c)
