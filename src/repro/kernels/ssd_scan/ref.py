"""Pure-jnp oracle: the per-step SSD recurrence (independent of the
chunked dual form in models.ssm)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, b, c):
    """x: [B,S,H,P], dt: [B,S,H], a: [H], b/c: [B,S,N] → y [B,S,H,P]."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hst, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a[None, :])[..., None, None]
        upd = (dtt[..., None, None] * xt.astype(jnp.float32)[..., None]
               * bt.astype(jnp.float32)[:, None, None, :])
        hst = hst * decay + upd
        y = jnp.einsum("bhpn,bn->bhp", hst, ct.astype(jnp.float32))
        return hst, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3)
