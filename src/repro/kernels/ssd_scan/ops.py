"""jit'd wrapper: model-layout SSD → kernel layout (fused dt·x and
a·dt, per-head broadcast of B/C, chunk padding with inert steps)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, chunk: int = 128, interpret: bool = True):
    """Same signature as models.ssm.ssd_chunked (single B/C group):
    x: [B,S,H,P], dt: [B,S,H], a: [H], b/c: [B,S,N] → y [B,S,H,P]."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    pad = (-s) % chunk
    dtx = (dt[..., None] * x.astype(jnp.float32))
    da = dt * a[None, None, :]
    if pad:
        dtx = jnp.pad(dtx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    # [B,S,H,*] -> [B*H, S, *]
    dtx = dtx.transpose(0, 2, 1, 3).reshape(bsz * h, sp, p)
    da = da.transpose(0, 2, 1).reshape(bsz * h, sp, 1)
    bb = jnp.broadcast_to(b[:, None], (bsz, h, sp, n)).reshape(
        bsz * h, sp, n)
    cc = jnp.broadcast_to(c[:, None], (bsz, h, sp, n)).reshape(
        bsz * h, sp, n)
    y = ssd_scan_fwd(dtx.astype(jnp.float32), da.astype(jnp.float32),
                     bb.astype(jnp.float32), cc.astype(jnp.float32),
                     chunk=chunk, interpret=interpret)
    y = y.reshape(bsz, h, sp, p).transpose(0, 2, 1, 3)
    return y[:, :s]
