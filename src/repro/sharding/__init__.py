"""Logical-axis sharding rules → PartitionSpecs.

Models annotate activations with *logical* axis names; params get specs
from path-based rules.  Logical names resolve to mesh axes through
``LOGICAL_RULES`` and are silently dropped when the current mesh lacks
the axis or the dimension is not divisible — this is what makes one
model definition run unchanged on the single-pod (data, model) mesh,
the multi-pod (pod, data, model) mesh, a tiny 8-device test mesh, and a
single CPU device.
"""
from __future__ import annotations

import contextlib
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# logical axis -> preferred mesh axes (first match that exists wins; for
# composite entries every present axis is used).
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),       # data parallel over pod × data
    "fsdp": ("data",),              # ZeRO-3 parameter sharding
    "fsdp_pod": ("pod", "data"),
    "model": ("model",),            # TP: heads / ff / vocab
    "expert": ("model",),           # EP: expert dim of MoE weights
    "moe_fsdp": ("data",),          # ZeRO-3 on MoE weights specifically
    "moe_ff": (),                   # TP within expert (small-E MoE)
    "moe_cap": (),                  # capacity dim of dispatch buffers
    "kv_seq": ("data",),            # long-context decode: shard KV seq
    "none": (),
}


def mesh_context(mesh):
    """Context manager putting ``mesh`` in scope for PartitionSpec
    resolution (jax.set_mesh in jax ≥ 0.7, use_mesh in 0.5–0.6, the
    plain ``Mesh`` context manager before that)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)  # pragma: no cover
    return mesh  # jax ≤ 0.4: ``with mesh:`` sets thread_resources


@contextlib.contextmanager
def logical_rules(**over):
    """Temporarily override LOGICAL_RULES (perf experiments)."""
    old = {k: LOGICAL_RULES[k] for k in over}
    LOGICAL_RULES.update({k: tuple(v) for k, v in over.items()})
    try:
        yield
    finally:
        LOGICAL_RULES.update(old)


def current_mesh():
    """The mesh in scope: the abstract mesh on jax ≥ 0.5, the physical
    thread-resources mesh (set by ``with mesh:``) before."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources
    mesh = thread_resources.env.physical_mesh
    return None if mesh is None or mesh.empty else mesh


def _mesh_axis_sizes() -> dict[str, int]:
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return {}
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    # jax ≤ 0.4: the mesh context manager sets thread_resources instead
    from jax._src.mesh import thread_resources
    mesh = thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return {}
    return dict(mesh.shape)


def resolve(logical: str | None, dim: int | None = None,
            used: set | None = None):
    """Logical name -> mesh axes tuple (or None), respecting presence,
    divisibility of ``dim``, and axes already used by other dims."""
    if logical is None or logical == "none":
        return None
    sizes = _mesh_axis_sizes()
    axes = [a for a in LOGICAL_RULES.get(logical, ()) if a in sizes
            and (used is None or a not in used)]
    if not axes:
        return None
    if dim is not None:
        total = 1
        kept = []
        for a in axes:
            if dim % (total * sizes[a]) == 0:
                kept.append(a)
                total *= sizes[a]
        axes = kept
    if not axes:
        return None
    if used is not None:
        used.update(axes)
    return tuple(axes) if len(axes) > 1 else axes[0]


def spec(*logical: str | None, dims: Sequence[int] | None = None) -> P:
    parts = []
    used: set = set()
    for i, name in enumerate(logical):
        d = None if dims is None else dims[i]
        parts.append(resolve(name, d, used))
    return P(*parts)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate an activation with logical axes (no-op off-mesh)."""
    if not _mesh_axis_sizes():
        return x
    s = spec(*logical, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, s)


# ---------------------------------------------------------------------------
# Parameter specs by path rules
# ---------------------------------------------------------------------------

# (path-substring, logical names per dim). First match wins; matched
# against "/".join(path). Entries cover every param family in
# repro/models. Stacked (scan-over-layers) params get a leading None.
PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    ("embed/tok", ("model", "fsdp")),          # vocab × d
    ("embed/pos", (None, "fsdp")),
    ("embed/unembed", ("fsdp", "model")),
    ("attn/wq", ("fsdp", "model", None)),      # d × Hq × hd
    ("attn/wk", ("fsdp", "model", None)),
    ("attn/wv", ("fsdp", "model", None)),
    ("attn/wo", ("model", None, "fsdp")),      # Hq × hd × d
    ("moe/wg", ("fsdp", None)),                        # d × E router
    ("moe/w_gate", ("expert", "moe_fsdp", "moe_ff")),  # E × d × ff
    ("moe/w_up", ("expert", "moe_fsdp", "moe_ff")),
    ("moe/w_down", ("expert", "moe_ff", "moe_fsdp")),  # E × ff × d
    ("mlp/w_gate", ("fsdp", "model")),
    ("mlp/w_up", ("fsdp", "model")),
    ("mlp/w_down", ("model", "fsdp")),
    ("ssm/in_proj", ("fsdp", "model")),        # d × d_in_all
    ("ssm/out_proj", ("model", "fsdp")),       # d_inner × d
    ("ssm/conv", (None, "model")),             # width × channels
    ("ssm/", (None,)),                         # A_log, D, dt_bias, norm
    ("norm", (None,)),
]


def param_spec_for(path: str, shape: tuple[int, ...]) -> P:
    for sub, names in PARAM_RULES:
        if sub in path:
            # align rule names to trailing dims (leading scan dims None)
            k = len(names)
            if len(shape) >= k:
                lead = (None,) * (len(shape) - k)
                dims = shape[len(shape) - k:]
                used: set = set()
                parts = [resolve(n, d, used)
                         for n, d in zip(names, dims)]
                return P(*lead, *parts)
            return P(*([None] * len(shape)))
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    out = []
    for p in path:
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                out.append(str(getattr(p, attr)))
                break
        else:
            out.append(str(p))
    return "/".join(out)


def param_specs(params) -> dict:
    """PartitionSpec pytree matching a param pytree (call inside a mesh
    context — jax.sharding.use_mesh — so divisibility is checked against
    the actual mesh)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec_for(_path_str(path), leaf.shape),
        params)


def named_shardings(params, mesh) -> dict:
    from jax.sharding import NamedSharding
    with mesh_context(mesh):
        specs = param_specs(params)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
