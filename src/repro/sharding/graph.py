"""Mesh/axis helpers for the distributed graph engine.

The graph engine uses ONE 1-D mesh whose single axis plays a different
role per (plan, anchor) group (core/distributed.py):

* hybrid / delta-only groups — the axis shards the *padded query batch*
  (graph + delta replicated, queries split),
* two-phase groups — the axis shards the *adjacency rows* (queries
  replicated, the LWW scatter row-parallel, measures psum'd).

The axis is named ``rows`` for historical reasons (the row-parallel
reconstruction primitives predate query sharding); it is the only axis
the graph engine ever uses, unlike the LM-side (pod, data, model)
meshes of ``repro.sharding``.

Everything here is host-side plumbing: mesh construction, batch
padding arithmetic, and snapshot/delta device placement.  Placement is
an optimization, not a requirement — ``jit``-of-``shard_map`` reshards
automatically; pre-placing just avoids a host→device transfer per
dispatch.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AXIS = "rows"


def graph_mesh(devices=None) -> Mesh:
    """The 1-D graph-engine mesh over all (or the given) devices."""
    devices = devices if devices is not None else jax.devices()
    return Mesh(np.asarray(devices), (AXIS,))


def mesh_size(mesh: Mesh | None) -> int:
    return 1 if mesh is None else int(np.prod(list(mesh.shape.values())))


def single_device(mesh: Mesh | None) -> bool:
    """True when there is nothing to shard over — the host-process
    fallback: run the ordinary single-device path."""
    return mesh_size(mesh) <= 1


def batch_pad(b: int, n_dev: int) -> int:
    """Padded batch size: per-device slice rounded to a power of two
    (bounds recompiles exactly like the single-device executor), times
    the device count (so the batch axis divides evenly)."""
    per = max(1, int(np.ceil(b / max(n_dev, 1))))
    per = 1 << int(np.ceil(np.log2(per)))
    return per * n_dev


def rows_divisible(n_cap: int, mesh: Mesh | None) -> bool:
    """Row-sharding needs the node capacity to split evenly."""
    return mesh is not None and n_cap % mesh_size(mesh) == 0


def slots_divisible(e_cap: int, mesh: Mesh | None) -> bool:
    """Slot-sharding needs the edge-slot capacity to split evenly
    (e_cap is a power of two from the store, so any pow2 device count
    divides it)."""
    return mesh is not None and e_cap % mesh_size(mesh) == 0


def replicate(tree, mesh: Mesh):
    """Place a pytree fully replicated on the mesh."""
    return jax.device_put(tree, NamedSharding(mesh, P()))


def shard_rows(tree, mesh: Mesh):
    """Place a pytree with the leading axis of every leaf sharded over
    the mesh (node mask i1[N], adjacency i1[N, N], degree i32[N]...)."""

    def put(x):
        spec = P(AXIS, *([None] * (np.ndim(x) - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree)


def shard_slots(g, mesh: Mesh):
    """Place an edge-layout snapshot with the *slot axis* sharded: the
    E-sized fields (eu, ev, emask) split over the mesh, everything
    N-sized or scalar (nodes, n_edges_reg) replicated.  The 1-D
    analogue of ``shard_rows`` for ``core.distributed.two_phase_slots``.
    """
    import dataclasses

    def split(x):
        return jax.device_put(x, NamedSharding(mesh, P(AXIS)))

    rep = replicate((g.nodes, g.n_edges_reg), mesh)
    return dataclasses.replace(g, nodes=rep[0], n_edges_reg=rep[1],
                               eu=split(g.eu), ev=split(g.ev),
                               emask=split(g.emask))


def batch_specs(qmask) -> tuple:
    """in_specs for a batched kernel call: P(AXIS) for query-batch
    arguments, P() (replicated) for everything else."""
    return tuple(P(AXIS) if q else P() for q in qmask)
