"""Configuration system: model / sharding / train / run configs.

One ``ModelConfig`` instance per assigned architecture lives in
``repro/configs/<id>.py``.  Families:

  dense   — decoder-only transformer (llama/gemma/glm/olmo style)
  moe     — decoder-only with mixture-of-experts FFN
  ssm     — attention-free Mamba2 (SSD)
  hybrid  — Jamba-style interleave (1 attn : 7 mamba, MoE every 2nd)
  encdec  — Whisper-style encoder-decoder (stub audio frontend)
  vlm     — decoder with prepended patch embeddings (stub ViT frontend)
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None           # default d_model // n_heads
    mlp_kind: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm_kind: Literal["rms", "ln", "ln_nonparam"] = "rms"
    rope_theta: float = 10000.0
    pos_kind: Literal["rope", "sinusoidal", "learned", "none"] = "rope"
    window: Optional[int] = None           # sliding-window attention
    logit_softcap: Optional[float] = None
    tie_embeddings: bool = True
    max_seq: int = 8192                    # learned-pos table size
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1                     # MoE FFN on layers l % every == 0
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (Jamba) ---
    attn_period: int = 0                   # 8 → 1 attn : 7 mamba
    attn_offset: int = 0                   # index of attn layer in period
    # --- encdec (Whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0                       # encoder frames (post conv stub)
    # --- vlm ---
    n_patches: int = 0

    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd()

    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (sub-quadratic sequence mixing)."""
        return (self.family in ("ssm", "hybrid")
                or self.window is not None)

    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def ssm_nheads(self) -> int:
        return self.d_inner() // self.ssm_headdim


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Mesh-axis assignment. Axes: pod / data / model (launch/mesh.py)."""
    fsdp: bool = True          # shard params/opt-state over the data axis
    fsdp_pod: bool = False     # additionally over the pod axis (ZeRO-3 at
                               # cluster scope — needed for ≥398B configs)
    seq_shard_decode: bool = True  # shard long KV caches over data axis
    remat: Literal["none", "block", "full"] = "block"
    attn_impl: Literal["xla", "xla_flash", "pallas"] = "xla"

    def fsdp_axes(self):
        if not self.fsdp:
            return None
        return ("pod", "data") if self.fsdp_pod else "data"


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    param_dtype: Literal["float32", "bfloat16"] = "bfloat16"
    opt_state_dtype: Literal["float32", "bfloat16", "int8"] = "float32"
    grad_compression: Literal["none", "int8"] = "none"
    microbatches: int = 1
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (arch × shape = a dry-run cell)."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family (CPU-runnable)."""
    base = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.family != "hybrid" else 0),
        d_model=128, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        head_dim=32, d_ff=256, vocab=512, max_seq=512,
    )
    if cfg.family == "hybrid":
        base["n_layers"] = cfg.attn_period  # one full period
    if cfg.n_experts:
        base["n_experts"] = min(cfg.n_experts, 4)
        base["top_k"] = min(cfg.top_k, 2)
        # generous capacity: no token dropping, so reduced-config smoke
        # tests can assert causal prefill/decode consistency
        base["capacity_factor"] = 8.0
    if cfg.ssm_state:
        base["ssm_state"] = 16
        base["ssm_headdim"] = 32
        base["ssm_chunk"] = 16
    if cfg.family == "encdec":
        base["n_enc_layers"] = 2
        base["enc_seq"] = 64
    if cfg.family == "vlm":
        base["n_patches"] = 16
    if cfg.window is not None:
        base["window"] = 64
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
