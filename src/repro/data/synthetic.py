"""Deterministic, stateless synthetic data pipeline.

Every batch is a pure function of (seed, step) — the pipeline has no
cursor state, which makes resume-after-failure trivial (restore the
step counter and the stream continues exactly), sharding-friendly
(each data shard draws its slice of the batch from a per-shard fold-in)
and reproducible across mesh shapes (elastic restarts see the same
token stream).

The token stream is a mixture of Zipf-distributed unigrams and short
copy patterns, so small models have learnable structure (loss visibly
drops within a few hundred steps in examples/train_lm_delta_ckpt.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int | jax.Array) -> dict:
        """Batch for a given step (host or traced)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                 jnp.asarray(step, jnp.uint32))
        k1, k2, k3 = jax.random.split(key, 3)
        # Zipf-ish unigrams via exponential transform of uniforms
        u = jax.random.uniform(k1, (self.batch, self.seq),
                               minval=1e-6, maxval=1.0)
        zipf = jnp.floor(jnp.exp(jnp.log(float(cfg.vocab)) * u)) - 1.0
        toks = jnp.clip(zipf.astype(jnp.int32), 0, cfg.vocab - 1)
        # splice in copy patterns: second half repeats the first quarter
        quarter = self.seq // 4
        if quarter > 0:
            src = jax.lax.dynamic_slice_in_dim(toks, 0, quarter, axis=1)
            insert_at = self.seq - quarter
            do_copy = jax.random.bernoulli(k2, 0.5,
                                           (self.batch, 1))
            tail = jax.lax.dynamic_slice_in_dim(toks, insert_at, quarter,
                                                axis=1)
            spliced = jnp.where(do_copy, src, tail)
            toks = jax.lax.dynamic_update_slice_in_dim(
                toks, spliced, insert_at, axis=1)
        batch = {"tokens": toks, "labels": toks}
        if cfg.family == "encdec":
            batch["frames"] = 0.02 * jax.random.normal(
                k3, (self.batch, cfg.enc_seq, cfg.d_model))
        if cfg.family == "vlm":
            batch["patches"] = 0.02 * jax.random.normal(
                k3, (self.batch, cfg.n_patches, cfg.d_model))
        return batch


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    s = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
         "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        s["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_seq,
                                            cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        s["patches"] = jax.ShapeDtypeStruct((batch, cfg.n_patches,
                                             cfg.d_model), jnp.float32)
    return s
