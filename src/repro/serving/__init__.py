# Live serving: double-buffered ingest (epoch swap + watermark),
# workload-driven materialization, and the micro-batching frontend.
# The batch engine (repro.core) stays the execution substrate; this
# package owns everything that makes it continuously-serving.
from repro.serving.frontend import (FrontendStats, MicroBatchFrontend,
                                    OverloadError, query_cache_key)
from repro.serving.ingest import LiveGraphStore, SwapRecord, WatermarkError
from repro.serving.policy import (PeriodicMaterializationPolicy,
                                  RebalanceResult, WorkloadStats,
                                  WorkloadMaterializationPolicy)

__all__ = [
    "FrontendStats", "LiveGraphStore", "MicroBatchFrontend",
    "OverloadError",
    "PeriodicMaterializationPolicy", "RebalanceResult", "SwapRecord",
    "WatermarkError", "WorkloadMaterializationPolicy", "WorkloadStats",
    "query_cache_key",
]
