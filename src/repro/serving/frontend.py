"""Micro-batching query frontend: coalesce, dedupe, cache, dispatch once.

The batched executor's whole advantage is amortization — one device
program per (plan, anchor, layout) group — but a live system receives
queries one at a time.  ``MicroBatchFrontend`` closes that gap:

* ``submit(q)`` returns a future immediately.  Requests queue until
  either ``max_batch`` of them are waiting or the oldest has aged past
  ``max_delay_ms``; the scheduler then drains the queue and dispatches
  ONE ``LiveGraphStore.evaluate_many`` (which reuses the engine's
  planner groups, pow2 padding, and ``mesh``/``layout`` pass-through
  unchanged).

* **Exact result cache** keyed ``(measure, args, t, layout)`` — the
  full query tuple plus the forced layout — and stamped with the live
  store's ``generation``, which every epoch swap bumps: watermark
  advance invalidates the whole cache in O(1).  Within an epoch the
  cache is exact by the serving contract (history at ``t ≤ t_served``
  is immutable and results are layout/shard bit-stable), so hits skip
  the device entirely.  Duplicate queries *within* one batch collapse
  to a single evaluation the same way.

The frontend runs in two modes: synchronous (call ``flush()`` — or
let a full queue auto-drain — and collect futures; what the tests and
benchmarks use) and threaded (``start()`` spawns a scheduler thread
that drains on the deadline; ``stop()`` joins it).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from concurrent.futures import Future
from typing import Sequence

import numpy as np

from repro.core.plans import Query
from repro.obs import clock
from repro.obs.metrics import (MetricsRegistry, NullRegistry,
                               default_registry)
from repro.obs.trace import trace_span
from repro.serving.ingest import LiveGraphStore, WatermarkError

__all__ = ["MicroBatchFrontend", "FrontendStats", "OverloadError",
           "query_cache_key"]


class OverloadError(RuntimeError):
    """The serving path is saturated: the request was rejected at
    admission (``max_pending`` bound) or shed at dispatch (aged past
    ``shed_after_ms``).  Callers should back off and retry — shedding
    early and explicitly beats queueing into timeout territory."""


def query_cache_key(q: Query, layout: str | None) -> tuple:
    """The exact-result-cache key: every semantic field of the query
    plus the requested execution layout.  Layout never changes a
    result bit (the engine's parity contract), but keying on it keeps
    cache entries interpretable per serving configuration."""
    return (q.kind, q.scope, q.measure, q.agg if q.kind == "agg" else "",
            int(q.t_k), None if q.t_l is None else int(q.t_l),
            None if q.v is None else int(q.v),
            int(getattr(q, "stride", 1)) if q.kind == "evolve" else 1,
            layout or "auto")


class FrontendStats:
    """Read-only view over a frontend's leaf metrics registry.

    Source-compatible with the old plain-int dataclass: reads like
    ``fe.stats.cache_hits`` resolve the live registry children.  All
    mutation happens at the instrumented call sites through atomic
    child operations — the view itself never writes, so there is no
    read-modify-write window to lose increments in.  Each frontend
    owns a fresh leaf registry, so these per-instance counts start at
    zero per frontend lifetime while the same increments aggregate
    into the parent (session/process) registry.

    ``sync`` (when given) runs before every read: the frontend's
    submit path accumulates its per-request counts as plain ints under
    the queue lock it already holds (registry child ops per submit
    would be measurable overhead on the serving hot path — the
    bench_obs_overhead contract) and folds them into the registry at
    every drain; the sync hook folds them on read too, so the view
    stays exact at all times.
    """

    _COUNTERS = {
        "submitted": ("frontend_submitted_total",
                      "queries submitted"),
        "served": ("frontend_served_total",
                   "requests resolved by a dispatch (shed included)"),
        "batches": ("frontend_batches_total",
                    "dispatches to the engine"),
        "cache_hits": ("frontend_cache_hits_total",
                       "exact-result cache hits"),
        "cache_misses": ("frontend_cache_misses_total",
                         "exact-result cache misses"),
        "coalesced_dupes": ("frontend_coalesced_dupes_total",
                            "duplicate queries collapsed in a batch"),
        "rejected": ("frontend_rejected_total",
                     "submissions bounced at the max_pending bound"),
        "shed": ("frontend_shed_total",
                 "requests dropped at dispatch: aged past "
                 "shed_after_ms"),
    }
    _GAUGES = {
        "max_batch_seen": ("frontend_max_batch_seen",
                           "largest batch dispatched"),
        "max_pending_seen": ("frontend_max_pending_seen",
                             "deepest queue observed"),
    }

    def __init__(self, registry, sync=None):
        children = {}
        for attr, (name, help_) in self._COUNTERS.items():
            children[attr] = registry.counter(name, help_)
        for attr, (name, help_) in self._GAUGES.items():
            children[attr] = registry.gauge(name, help_)
        self._children = children
        self._sync = sync

    def __getattr__(self, name):
        children = self.__dict__.get("_children")
        if children is not None and name in children:
            sync = self.__dict__.get("_sync")
            if sync is not None:
                sync()
            return children[name].value
        raise AttributeError(name)

    def batch_occupancy(self) -> float:
        batches = self.batches
        return self.served / batches if batches else 0.0


class MicroBatchFrontend:
    """Request queue + coalescing scheduler over a ``LiveGraphStore``."""

    def __init__(self, live: LiveGraphStore, *, max_batch: int = 64,
                 max_delay_ms: float = 2.0, cache_entries: int = 4096,
                 stale: str = "raise", layout: str | None = None,
                 max_pending: int | None = None, overload: str = "raise",
                 shed_after_ms: float | None = None, metrics=None,
                 **evaluate_kw):
        self.live = live
        self.max_batch = int(max_batch)
        self.max_delay_ms = float(max_delay_ms)
        self.cache_entries = int(cache_entries)
        self.stale = stale
        self.layout = layout
        # Backpressure.  ``max_pending`` bounds the queue: a submit
        # past it either raises ``OverloadError`` (overload="raise" —
        # the caller hears "slow down" immediately) or blocks until
        # the scheduler frees space (overload="block" — producers are
        # paced instead of refused; needs a running drain thread or a
        # concurrent flusher).  ``shed_after_ms`` is the dispatch-side
        # valve: a request that aged past it is shed with
        # ``OverloadError`` rather than evaluated — under sustained
        # overload, serving a request whose client already gave up
        # only steals device time from the ones still waiting.
        if overload not in ("raise", "block"):
            raise ValueError(f"unknown overload policy {overload!r}")
        self.max_pending = None if max_pending is None else int(max_pending)
        self.overload = overload
        self.shed_after_ms = (None if shed_after_ms is None
                              else float(shed_after_ms))
        self.evaluate_kw = evaluate_kw
        # per-instance leaf registry chained onto the session/process
        # parent: ``fe.stats`` counts THIS frontend, the parent sees
        # the aggregate.  A NullRegistry parent is adopted whole so
        # "metrics off" really is off end to end.
        parent = default_registry() if metrics is None else metrics
        self.metrics = (parent if isinstance(parent, NullRegistry)
                        else MetricsRegistry(parent=parent))
        self.stats = FrontendStats(self.metrics, sync=self._sync_stats)
        self._m = self.stats._children
        self._m_qdepth = self.metrics.gauge(
            "frontend_queue_depth", "requests waiting for dispatch")
        self._m_wait = self.metrics.histogram(
            "frontend_queue_wait_seconds",
            "submit-to-dispatch wait per request")
        self._cache: OrderedDict[tuple, tuple[int, object]] = OrderedDict()
        self._queue: list[tuple[Query, tuple, Future, float]] = []
        self._cv = threading.Condition()   # RLock-backed: sync nests
        # submit-path counts accumulate here as plain ints under
        # ``_cv`` and fold into the registry at every drain / stats
        # read — registry child ops per submit would tax the hot path
        self._pend_counts = {"submitted": 0, "cache_hits": 0,
                             "cache_misses": 0, "rejected": 0}
        self._pend_maxdepth = 0
        self._thread: threading.Thread | None = None
        self._running = False

    def _sync_stats(self) -> None:
        """Fold the submit path's pending plain-int counts into the
        registry (exactness on read; cheapness on write)."""
        with self._cv:
            for attr, n in self._pend_counts.items():
                if n:
                    self._m[attr].inc(n)
                    self._pend_counts[attr] = 0
            if self._pend_maxdepth:
                self._m["max_pending_seen"].set_max(self._pend_maxdepth)
                self._pend_maxdepth = 0
            self._m_qdepth.set(len(self._queue))

    # ----------------------------------------------------------- cache

    def _cache_get(self, key: tuple):
        """Hit iff present AND stamped with the current generation —
        every epoch swap bumps ``live.generation``, so watermark
        advance invalidates without walking the table."""
        entry = self._cache.get(key)
        if entry is None:
            return None
        gen, value = entry
        if gen != self.live.generation:
            del self._cache[key]        # stale epoch: drop lazily
            return None
        self._cache.move_to_end(key)
        return value

    def _cache_put(self, key: tuple, gen: int, value) -> None:
        if gen != self.live.generation:
            return                      # swapped mid-flight: don't poison
        self._cache[key] = (gen, value)
        while len(self._cache) > self.cache_entries:
            self._cache.popitem(last=False)

    # ---------------------------------------------------------- submit

    def submit(self, q: Query) -> Future:
        """Enqueue one query; resolve immediately on a cache hit.
        (``repro.api.GraphSession.query``/``query_many`` wrap this with
        construction and lifecycle — prefer them in application
        code.)"""
        fut: Future = Future()
        key = query_cache_key(q, self.layout)
        with self._cv:
            pend = self._pend_counts
            pend["submitted"] += 1
            hit = self._cache_get(key)
            if hit is not None:
                pend["cache_hits"] += 1
                fut.set_result(hit)
                return fut
            pend["cache_misses"] += 1
            while (self.max_pending is not None
                   and len(self._queue) >= self.max_pending):
                if self.overload == "raise":
                    pend["rejected"] += 1
                    raise OverloadError(
                        f"{len(self._queue)} requests already pending "
                        f"(max_pending={self.max_pending})")
                self._cv.wait()          # paced: drain frees space
            self._queue.append((q, key, fut, clock.now()))
            if len(self._queue) > self._pend_maxdepth:
                self._pend_maxdepth = len(self._queue)
            self._cv.notify()
            full = len(self._queue) >= self.max_batch
        if full and self._thread is None:
            self._drain_one_batch()
        return fut

    def submit_sweep(self, measure: str, t_lo: int, t_hi: int, *,
                     stride: int = 1, v: int | None = None,
                     scope: str | None = None) -> Future:
        """Enqueue one time-sweep (``evolve``) request.

        Sweeps ride the same coalescing path as point queries: same
        deadline/batch-size drain, duplicate sweeps within a batch
        collapse to one evaluation, repeated sweeps within an epoch hit
        the exact-result cache (the full sample array is the cached
        value).  The engine groups co-batched sweeps sharing (measure,
        stride, anchor) into ONE device program."""
        scope = scope or ("node" if v is not None else "global")
        return self.submit(Query("evolve", scope, measure, t_k=int(t_lo),
                                 t_l=int(t_hi), v=v, stride=int(stride)))

    def serve(self, queries: Sequence[Query]) -> list:
        """Synchronous convenience: submit everything, flush, gather."""
        futs = [self.submit(q) for q in queries]
        self.flush()
        return [f.result() for f in futs]

    # ------------------------------------------------------- scheduler

    def flush(self) -> int:
        """Drain every queued request now (≤ max_batch per dispatch)."""
        n = 0
        while True:
            served = self._drain_one_batch()
            if not served:
                return n
            n += served

    def _drain_one_batch(self) -> int:
        with self._cv:
            batch, self._queue = (self._queue[:self.max_batch],
                                  self._queue[self.max_batch:])
            self._sync_stats()           # fold submit-path counts
            self._cv.notify_all()        # wake blocked submitters
        if not batch:
            return 0
        now = clock.now()
        for entry in batch:
            self._m_wait.observe(now - entry[3])
        if self.shed_after_ms is not None:
            cutoff = now - self.shed_after_ms / 1e3
            kept = []
            for entry in batch:
                if entry[3] < cutoff:
                    self._m["shed"].inc()
                    entry[2].set_exception(OverloadError(
                        f"request shed after waiting past "
                        f"{self.shed_after_ms}ms"))
                else:
                    kept.append(entry)
            if not kept:
                return len(batch)
            n_shed, batch = len(batch) - len(kept), kept
        else:
            n_shed = 0
        gen = self.live.generation
        w = self.live.t_served
        if self.stale == "raise":
            # fail ONLY the past-watermark requests — one early query
            # must not poison the coalesced batch of servable ones
            servable = []
            for entry in batch:
                q = entry[0]
                t_hi = q.t_k if q.t_l is None else max(q.t_k, q.t_l)
                if t_hi > w:
                    entry[2].set_exception(WatermarkError(
                        f"query time {t_hi} is past the watermark "
                        f"t_served={w}"))
                else:
                    servable.append(entry)
            if not servable:
                return len(batch) + n_shed
        else:
            servable = batch
        # collapse duplicate keys: one evaluation, every future filled
        uniq: dict[tuple, list[Future]] = {}
        uniq_qs: list[Query] = []
        for q, key, fut, _ts in servable:
            if key not in uniq:
                uniq[key] = []
                uniq_qs.append(q)
            else:
                self._m["coalesced_dupes"].inc()
            uniq[key].append(fut)
        try:
            with trace_span("frontend.dispatch", batch=len(uniq_qs)):
                results = self.live.evaluate_many(
                    uniq_qs, stale=self.stale, layout=self.layout,
                    **self.evaluate_kw)
        except Exception as exc:            # noqa: BLE001 — fan out
            for futs in uniq.values():
                for f in futs:
                    f.set_exception(exc)
            return len(batch) + n_shed
        resolved = []
        for q, (key, futs), r in zip(uniq_qs, uniq.items(), results):
            value = np.asarray(r)
            value = value.item() if value.ndim == 0 else value
            t_hi = q.t_k if q.t_l is None else max(q.t_k, q.t_l)
            resolved.append((key, value, t_hi, futs))
        # cache writes go under the queue lock: submitters read the
        # OrderedDict under _cv, and dict reshaping during a lock-free
        # write is a real data race (graphlint: unlocked-mutation)
        with self._cv:
            for key, value, t_hi, _futs in resolved:
                if t_hi <= w:
                    # only exact (within-watermark) results cacheable
                    self._cache_put(key, gen, value)
        for _key, value, _t_hi, futs in resolved:
            for f in futs:
                f.set_result(value)
        self._m["batches"].inc()
        self._m["served"].inc(len(batch))
        self._m["max_batch_seen"].set_max(len(batch))
        return len(batch) + n_shed

    def _scheduler(self) -> None:
        while True:
            with self._cv:
                while self._running and not self._queue:
                    self._cv.wait(timeout=0.1)
                if not self._running and not self._queue:
                    return
                oldest = self._queue[0][3]
                deadline = oldest + self.max_delay_ms / 1e3
                now = clock.now()
                ready = (len(self._queue) >= self.max_batch
                         or now >= deadline)
                if not ready:
                    self._cv.wait(timeout=deadline - now)
                    ready = bool(self._queue) and (
                        len(self._queue) >= self.max_batch
                        or clock.now() >= deadline)
            if ready:
                self._drain_one_batch()

    def start(self) -> "MicroBatchFrontend":
        """Spawn the deadline-draining scheduler thread."""
        if self._thread is None:
            self._running = True
            self._thread = threading.Thread(target=self._scheduler,
                                            name="frontend-scheduler",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the scheduler after draining what is queued."""
        th = self._thread
        if th is None:
            return
        with self._cv:
            self._running = False
            self._cv.notify_all()
        th.join(timeout=10)
        self._thread = None
        self.flush()
