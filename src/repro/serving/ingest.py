"""Double-buffered live ingest: a frozen epoch serves, a pending log fills.

The paper's storage model is inherently live — the current snapshot
plus an append-only delta absorbing new time-annotated operations
(Algorithm 3) — but the batch engine assumes ingest has stopped before
queries start.  ``LiveGraphStore`` removes that assumption with two
buffers and one pointer flip:

* **Pending buffer** (host): ``append`` lands writes in a plain python
  list.  No device work, no cache invalidation, no effect on in-flight
  queries — the write path costs an O(1) append plus two integer
  comparisons.

* **Frozen epoch** (device): queries run against an immutable
  ``HistoricalQueryEngine`` built by the last epoch swap.  Its delta,
  snapshots, placements and host planning copies never change after
  the flip, so the read path is exactly the batch engine's.

* **Epoch swap** (``swap()``): drains the pending buffer, feeds it
  through ``TemporalGraphStore.ingest``/``advance_to`` (registry
  rebasing included), lets the materialization policy rebalance the
  anchor set against the epoch's query histogram, then builds the next
  frozen engine with ``store.freeze_serving_state`` — delta device
  conversion, edge-snapshot rebase, and (given a mesh) the eager
  multi-device placements all happen HERE, off the serving critical
  path — and finally flips the engine pointer.  ``swap_async`` runs
  the whole thing on a daemon thread while the old epoch keeps
  serving.

**Watermark.** ``t_served`` defines exactness: every query with times
``t ≤ t_served`` is answered bit-identically to a from-scratch store
built from the full op log (tests/test_serving.py).  It is the frozen
epoch's ``t_cur``, clamped below the earliest pending-op time — ops
can only arrive with strictly increasing times past the watermark, so
served history is immutable.  Queries beyond it either raise
(``stale="raise"``, the default), block on a synchronous swap
(``stale="block"``), or are served best-effort from the frozen state
(``stale="serve"``).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Sequence

from repro.core.engine import HistoricalQueryEngine, WatermarkError
from repro.core.plans import Query
from repro.core.store import Op, TemporalGraphStore
from repro.obs import clock
from repro.obs.metrics import default_registry
from repro.obs.slowlog import SlowQueryLog
from repro.obs.trace import trace_span
from repro.serving.policy import WorkloadStats

__all__ = ["LiveGraphStore", "SwapRecord", "WatermarkError"]


@dataclasses.dataclass(frozen=True)
class SwapRecord:
    """One epoch swap, as observed by the serving layer."""

    epoch: int
    t_served: int
    ops_absorbed: int
    ops_rejected: int
    seconds: float
    anchors_added: tuple[int, ...] = ()
    anchors_evicted: tuple[int, ...] = ()


class LiveGraphStore:
    """A continuously-serving temporal graph store.

    ``policy`` follows the serving rebalance protocol
    (``serving.policy``): called at each swap with the store and the
    epoch's ``WorkloadStats``.  ``mesh`` makes every frozen epoch a
    multi-device engine (``place_on_mesh`` placements are part of the
    swap, so steady-state queries never pay placement transfers).
    ``delta_cap_hint`` pre-sizes the device log (rounded up to a power
    of two) so the frozen delta keeps one shape across epochs — no
    steady-state recompiles until ingest outgrows the hint.
    ``group_pad_min`` pads every executor group to at least that many
    queries (set it to the frontend's micro-batch size): fragmented
    batches then reuse one compiled program per group key instead of
    one per occupancy.

    With the (default) segmented store, a swap seals the epoch's ops
    into an immutable ``Segment`` and converts ONLY that tail to
    device arrays — successive frozen epochs share the sealed
    history's device arrays by reference, so swap cost is O(ops since
    the last swap) instead of O(total history).
    ``segment_device_budget`` bounds the device bytes the sealed log
    may hold: cold segments are spilled to host at the swap and
    reloaded on demand when a query window touches them.

    A store opened through ``repro.persist.open_store`` (or
    ``repro.api.GraphSession(path=...)``) makes the whole serving
    lifecycle durable: ``append`` WAL-logs each batch *before*
    buffering it, a swap logs its drain intent before ingesting and
    persists the segment/anchor manifest before flipping the engine
    pointer, so ``kill -9`` at any instant recovers bit-exactly.
    ``pending`` seeds the buffer with ops recovered from that WAL —
    they are already durable and are NOT re-logged.
    """

    def __init__(self, n_cap: int = 0, *, e_cap: int | None = None,
                 layout: str = "dense", policy=None, mesh=None,
                 indexed: bool = False, node_cap: int = 1024,
                 delta_cap_hint: int | None = None,
                 group_pad_min: int = 1,
                 segment_device_budget: int | None = None,
                 store: TemporalGraphStore | None = None,
                 pending: Sequence[Op] = (), metrics=None,
                 slow_query_ms: float | None = None):
        if store is None:
            store = TemporalGraphStore(n_cap, e_cap=e_cap, layout=layout)
        if segment_device_budget is not None:
            if not store.segmented:
                raise ValueError(
                    "segment_device_budget needs a segmented store "
                    "(the monolithic log keeps the full history "
                    "device-resident)")
            # host-residency budget for the segmented delta log: cold
            # sealed segments past this many device bytes are spilled
            # to host at each swap and reloaded on demand
            store.segment_device_budget = int(segment_device_budget)
        if policy is not None and store.layout != "dense":
            raise ValueError("materialization policies need the dense "
                             "layout (snapshots are stored dense)")
        self.store = store
        if delta_cap_hint:
            # pre-size the device log for expected growth: every epoch
            # then freezes a delta of the SAME capacity, so swap never
            # changes a kernel shape (no steady-state recompiles)
            store.delta_cap_min = max(
                store.delta_cap_min,
                1 << (int(delta_cap_hint) - 1).bit_length())
        self.policy = policy
        self.mesh = mesh
        self.indexed = indexed
        self.node_cap = node_cap
        self.group_pad_min = int(group_pad_min)
        self.workload = WorkloadStats()
        self.epoch = 0
        # Result-cache invalidation token: bumped by every swap (the
        # frontend keys its exact cache on it — watermark advance
        # invalidates, per the serving contract).
        self.generation = 0
        self.swap_history: list[SwapRecord] = []
        # Recovered stores may carry an open tail past t_cur (ingested
        # but not advanced at the crash) and a WAL-durable pending
        # buffer: seed both time cursors so post-recovery appends keep
        # the stream ordered against everything already logged.
        self._pending: list[Op] = [o for o in pending if o.t > store.t_cur]
        tail_last = store._t_l[-1] if store._t_l else store.t_cur
        self._t_append_last = max([store.t_cur, tail_last]
                                  + [o.t for o in self._pending])
        # The time unit the in-flight (or last) swap closes: appends
        # validate against it as well as the engine watermark, so an op
        # at the closing time cannot slip in between the swap's buffer
        # drain and its engine flip (it would be logged but never
        # applied to the already-advanced current snapshot).
        self._t_closing = store.t_cur
        self._lock = threading.RLock()       # pending buffer + flip
        self._swap_lock = threading.Lock()   # one swap in flight
        # post-swap callbacks (fed the SwapRecord): replication publish
        # hooks live here.  They run on the swap thread AFTER the
        # checkpoint and the engine flip — the artifacts a listener
        # ships are exactly the just-persisted ones.
        self._swap_listeners: list = []
        self.listener_errors: list[BaseException] = []
        self.metrics = default_registry() if metrics is None else metrics
        self.slow_log = (SlowQueryLog(slow_query_ms)
                         if slow_query_ms is not None else None)
        # pre-created children: append() is the ingest hot path
        reg = self.metrics
        self._m_appended = reg.counter("serving_appended_ops_total",
                                       "ops accepted into pending")
        self._m_pending = reg.gauge("serving_pending_ops",
                                    "ops buffered awaiting a swap")
        self._m_watermark = reg.gauge("serving_watermark",
                                      "t_served exactness watermark")
        self._m_t_behind = reg.gauge("serving_t_behind",
                                     "time units ingest leads serving")
        self._m_swaps = reg.counter("serving_swaps_total",
                                    "epoch swaps completed")
        self._m_swap_s = reg.histogram("serving_swap_seconds",
                                       "full epoch-swap duration")
        self._m_phase = {
            ph: reg.histogram("serving_swap_phase_seconds",
                              "epoch-swap phase durations", phase=ph)
            for ph in ("drain", "ingest", "rebalance", "seal",
                       "checkpoint", "flip", "publish")}
        self._m_listener_err = reg.counter(
            "serving_listener_errors_total",
            "swap listener callbacks that raised")
        self._engine = self._freeze()

    # ------------------------------------------------------------ write path

    def append(self, ops: Iterable[Op | tuple]) -> int:
        """Land a batch of time-annotated ops in the pending buffer.

        Ops must keep the stream time-ordered and strictly past the
        watermark (served history is immutable).  Legality against the
        graph state (duplicate edges, dangling endpoints, ...) is the
        store's job at swap time — the pending buffer is just a log.
        The batch is atomic: it is validated whole, WAL-logged whole
        (durable stores — the write-ahead append happens *before* the
        buffer append, so an acknowledged op survives any crash), then
        buffered whole.  Returns the number of ops buffered.
        """
        with self._lock:
            w = max(self._engine.t_served, self._t_closing)
            t_last = self._t_append_last
            batch: list[Op] = []
            for o in ops:
                if not isinstance(o, Op):
                    o = Op(*o)
                if o.t < t_last:
                    raise ValueError(
                        f"ops must be time-ordered: got t={o.t} after "
                        f"t={t_last}")
                if o.t <= w:
                    raise ValueError(
                        f"op at t={o.t} is at or before the watermark "
                        f"t_served={w}; served history is immutable")
                batch.append(o)
                t_last = o.t
            persist = self.store.persist
            if persist is not None and batch:
                persist.log_pending(batch)
            self._pending.extend(batch)
            self._t_append_last = t_last
            self._m_appended.inc(len(batch))
            self._m_pending.set(len(self._pending))
            if batch:
                self._m_t_behind.set(max(0, t_last - w))
            return len(batch)

    @property
    def pending_ops(self) -> int:
        return len(self._pending)

    @property
    def t_served(self) -> int:
        """The exactness watermark: the frozen epoch's time frontier,
        clamped below the earliest pending op (an op appended during an
        in-flight swap may carry a time the new epoch already claims)."""
        with self._lock:
            w = self._engine.t_served
            if self._pending:
                w = min(w, self._pending[0].t - 1)
            return int(w)

    def ingest_lag(self) -> dict:
        """How far serving trails ingest: buffered ops and time units
        between the newest accepted op and the watermark."""
        with self._lock:
            return {
                "pending_ops": len(self._pending),
                "t_behind": max(0, self._t_append_last - self.t_served),
                "epoch": self.epoch,
            }

    # ------------------------------------------------------------ epoch swap

    def _freeze(self) -> HistoricalQueryEngine:
        eng = self.store.freeze_serving_state(
            mesh=self.mesh, indexed=self.indexed, node_cap=self.node_cap)
        eng.t_served = self.store.t_cur
        # the histogram is only consumed (and decayed) by a policy's
        # rebalance — without one, recording would grow it unboundedly
        eng.workload = self.workload if self.policy is not None else None
        eng.group_pad_min = self.group_pad_min
        eng.bind_metrics(self.metrics)
        eng.slow_log = self.slow_log
        return eng

    def swap(self, t_next: int | None = None) -> SwapRecord:
        """One epoch swap: drain pending → ingest/advance → policy
        rebalance → freeze the next engine → flip.  Everything before
        the flip runs against store state the frozen epoch no longer
        reads, so queries proceed concurrently (``swap_async``); the
        flip itself is a pointer assignment under the buffer lock.

        Swapping CLOSES every pending time unit (Algorithm 3's unit
        boundary): the new watermark is the newest pending op's time,
        and later appends must use strictly later times.  Producers
        streaming mid-unit should batch appends at unit boundaries (or
        accept the force-close)."""
        with self._swap_lock, \
                trace_span("swap", epoch=self.epoch + 1) as sp:
            t0 = clock.now()

            def _phase_done(name: str, since: float) -> float:
                now = clock.now()
                self._m_phase[name].observe(now - since)
                return now

            persist = self.store.persist
            with trace_span("swap.drain"), self._lock:
                pending, self._pending = self._pending, []
                t_hi = max((o.t for o in pending),
                           default=self.store.t_cur)
                target = max(int(t_next) if t_next is not None else 0,
                             t_hi, self.store.t_cur)
                # publish the closing time BEFORE ingesting: from here
                # on, concurrent appends must be strictly past it
                self._t_closing = max(self._t_closing, target)
                if persist is not None:
                    # drain intent, logged while the lock still orders
                    # us against concurrent PENDING records: replay
                    # re-executes the ingest/advance below from the
                    # same pending prefix, so their own WAL records
                    # are suppressed (the drain record subsumes them)
                    persist.log_drain(len(pending), target)
            t_ph = _phase_done("drain", t0)
            with trace_span("swap.ingest", ops=len(pending)):
                if persist is not None:
                    with persist.suspend_store_log():
                        n_acc = self.store.ingest(pending)
                        self.store.advance_to(target)
                else:
                    n_acc = self.store.ingest(pending)
                    self.store.advance_to(target)
            t_ph = _phase_done("ingest", t_ph)
            added: tuple[int, ...] = ()
            evicted: tuple[int, ...] = ()
            if self.policy is not None:
                with trace_span("swap.rebalance"):
                    res = self.policy.rebalance(self.store,
                                                self.workload)
                added = tuple(res.added)
                evicted = tuple(res.evicted)
            t_ph = _phase_done("rebalance", t_ph)
            # "seal" is the freeze: the epoch's tail becomes an
            # immutable segment + the next engine's device state
            with trace_span("swap.seal"):
                eng = self._freeze()
            t_ph = _phase_done("seal", t_ph)
            with self._lock:
                if persist is not None:
                    # persist the manifest (sealed segments + anchors +
                    # rotated WAL) BEFORE the engine pointer flips: once
                    # a client can observe the new watermark, the state
                    # below it is durable
                    with trace_span("swap.checkpoint"):
                        persist.checkpoint(self.store,
                                           pending=self._pending)
                t_ph = _phase_done("checkpoint", t_ph)
                with trace_span("swap.flip"):
                    self._engine = eng
                    self.epoch += 1
                    self.generation += 1
                self._m_watermark.set(int(eng.t_served))
                self._m_pending.set(len(self._pending))
            t_ph = _phase_done("flip", t_ph)
            rec = SwapRecord(
                epoch=self.epoch, t_served=int(eng.t_served),
                ops_absorbed=n_acc, ops_rejected=len(pending) - n_acc,
                seconds=clock.now() - t0,
                anchors_added=added, anchors_evicted=evicted)
            self.swap_history.append(rec)
            with trace_span("swap.publish",
                            listeners=len(self._swap_listeners)):
                for fn in list(self._swap_listeners):
                    try:
                        fn(rec)
                    except Exception as exc:  # noqa: BLE001 — a failed
                        # publish must not take down serving; the writer
                        # keeps its own durable copy and the listener
                        # runs again at the next swap
                        self.listener_errors.append(exc)
                        self._m_listener_err.inc()
            _phase_done("publish", t_ph)
            self._m_swaps.inc()
            self._m_swap_s.observe(clock.now() - t0)
            sp.set(ops=n_acc, t_served=int(eng.t_served))
            return rec

    def add_swap_listener(self, fn) -> None:
        """Register a post-swap callback ``fn(SwapRecord)``.  Runs on
        the swap thread after checkpoint + engine flip; exceptions are
        collected in ``listener_errors`` rather than raised."""
        with self._lock:
            self._swap_listeners.append(fn)

    def swap_async(self) -> threading.Thread:
        """Run one epoch swap on a daemon thread; the frozen epoch
        keeps serving until the flip."""
        th = threading.Thread(target=self.swap, name="epoch-swap",
                              daemon=True)
        th.start()
        return th

    def close(self) -> None:
        """Checkpoint (pending buffer included — it replays into the
        next session's buffer) and release the durability layer.
        No-op for a process-resident store."""
        persist = self.store.persist
        if persist is None:
            return
        with self._swap_lock:
            with self._lock:
                persist.checkpoint(self.store, pending=self._pending)
            persist.close()

    # ------------------------------------------------------------- read path

    @property
    def engine(self) -> HistoricalQueryEngine:
        """The frozen serving engine of the current epoch."""
        return self._engine

    def _late(self, queries: Sequence[Query], w: int) -> list[Query]:
        return [q for q in queries
                if (q.t_k if q.t_l is None else max(q.t_k, q.t_l)) > w]

    def evaluate_many(self, queries: Sequence[Query], plan: str = "auto",
                      *, stale: str = "raise", **kw):
        """Batched serving with watermark semantics.

        ``stale`` picks what happens to queries past ``t_served``:
        ``"raise"`` surfaces ``WatermarkError`` (exactness guaranteed),
        ``"block"`` runs a synchronous epoch swap first (exact, pays
        the swap latency), ``"serve"`` answers from the frozen state
        (may miss pending ops — explicitly best-effort).  Everything
        else is ``HistoricalQueryEngine.evaluate_many``.
        """
        if stale not in ("raise", "block", "serve"):
            raise ValueError(f"unknown stale mode {stale!r}")
        late = self._late(queries, self.t_served)
        if late and stale == "block":
            self.swap()
            late = self._late(queries, self.t_served)
        if late and stale != "serve":
            t_hi = max(q.t_k if q.t_l is None else max(q.t_k, q.t_l)
                       for q in late)
            raise WatermarkError(
                f"{len(late)} queries up to t={t_hi} are past the "
                f"watermark t_served={self.t_served}; swap the epoch or "
                "pass stale='block'/'serve'")
        eng = self._engine
        return eng.evaluate_many(queries, plan,
                                 enforce_watermark=not late, **kw)

    def query(self, q: Query, plan: str = "auto", **kw):
        return self.evaluate_many([q], plan, **kw)[0]
