"""Workload-driven materialization: where snapshots live, not just when.

The paper's policies (``core.materialize.MaterializationPolicy``) are
*cadence* rules — periodic, op-count, similarity — that decide **when**
to take the next snapshot but always take it at the ingest frontier.
Khurana & Deshpande (arXiv 1207.5777) show snapshot-retrieval cost is
dominated by **where** materialized snapshots sit relative to the query
workload; AeonG (arXiv 2304.12212) builds the same observation into its
serving path.  This module replaces the static cadence for live
serving:

* ``WorkloadStats`` — a query-time histogram the engine fills while it
  serves (``HistoricalQueryEngine.workload`` hook).  Epoch rollovers
  decay it, so the hot set tracks the workload as it drifts.

* ``WorkloadMaterializationPolicy`` — at each epoch swap, turns the
  histogram into a target anchor set under a device-byte budget:
  greedily pick the hottest query times that are at least
  ``min_gap_ops`` log operations away from every other anchor (ops
  distance is the reconstruction cost the ``AnchorSelector`` actually
  pays — Theorem 1), keep existing snapshots that already cover a
  target, materialize the uncovered ones, and evict anchors that are
  cold or over budget.  The anchors land in ``store.materialized``,
  which the ``AnchorSelector`` prices on the next engine build — so
  observed workload directly reshapes reconstruction cost.

* ``PeriodicMaterializationPolicy`` — the static cadence expressed in
  the same ``rebalance`` protocol, kept as the serving-layer baseline
  (``benchmarks/bench_serving.py`` races the two on a hot-tail
  workload).

Everything here is host-side planning; the only device work is the
reconstruction of snapshots the policy decides to add.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
from typing import Sequence

import numpy as np


class WorkloadStats:
    """Query-time histogram accumulated per serving epoch.

    ``record_queries`` is the engine-facing hook
    (``HistoricalQueryEngine.workload``): every served query drops its
    time endpoints here.  Weights are floats because epoch rollovers
    decay them (``decay``) instead of resetting — a time that was hot
    two epochs ago still counts, just less.

    Bounded by construction (tests/test_obs.py): the histogram holds at
    most ``max_times`` distinct times — when an epoch's queries touch
    more, the lightest entries are pruned (their mass leaves ``total``
    too), so a scan workload cannot grow the dict without limit between
    rollovers.  ``queries_recorded`` decays at ``rollover`` along with
    the weights: it is an exponentially-aged activity level (what the
    policy would see as "recent traffic"), not a forever-monotonic
    count — the registry's ``engine_queries_total`` is the monotonic
    one.
    """

    def __init__(self, *, max_times: int = 4096):
        self.max_times = int(max_times)
        self._w: dict[int, float] = {}
        self.total = 0.0
        self.queries_recorded = 0.0
        self._lock = threading.Lock()

    def record(self, times, weight: float = 1.0) -> None:
        with self._lock:
            for t in times:
                t = int(t)
                self._w[t] = self._w.get(t, 0.0) + weight
                self.total += weight
            if len(self._w) > self.max_times:
                # prune the lightest ~1/8 in one pass (amortized: the
                # next few thousand inserts are bound-free) and keep
                # ``total`` consistent with the surviving mass
                drop = heapq.nsmallest(
                    len(self._w) - self.max_times * 7 // 8,
                    self._w.items(), key=lambda kv: (kv[1], kv[0]))
                for t, w in drop:
                    del self._w[t]
                    self.total -= w

    def record_queries(self, queries) -> None:
        """Engine hook: record t_k (and t_l for range queries).

        Sweep (``evolve``) queries record EVERY swept sample time, each
        at weight 1/B — one dashboard sweep carries one query's total
        mass, spread over its window, so sweep-heavy workloads pull
        anchors toward the swept region without a single wide sweep
        drowning out the point traffic."""
        ts = []
        for q in queries:
            if getattr(q, "kind", "") == "evolve" and q.t_l is not None:
                stride = max(int(getattr(q, "stride", 1)), 1)
                swept = range(int(q.t_k), int(q.t_l) + 1, stride)
                self.record(swept, weight=1.0 / max(len(swept), 1))
                continue
            ts.append(q.t_k)
            if q.t_l is not None:
                ts.append(q.t_l)
        self.record(ts)
        with self._lock:
            self.queries_recorded += len(queries)

    def histogram(self) -> dict[int, float]:
        with self._lock:
            return dict(self._w)

    def hot_times(self) -> list[tuple[int, float]]:
        """(time, weight) sorted by weight desc, time asc on ties —
        deterministic input to the greedy anchor placement."""
        with self._lock:
            return sorted(self._w.items(), key=lambda kv: (-kv[1], kv[0]))

    def mass_near(self, t: int, t_sorted: np.ndarray, gap_ops: int) -> float:
        """Total query weight within ``gap_ops`` log operations of
        ``t`` — the "is this anchor hot" integral."""
        total = 0.0
        with self._lock:
            items = list(self._w.items())
        for tq, w in items:
            if _ops_between(t_sorted, t, tq) <= gap_ops:
                total += w
        return total

    def rollover(self, decay: float) -> None:
        """Epoch boundary: decay every weight (and the activity level),
        drop negligible ones.  This is the anti-overflow contract: with
        a policy attached, every swap multiplies the whole histogram by
        ``decay < 1``, so long-running servers converge to a bounded
        steady state instead of accumulating forever."""
        with self._lock:
            self._w = {t: w * decay for t, w in self._w.items()
                       if w * decay > 1e-3}
            self.total = sum(self._w.values())
            self.queries_recorded *= decay


def _ops_between(t_sorted, t_a: int, t_b: int) -> int:
    """#log ops in the (t_lo, t_hi] window between two times — the
    AnchorSelector's exact cost proxy.  ``t_sorted`` is either a host
    timestamp array or a ``SegmentedDeltaView`` (per-segment op
    counts — the segmented store never concatenates its full
    timestamp column just to cost anchors); the counting rule itself
    is the planner's, shared via ``core.segments``."""
    from repro.core.segments import window_ops_count
    lo, hi = (t_a, t_b) if t_a <= t_b else (t_b, t_a)
    return window_ops_count(t_sorted, lo, hi)


@dataclasses.dataclass
class RebalanceResult:
    """What one policy pass did to ``store.materialized``."""

    targets: list[int]
    added: list[int]
    evicted: list[int]
    kept: list[int]
    budget_snapshots: int


@dataclasses.dataclass
class WorkloadMaterializationPolicy:
    """Greedy hot-anchor placement under a device-byte budget.

    ``budget_bytes`` caps the materialized sequence's device footprint
    (snapshot size comes from the engine's ``_snapshot_bytes``);
    ``min_gap_ops`` is the minimum ops-distance between anchors —
    below it a second anchor saves less than it costs, because the
    ``AnchorSelector`` would reconstruct through ``min_gap_ops`` ops
    anyway.  ``decay`` ages the histogram at each rebalance so the
    anchor set follows workload drift.
    """

    budget_bytes: int = 256 << 20
    min_gap_ops: int = 128
    decay: float = 0.5
    max_adds_per_epoch: int = 4

    def plan(self, *, stats: WorkloadStats, existing: Sequence[int],
             t_sorted: np.ndarray, t_cur: int,
             bytes_per_snapshot: int) -> RebalanceResult:
        k_max = int(self.budget_bytes // max(int(bytes_per_snapshot), 1))
        existing = [int(t) for t in existing]
        if stats.total <= 0 or k_max == 0:
            # no observed workload: leave the anchor set alone (but
            # still enforce the budget on whatever is there)
            evict = sorted(existing)[:max(0, len(existing) - k_max)]
            return RebalanceResult(targets=[], added=[], evicted=evict,
                                   kept=[t for t in existing
                                         if t not in evict],
                                   budget_snapshots=k_max)

        # 1. Greedy target set: hottest times first, spaced at least
        #    min_gap_ops from each other and from the free anchor at
        #    t_cur (the current snapshot always competes — Theorem 1).
        targets: list[int] = []
        for t, _w in stats.hot_times():
            if len(targets) >= k_max:
                break
            if t > t_cur or t < 0:
                continue
            if _ops_between(t_sorted, t, t_cur) <= self.min_gap_ops:
                continue
            if any(_ops_between(t_sorted, t, s) <= self.min_gap_ops
                   for s in targets):
                continue
            targets.append(t)

        # 2. Existing anchors within the gap of a target cover it.
        kept, covered = [], set()
        for s in existing:
            near = [t for t in targets
                    if _ops_between(t_sorted, s, t) <= self.min_gap_ops]
            if near and len(kept) < k_max:
                kept.append(s)
                covered.update(near)

        # 3. Materialize the uncovered targets, hottest first, within
        #    budget and the per-epoch add cap (reconstruction work at
        #    swap time is bounded).
        room = min(k_max - len(kept), self.max_adds_per_epoch)
        added = [t for t in targets if t not in covered][:max(0, room)]

        # 4. Evict the cold remainder: anchors covering no target are
        #    dead weight under the budget; with observed workload they
        #    only survive if they still see query mass nearby.
        evicted = []
        for s in existing:
            if s in kept:
                continue
            cold = stats.mass_near(s, t_sorted, self.min_gap_ops) <= 0.0
            over_budget = len(kept) + len(added) >= k_max
            if cold or over_budget:
                evicted.append(s)
            else:
                kept.append(s)
        return RebalanceResult(targets=targets, added=added,
                               evicted=evicted, kept=kept,
                               budget_snapshots=k_max)

    def rebalance(self, store, stats: WorkloadStats) -> RebalanceResult:
        """Apply one policy pass to ``store.materialized`` (the epoch
        swap calls this off the serving critical path)."""
        from repro.core.engine import _snapshot_bytes
        if getattr(store, "layout", "dense") != "dense":
            raise ValueError("materialization needs the dense layout "
                             "(snapshots are stored dense)")
        t_src = (store.op_count_source()
                 if hasattr(store, "op_count_source")
                 else store.op_times_host())
        res = self.plan(stats=stats, existing=store.materialized.times,
                        t_sorted=t_src, t_cur=store.t_cur,
                        bytes_per_snapshot=_snapshot_bytes(store.current))
        for t in res.evicted:
            store.materialized.remove(t)
        for t in res.added:
            g = store.snapshot_at(t, use_materialized=True)
            store.materialized.add(t, g)
        stats.rollover(self.decay)
        return res


@dataclasses.dataclass
class PeriodicMaterializationPolicy:
    """The static cadence in serving clothes: an anchor every
    ``period`` time units behind the frontier, oldest evicted first
    under the same byte budget.  Exists as the baseline the
    workload-driven policy is benchmarked against."""

    period: int = 64
    budget_bytes: int = 256 << 20

    def rebalance(self, store, stats: WorkloadStats) -> RebalanceResult:
        from repro.core.engine import _snapshot_bytes
        k_max = int(self.budget_bytes
                    // max(_snapshot_bytes(store.current), 1))
        existing = sorted(int(t) for t in store.materialized.times)
        last = max(existing, default=0)
        added = []
        t = last + self.period
        while t <= store.t_cur and len(added) < 8:
            g = store.snapshot_at(t, use_materialized=True)
            store.materialized.add(t, g)
            added.append(t)
            t += self.period
        evicted = []
        while len(store.materialized.times) > k_max:
            oldest = min(store.materialized.times)
            store.materialized.remove(oldest)
            evicted.append(oldest)
        return RebalanceResult(targets=added, added=added, evicted=evicted,
                               kept=[t for t in existing
                                     if t not in evicted],
                               budget_snapshots=k_max)
