# The paper's primary contribution: graph deltas for historical queries.
# Storage model (snapshots + interval deltas), reconstruction (sequential
# & last-writer-wins), query plans, indexes, materialization, and the
# distributed (shard_map) engine.
from repro.core.delta import (ADD_EDGE, ADD_NODE, NOP, REM_EDGE, REM_NODE,
                              Delta, concat_deltas, delta_from_numpy,
                              empty_delta, minimal_delta_between, slice_delta)
from repro.core.engine import (AnchorCandidate, AnchorSelector,
                               HistoricalQueryEngine, PlanChoice, Planner,
                               WatermarkError)
from repro.core.graph import (DenseGraph, EdgeGraph, dense_from_numpy,
                              dense_to_edge, edge_to_dense, empty_dense,
                              empty_edge)
from repro.core.index import (NodeIndex, build_node_index,
                              build_node_index_host, count_window_ops,
                              gather_node_ops, gather_window, temporal_range)
from repro.core.materialize import (MaterializationPolicy, MaterializedStore,
                                    edge_jaccard)
from repro.core.partial import closure_mask, partial_reconstruct, seed_mask
from repro.core.plans import Query, applicable_plans, evaluate, two_phase
from repro.core.reconstruct import (degree_series, node_degree_series,
                                    reconstruct_at, reconstruct_dense,
                                    reconstruct_edge, reconstruct_sequential)
from repro.core.store import Op, TemporalGraphStore

__all__ = [k for k in dir() if not k.startswith("_")]
