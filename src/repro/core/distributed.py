"""Distributed temporal-graph engine (DESIGN.md §2.4).

The paper names parallel snapshot reconstruction (à la Pregel/GBASE) as
future work; here it is, in two layers:

**Primitives** (bottom half of this file): adjacency rows + node mask
sharded over a 1-D ``rows`` mesh axis, the delta log replicated (it is
tiny next to N²), reconstruction row-parallel with zero communication,
global measures psum partial aggregates.

**Sharded group execution** (top half): the engine's batched executor
(``core.engine.evaluate_many``) groups queries by (plan choice,
anchor); a group is exactly the unit that is device-parallel, and this
module turns one group dispatch into one multi-device program:

* hybrid / delta-only groups → ``batch_sharded``: graph + delta
  replicated, the padded query batch axis split over the mesh.  Each
  device runs the identical vmapped kernel on its query slice, so
  results are bit-identical to the single-device path by construction.
* two-phase groups → ``two_phase_rows``: queries replicated, adjacency
  rows split; every device runs the LWW delta-apply scatter on its row
  block only (O(N²/D) work) and contributes integer partial sums that
  are ``psum``'d into the global measure.  Integer partials make the
  combination exact, so these also bit-match the single-device path.

All functions are shard_map programs over an existing mesh; they make
no assumption about the device count (tests run them on 8 forced host
devices, the production mesh on 512).  With a 1-device mesh the engine
never routes here — the host-process fallback is the ordinary path.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from repro.core.delta import ADD_EDGE, Delta
from repro.core.graph import DenseGraph, EdgeGraph
from repro.core.plans import masked_aggregate
from repro.core.reconstruct import _lww_decide
from repro.sharding.graph import (AXIS, batch_specs,  # noqa: F401
                                  graph_mesh, replicate, shard_rows,
                                  shard_slots)
# graph_mesh / replicate are re-exported: callers historically import
# them from here.


def shard_graph(g: DenseGraph, mesh: Mesh) -> DenseGraph:
    """Place adjacency rows / node mask row-sharded on the mesh."""
    return shard_rows(g, mesh)


def shard_edge_graph(g: EdgeGraph, mesh: Mesh) -> EdgeGraph:
    """Place an edge-layout snapshot slot-sharded on the mesh."""
    return shard_slots(g, mesh)


# ---------------------------------------------------------------------------
# Sharded group execution: batch-axis sharding (hybrid / delta-only)
# ---------------------------------------------------------------------------

# (mesh, kernel, statics, qmask) -> jitted shard_map program.  Kernels
# are module-level jitted functions, statics are hashable (name, value)
# pairs, so the cache key is stable across calls and each program
# compiles once per padded shape.
_BATCH_CACHE: dict = {}


def batch_sharded(mesh: Mesh, kernel, statics: tuple, args: tuple,
                  qmask: tuple):
    """Run ``kernel(*args, **dict(statics))`` with the query-batch axis
    of the ``qmask``-flagged args split over the mesh.

    Every other arg (graph, delta, scalars) is replicated.  The kernel
    body is the *same* vmapped program the single-device executor runs,
    applied to a contiguous slice of the batch, so per-query results
    are bit-identical; out axis ``P(AXIS)`` re-concatenates slices in
    order.  Batch length must be a multiple of the device count
    (``sharding.graph.batch_pad``).
    """
    key = (mesh, kernel, statics, qmask)
    fn = _BATCH_CACHE.get(key)
    if fn is None:
        bound = functools.partial(kernel, **dict(statics))
        fn = jax.jit(shard_map(lambda *a: bound(*a), mesh=mesh,
                               in_specs=batch_specs(qmask),
                               out_specs=P(AXIS)))
        _BATCH_CACHE[key] = fn
    return fn(*args)


# ---------------------------------------------------------------------------
# Sharded group execution: row-sharded two-phase with psum measures
# ---------------------------------------------------------------------------

# Measures whose value decomposes into a sum of per-row-block integer
# partials (finalized identically to the single-device formula after
# the psum).  Everything else routes through batch_sharded.
ROW_MEASURES = ("degree", "num_nodes", "num_edges", "density",
                "avg_degree")


def _row_parts(nodes_l, adj_l, v, row0, measure: str):
    """Integer partial sums of this shard's row block: i32[2] =
    (node-ish partial, edge partial).  Edge rows count each edge twice
    across the full mesh — finalization divides by 2, exactly like
    ``DenseGraph.num_edges``."""
    i32 = jnp.int32
    n_loc = adj_l.shape[0]
    if measure == "degree":
        lv = v - row0
        ok = (lv >= 0) & (lv < n_loc)
        row = adj_l[jnp.clip(lv, 0, n_loc - 1)]
        deg = jnp.where(ok, jnp.sum(row.astype(i32)), 0)
        return jnp.stack([deg, jnp.zeros((), i32)])
    nn = jnp.sum(nodes_l.astype(i32))
    ee = jnp.sum(adj_l.astype(i32))
    return jnp.stack([nn, ee])


def _row_finalize(tot, measure: str):
    """Global measure from psum'd partials — the same arithmetic as the
    single-device measures in ``core.queries`` (exact for integers,
    identical f32 expression for density/avg_degree)."""
    if measure == "degree":
        return tot[..., 0]
    if measure == "num_nodes":
        return tot[..., 0]
    if measure == "num_edges":
        return tot[..., 1] // 2
    n = tot[..., 0]
    e = tot[..., 1] // 2
    if measure == "density":
        nf = n.astype(jnp.float32)
        ef = e.astype(jnp.float32)
        return jnp.where(nf > 1, 2.0 * ef / (nf * (nf - 1.0)), 0.0)
    if measure == "avg_degree":
        nf = jnp.maximum(n, 1).astype(jnp.float32)
        return 2.0 * e.astype(jnp.float32) / nf
    raise ValueError(f"measure {measure} is not row-decomposable")


_ROW_CACHE: dict = {}


def two_phase_rows(mesh: Mesh, anchor: DenseGraph, delta: Delta, t_anchor,
                   tks, tls, vs, *, kind: str, measure: str, agg: str = "",
                   num_buckets: int = 0):
    """One two-phase (plan, anchor) group as a row-parallel program.

    The anchor's rows are split over the mesh (``shard_graph`` layout);
    the delta and the query arrays are replicated.  Each device
    LWW-reconstructs only its row block per query time (the row-sharded
    delta-apply scatter — O(B · N²/D) instead of O(B · N²)) and emits
    integer partial sums; one psum per group combines them, then the
    measure is finalized with the single-device formula, so results
    bit-match ``core.engine.batch_two_phase_*``.

    Supported: kind ∈ {point, diff, agg} × measure ∈ ROW_MEASURES.
    """
    key = (mesh, kind, measure, agg, num_buckets)
    fn = _ROW_CACHE.get(key)
    if fn is None:
        fn = jax.jit(shard_map(
            functools.partial(_two_phase_rows_local, kind=kind,
                              measure=measure, agg=agg,
                              num_buckets=num_buckets),
            mesh=mesh,
            in_specs=(P(AXIS), P(AXIS, None), P(), P(), P(), P(), P()),
            out_specs=P()))
        _ROW_CACHE[key] = fn
    return fn(anchor.nodes, anchor.adj, delta, t_anchor, tks, tls, vs)


def _two_phase_rows_local(nodes_l, adj_l, delta, t_anchor, tks, tls, vs,
                          *, kind, measure, agg, num_buckets):
    row0 = jax.lax.axis_index(AXIS) * adj_l.shape[0]

    def parts_at(base_nodes, base_adj, t_base, t, v):
        nl, al = _local_lww(base_nodes, base_adj, delta, t_base, t)
        return _row_parts(nl, al, v, row0, measure), (nl, al)

    if kind == "point":
        def one(t, v):
            return parts_at(nodes_l, adj_l, t_anchor, t, v)[0]

        parts = jax.vmap(one)(tks, vs)                       # [B, 2]
        return _row_finalize(jax.lax.psum(parts, AXIS), measure)

    if kind == "diff":
        # SG_tl from the anchor, then SG_tk from SG_tl — the same
        # nearer-snapshot reuse as the single-device diff kernel.
        def one(tk, tl, v):
            p_l, (nl, al) = parts_at(nodes_l, adj_l, t_anchor, tl, v)
            p_k, _ = parts_at(nl, al, tl, tk, v)
            return p_l, p_k

        p_l, p_k = jax.vmap(one)(tks, tls, vs)               # [B, 2] each
        a = _row_finalize(jax.lax.psum(p_l, AXIS), measure)
        b = _row_finalize(jax.lax.psum(p_k, AXIS), measure)
        return jnp.abs(a - b)

    # agg: one reconstruction per bucket (times past each query's t_l
    # are computed but masked by masked_aggregate, exactly as in
    # batch_two_phase_agg).
    def one(tk, tl, v):
        ts = tk + jnp.arange(num_buckets, dtype=jnp.int32)
        return jax.lax.map(
            lambda t: parts_at(nodes_l, adj_l, t_anchor, t, v)[0], ts)

    parts = jax.vmap(one)(tks, tls, vs)                      # [B, nb, 2]
    vals = _row_finalize(jax.lax.psum(parts, AXIS), measure)  # [B, nb]
    return jax.vmap(
        lambda row, tk, tl: masked_aggregate(row, tl - tk + 1,
                                             num_buckets, agg))(
        vals, tks, tls)


# ---------------------------------------------------------------------------
# Sharded group execution: slot-sharded edge-layout two-phase
# ---------------------------------------------------------------------------

# Measures combinable from per-slot-shard integer partials.  Slots
# partition the edge set (each undirected edge lives in exactly one
# slot), so per-shard popcounts / incident-slot counts sum to the
# global count — the same exactness argument as row-sharding, with the
# simplification that no edge is ever double-counted (rows see each
# edge twice, slots once).
SLOT_MEASURES = ROW_MEASURES


def _slot_parts(nodes_cur, live_l, eu_l, ev_l, v, measure: str):
    """Integer partial sums of this shard's slot block: i32[2] =
    (node-ish partial, edge partial).  The node mask is replicated
    (N-sized), so only shard 0 contributes its count."""
    i32 = jnp.int32
    if measure == "degree":
        touch = live_l & ((eu_l == v) | (ev_l == v))
        return jnp.stack([jnp.sum(touch.astype(i32)),
                          jnp.zeros((), i32)])
    on_zero = jax.lax.axis_index(AXIS) == 0
    nn = jnp.where(on_zero, jnp.sum(nodes_cur.astype(i32)), 0)
    ee = jnp.sum(live_l.astype(i32))
    return jnp.stack([nn, ee])


def _slot_finalize(tot, measure: str):
    """Global measure from psum'd slot partials — identical arithmetic
    to the single-device edge measures (``core.queries``): slots count
    each edge once, so no halving (unlike ``_row_finalize``)."""
    if measure in ("degree", "num_nodes"):
        return tot[..., 0]
    if measure == "num_edges":
        return tot[..., 1]
    n = tot[..., 0]
    e = tot[..., 1]
    if measure == "density":
        nf = n.astype(jnp.float32)
        ef = e.astype(jnp.float32)
        return jnp.where(nf > 1, 2.0 * ef / (nf * (nf - 1.0)), 0.0)
    if measure == "avg_degree":
        nf = jnp.maximum(n, 1).astype(jnp.float32)
        return 2.0 * e.astype(jnp.float32) / nf
    raise ValueError(f"measure {measure} is not slot-decomposable")


_SLOT_CACHE: dict = {}


def two_phase_slots(mesh: Mesh, anchor: EdgeGraph, delta: Delta, t_anchor,
                    tks, tls, vs, *, kind: str, measure: str,
                    agg: str = "", num_buckets: int = 0):
    """One edge-layout two-phase (plan, anchor) group as a
    slot-parallel program.

    The anchor's slot registry (eu/ev/emask) is split over the mesh
    (``shard_slots`` layout); the node mask, the delta and the query
    arrays are replicated.  Each device LWW-reconstructs only its slot
    block per query time (O(B · E/D) scatter work) and emits integer
    partial sums; one psum per group combines them and the measure is
    finalized with the single-device edge formula, so results
    bit-match ``core.engine.batch_edge_two_phase_*`` — and hence the
    dense path too (tests/test_distributed.py).

    Supported: kind ∈ {point, diff, agg} × measure ∈ SLOT_MEASURES.
    """
    key = (mesh, kind, measure, agg, num_buckets)
    fn = _SLOT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(shard_map(
            functools.partial(_two_phase_slots_local, kind=kind,
                              measure=measure, agg=agg,
                              num_buckets=num_buckets),
            mesh=mesh,
            in_specs=(P(), P(AXIS), P(AXIS), P(AXIS), P(), P(), P(),
                      P(), P(), P()),
            out_specs=P()))
        _SLOT_CACHE[key] = fn
    return fn(anchor.nodes, anchor.eu, anchor.ev, anchor.emask,
              anchor.n_edges_reg, delta, t_anchor, tks, tls, vs)


def _slot_lww(emask_l, delta: Delta, t_anchor, t_query, slot0):
    """Shard-local last-writer-wins over the local slot block (ops are
    pre-resolved to slot ids host-side, so this is a 1-D scatter)."""
    e_loc = emask_l.shape[0]
    m = delta.capacity
    forward = t_query >= t_anchor
    t_lo = jnp.minimum(t_anchor, t_query)
    t_hi = jnp.maximum(t_anchor, t_query)
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    idx = jnp.arange(m, dtype=jnp.int32)

    ew = in_win & delta.is_edge_op()
    ls = delta.slot - slot0
    ok = ew & (ls >= 0) & (ls < e_loc)
    ls = jnp.clip(ls, 0, e_loc - 1)
    first = jnp.full((e_loc,), m, jnp.int32).at[ls].min(
        jnp.where(ok, idx, m))
    last = jnp.full((e_loc,), -1, jnp.int32).at[ls].max(
        jnp.where(ok, idx, -1))
    dec, val = _lww_decide(first, last, delta.op, forward, m, ADD_EDGE)
    return jnp.where(dec, val, emask_l)


def _node_lww(nodes, delta: Delta, t_anchor, t_query):
    """Full-N node-mask LWW (the node mask is replicated on every
    shard — it is N-sized, negligible next to the slot scatter)."""
    n = nodes.shape[0]
    m = delta.capacity
    forward = t_query >= t_anchor
    t_lo = jnp.minimum(t_anchor, t_query)
    t_hi = jnp.maximum(t_anchor, t_query)
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    idx = jnp.arange(m, dtype=jnp.int32)
    nw = in_win & delta.is_node_op()
    firstn = jnp.full((n,), m, jnp.int32).at[delta.u].min(
        jnp.where(nw, idx, m))
    lastn = jnp.full((n,), -1, jnp.int32).at[delta.u].max(
        jnp.where(nw, idx, -1))
    dec_n, val_n = _lww_decide(firstn, lastn, delta.op, forward, m, 0)
    return jnp.where(dec_n, val_n, nodes)


def _two_phase_slots_local(nodes, eu_l, ev_l, emask_l, n_reg, delta,
                           t_anchor, tks, tls, vs, *, kind, measure, agg,
                           num_buckets):
    e_loc = emask_l.shape[0]
    slot0 = jax.lax.axis_index(AXIS) * e_loc
    reg_l = (slot0 + jnp.arange(e_loc, dtype=jnp.int32)) < n_reg

    def parts_at(emask_base, nodes_base, t_base, t, v):
        em = _slot_lww(emask_base, delta, t_base, t, slot0)
        nd = _node_lww(nodes_base, delta, t_base, t)
        p = _slot_parts(nd, em & reg_l, eu_l, ev_l, v, measure)
        return p, (em, nd)

    if kind == "point":
        def one(t, v):
            return parts_at(emask_l, nodes, t_anchor, t, v)[0]

        parts = jax.vmap(one)(tks, vs)                       # [B, 2]
        return _slot_finalize(jax.lax.psum(parts, AXIS), measure)

    if kind == "diff":
        # SG_tl from the anchor, then SG_tk from SG_tl — the same
        # nearer-snapshot reuse as the single-device diff kernel.
        def one(tk, tl, v):
            p_l, (em, nd) = parts_at(emask_l, nodes, t_anchor, tl, v)
            p_k, _ = parts_at(em, nd, tl, tk, v)
            return p_l, p_k

        p_l, p_k = jax.vmap(one)(tks, tls, vs)               # [B, 2] each
        a = _slot_finalize(jax.lax.psum(p_l, AXIS), measure)
        b = _slot_finalize(jax.lax.psum(p_k, AXIS), measure)
        return jnp.abs(a - b)

    # agg: one reconstruction per bucket (times past each query's t_l
    # are computed but masked by masked_aggregate, exactly as in
    # batch_edge_two_phase_agg).
    def one(tk, tl, v):
        ts = tk + jnp.arange(num_buckets, dtype=jnp.int32)
        return jax.lax.map(
            lambda t: parts_at(emask_l, nodes, t_anchor, t, v)[0], ts)

    parts = jax.vmap(one)(tks, tls, vs)                      # [B, nb, 2]
    vals = _slot_finalize(jax.lax.psum(parts, AXIS), measure)  # [B, nb]
    return jax.vmap(
        lambda row, tk, tl: masked_aggregate(row, tl - tk + 1,
                                             num_buckets, agg))(
        vals, tks, tls)


# ---------------------------------------------------------------------------
# Sharded group execution: slot-sharded incremental time sweeps (evolve)
# ---------------------------------------------------------------------------

_EVOLVE_SLOT_CACHE: dict = {}


def evolve_slots(mesh: Mesh, anchor: EdgeGraph, d_rec: Delta, d_net: Delta,
                 t_anchor, t_los, widths, vs, *, measure: str, scope: str,
                 stride: int, num_buckets: int):
    """One evolve (sweep) group as a slot-parallel program.

    The expensive half of a sweep is the one LWW reconstruction at each
    query's t_lo — so that is what shards: each device reconstructs only
    its slot block (O(E/D) scatter) and emits integer partials of the
    start state (per-node degree counts from local edges, local live-
    edge count; the replicated node mask contributes from shard 0 only,
    exactly the ``_slot_parts`` convention).  ONE psum of those integer
    partials rebuilds the exact start state on every device — the same
    exactness argument as ``two_phase_slots`` — and the cheap half (the
    per-sample net scatter + measure scan over the replicated ``d_net``)
    runs replicated, so every device holds the identical result and the
    outputs bit-match the single-device ``batch_evolve``.
    """
    key = (mesh, measure, scope, stride, num_buckets)
    fn = _EVOLVE_SLOT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(shard_map(
            functools.partial(_evolve_slots_local, measure=measure,
                              scope=scope, stride=stride,
                              num_buckets=num_buckets),
            mesh=mesh,
            in_specs=(P(), P(AXIS), P(AXIS), P(AXIS), P(), P(), P(), P(),
                      P(), P(), P()),
            out_specs=P()))
        _EVOLVE_SLOT_CACHE[key] = fn
    return fn(anchor.nodes, anchor.eu, anchor.ev, anchor.emask,
              anchor.n_edges_reg, d_rec, d_net, t_anchor, t_los, widths,
              vs)


def _evolve_slots_local(nodes, eu_l, ev_l, emask_l, n_reg, d_rec, d_net,
                        t_anchor, t_los, widths, vs, *, measure, scope,
                        stride, num_buckets):
    from repro.kernels.evolve_sweep.ops import sweep_nets, sweep_scan
    e_loc = emask_l.shape[0]
    n = nodes.shape[0]
    slot0 = jax.lax.axis_index(AXIS) * e_loc
    reg_l = (slot0 + jnp.arange(e_loc, dtype=jnp.int32)) < n_reg
    on_zero = jax.lax.axis_index(AXIS) == 0

    def one(t_lo, width, v):
        em = _slot_lww(emask_l, d_rec, t_anchor, t_lo, slot0)
        nd = _node_lww(nodes, d_rec, t_anchor, t_lo)
        live = (em & reg_l).astype(jnp.int32)
        deg_p = (jnp.zeros((n,), jnp.int32).at[eu_l].add(live)
                 .at[ev_l].add(live))
        ne_p = jnp.sum(live)
        nn_p = jnp.where(on_zero, jnp.sum(nd.astype(jnp.int32)),
                         jnp.int32(0))
        nodes_p = jnp.where(on_zero, nd.astype(jnp.int32),
                            jnp.zeros((n,), jnp.int32))
        deg0, nodes0, nn0, ne0 = jax.lax.psum(
            (deg_p, nodes_p, nn_p, ne_p), AXIS)
        nets = sweep_nets(d_net, t_lo, t_lo + (width - 1) * stride,
                          stride, num_buckets, n)
        return sweep_scan(measure, scope, v, deg0, nodes0, nn0, ne0, nets)

    return jax.vmap(one)(t_los, widths, vs)


# ---------------------------------------------------------------------------
# Row-parallel reconstruction
# ---------------------------------------------------------------------------


def _local_lww(nodes_l, adj_l, delta: Delta, t_anchor, t_query):
    """Shard-local last-writer-wins over the local row block."""
    n_loc = adj_l.shape[0]
    m = delta.capacity
    row0 = jax.lax.axis_index(AXIS) * n_loc
    forward = t_query >= t_anchor
    t_lo = jnp.minimum(t_anchor, t_query)
    t_hi = jnp.maximum(t_anchor, t_query)
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    idx = jnp.arange(m, dtype=jnp.int32)

    # Edge op (u, v) lands in local row u (col v) and local row v (col u).
    e_win = in_win & delta.is_edge_op()
    first = jnp.full((n_loc, adj_l.shape[1]), m, jnp.int32)
    last = jnp.full((n_loc, adj_l.shape[1]), -1, jnp.int32)
    for (r, c) in ((delta.u, delta.v), (delta.v, delta.u)):
        lr = r - row0
        ok = e_win & (lr >= 0) & (lr < n_loc)
        lr = jnp.clip(lr, 0, n_loc - 1)
        first = first.at[lr, c].min(jnp.where(ok, idx, m))
        last = last.at[lr, c].max(jnp.where(ok, idx, -1))
    dec, val = _lww_decide(first, last, delta.op, forward, m, ADD_EDGE)
    adj_l = jnp.where(dec, val, adj_l)

    n_win = in_win & delta.is_node_op()
    ln = delta.u - row0
    ok = n_win & (ln >= 0) & (ln < n_loc)
    ln = jnp.clip(ln, 0, n_loc - 1)
    firstn = jnp.full((n_loc,), m, jnp.int32).at[ln].min(
        jnp.where(ok, idx, m))
    lastn = jnp.full((n_loc,), -1, jnp.int32).at[ln].max(
        jnp.where(ok, idx, -1))
    dec_n, val_n = _lww_decide(firstn, lastn, delta.op, forward, m, 0)
    nodes_l = jnp.where(dec_n, val_n, nodes_l)
    return nodes_l, adj_l


def dist_reconstruct(mesh: Mesh, current: DenseGraph, delta: Delta,
                     t_anchor, t_query) -> DenseGraph:
    """SG_{t_query} with rows reconstructed in parallel, no comms."""
    fn = shard_map(
        _local_lww, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS, None), P(), P(), P()),
        out_specs=(P(AXIS), P(AXIS, None)))
    nodes, adj = jax.jit(fn)(current.nodes, current.adj, delta,
                             t_anchor, t_query)
    return DenseGraph(nodes=nodes, adj=adj)


# ---------------------------------------------------------------------------
# Global measures with psum combination
# ---------------------------------------------------------------------------


def dist_num_edges(mesh: Mesh, g: DenseGraph):
    def f(adj_l):
        local = jnp.sum(adj_l.astype(jnp.int32))
        return jax.lax.psum(local, AXIS)[None] // 2

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(AXIS, None),),
                             out_specs=P(AXIS)))(g.adj)[0]


def dist_degrees(mesh: Mesh, g: DenseGraph) -> jax.Array:
    def f(adj_l):
        return jnp.sum(adj_l, axis=1).astype(jnp.int32)

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(AXIS, None),),
                             out_specs=P(AXIS)))(g.adj)


def dist_degree_distribution(mesh: Mesh, g: DenseGraph, max_deg: int):
    @partial(shard_map, mesh=mesh,
             in_specs=(P(AXIS), P(AXIS, None)), out_specs=P(AXIS))
    def f(nodes_l, adj_l):
        deg = jnp.clip(jnp.sum(adj_l, axis=1).astype(jnp.int32), 0, max_deg)
        hist = jnp.zeros((max_deg + 1,), jnp.int32).at[deg].add(
            nodes_l.astype(jnp.int32))
        total = jax.lax.psum(hist, AXIS)
        # every shard holds the full histogram; emit only shard 0's copy
        keep = jax.lax.axis_index(AXIS) == 0
        return jnp.where(keep, total, 0)

    parts = jax.jit(f)(g.nodes, g.adj)
    return parts.reshape(len(mesh.devices), -1).sum(axis=0)


def dist_triangles(mesh: Mesh, g: DenseGraph):
    """trace(A³)/6 with row-sharded A: local A_l @ A_full (MXU), then
    elementwise with A_l, psum."""
    @partial(shard_map, mesh=mesh, in_specs=(P(AXIS, None),),
             out_specs=P(AXIS))
    def f(adj_l):
        a_l = adj_l.astype(jnp.float32)
        a_full = jax.lax.all_gather(a_l, AXIS, tiled=True)
        m = a_l @ a_full
        contrib = jnp.sum(m * a_l)
        return jax.lax.psum(contrib, AXIS)[None]

    return (jax.jit(f)(g.adj)[0] / 6.0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Batched historical query serving (hybrid plan, DESIGN.md §2.3)
# ---------------------------------------------------------------------------


def dist_batch_point_degree(mesh: Mesh, current: DenseGraph, delta: Delta,
                            vs: jax.Array, ts: jax.Array, t_cur):
    """Serve a batch of point node-centric degree queries:
    degree(vs[i]) at time ts[i].  Current-degree partials come from the
    owning shard (psum); the delta correction is computed redundantly on
    every shard (the log is replicated and the correction is O(B·M) int
    math)."""
    @partial(shard_map, mesh=mesh,
             in_specs=(P(AXIS, None), P(), P(), P(), P()),
             out_specs=P())
    def f(adj_l, delta, vs, ts, t_cur):
        n_loc = adj_l.shape[0]
        row0 = jax.lax.axis_index(AXIS) * n_loc
        lv = vs - row0
        ok = (lv >= 0) & (lv < n_loc)
        lv = jnp.clip(lv, 0, n_loc - 1)
        deg_local = jnp.where(ok, jnp.sum(adj_l[lv], axis=1), 0)
        deg_cur = jax.lax.psum(deg_local.astype(jnp.int32), AXIS)

        win = (delta.t[None, :] > ts[:, None]) & \
              (delta.t[None, :] <= t_cur) & delta.valid_mask()[None, :]
        touch = (delta.u[None, :] == vs[:, None]) | \
                (delta.v[None, :] == vs[:, None])
        sign = jnp.where(delta.op == ADD_EDGE, 1,
                         jnp.where(delta.is_edge_op(), -1, 0))[None, :]
        corr = jnp.sum(sign * (win & touch).astype(jnp.int32), axis=1)
        return deg_cur - corr

    return jax.jit(f)(current.adj, delta, vs, ts, t_cur)
