"""Distributed temporal-graph engine (DESIGN.md §2.4).

The paper names parallel snapshot reconstruction (à la Pregel/GBASE) as
future work; here it is.  Layout:

* adjacency rows + node mask sharded over a 1-D ``rows`` mesh axis
  (over *all* chips: ``pod × data × model`` collapse to one axis for the
  graph engine),
* the delta log replicated (it is tiny next to N²) — or time-sharded
  across pods for range scans,
* reconstruction is row-parallel (zero communication),
* global measures psum partial aggregates,
* batched query serving evaluates hybrid plans on the shard that owns
  the queried row and combines with psum.

All functions are shard_map programs over an existing mesh; they make no
assumption about the device count (tests run them on 8 host devices, the
production mesh on 512).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from repro.core.delta import ADD_EDGE, Delta
from repro.core.graph import DenseGraph
from repro.core.reconstruct import _lww_decide

AXIS = "rows"


def graph_mesh(devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    return Mesh(devices, (AXIS,))


def shard_graph(g: DenseGraph, mesh: Mesh) -> DenseGraph:
    """Place adjacency rows / node mask row-sharded on the mesh."""
    adj = jax.device_put(g.adj, NamedSharding(mesh, P(AXIS, None)))
    nodes = jax.device_put(g.nodes, NamedSharding(mesh, P(AXIS)))
    return DenseGraph(nodes=nodes, adj=adj)


def replicate(x, mesh: Mesh):
    return jax.device_put(x, NamedSharding(mesh, P()))


# ---------------------------------------------------------------------------
# Row-parallel reconstruction
# ---------------------------------------------------------------------------


def _local_lww(nodes_l, adj_l, delta: Delta, t_anchor, t_query):
    """Shard-local last-writer-wins over the local row block."""
    n_loc = adj_l.shape[0]
    m = delta.capacity
    row0 = jax.lax.axis_index(AXIS) * n_loc
    forward = t_query >= t_anchor
    t_lo = jnp.minimum(t_anchor, t_query)
    t_hi = jnp.maximum(t_anchor, t_query)
    in_win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()
    idx = jnp.arange(m, dtype=jnp.int32)

    # Edge op (u, v) lands in local row u (col v) and local row v (col u).
    e_win = in_win & delta.is_edge_op()
    first = jnp.full((n_loc, adj_l.shape[1]), m, jnp.int32)
    last = jnp.full((n_loc, adj_l.shape[1]), -1, jnp.int32)
    for (r, c) in ((delta.u, delta.v), (delta.v, delta.u)):
        lr = r - row0
        ok = e_win & (lr >= 0) & (lr < n_loc)
        lr = jnp.clip(lr, 0, n_loc - 1)
        first = first.at[lr, c].min(jnp.where(ok, idx, m))
        last = last.at[lr, c].max(jnp.where(ok, idx, -1))
    dec, val = _lww_decide(first, last, delta.op, forward, m, ADD_EDGE)
    adj_l = jnp.where(dec, val, adj_l)

    n_win = in_win & delta.is_node_op()
    ln = delta.u - row0
    ok = n_win & (ln >= 0) & (ln < n_loc)
    ln = jnp.clip(ln, 0, n_loc - 1)
    firstn = jnp.full((n_loc,), m, jnp.int32).at[ln].min(
        jnp.where(ok, idx, m))
    lastn = jnp.full((n_loc,), -1, jnp.int32).at[ln].max(
        jnp.where(ok, idx, -1))
    dec_n, val_n = _lww_decide(firstn, lastn, delta.op, forward, m, 0)
    nodes_l = jnp.where(dec_n, val_n, nodes_l)
    return nodes_l, adj_l


def dist_reconstruct(mesh: Mesh, current: DenseGraph, delta: Delta,
                     t_anchor, t_query) -> DenseGraph:
    """SG_{t_query} with rows reconstructed in parallel, no comms."""
    fn = shard_map(
        _local_lww, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS, None), P(), P(), P()),
        out_specs=(P(AXIS), P(AXIS, None)))
    nodes, adj = jax.jit(fn)(current.nodes, current.adj, delta,
                             t_anchor, t_query)
    return DenseGraph(nodes=nodes, adj=adj)


# ---------------------------------------------------------------------------
# Global measures with psum combination
# ---------------------------------------------------------------------------


def dist_num_edges(mesh: Mesh, g: DenseGraph):
    def f(adj_l):
        local = jnp.sum(adj_l.astype(jnp.int32))
        return jax.lax.psum(local, AXIS)[None] // 2

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(AXIS, None),),
                             out_specs=P(AXIS)))(g.adj)[0]


def dist_degrees(mesh: Mesh, g: DenseGraph) -> jax.Array:
    def f(adj_l):
        return jnp.sum(adj_l, axis=1).astype(jnp.int32)

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(AXIS, None),),
                             out_specs=P(AXIS)))(g.adj)


def dist_degree_distribution(mesh: Mesh, g: DenseGraph, max_deg: int):
    @partial(shard_map, mesh=mesh,
             in_specs=(P(AXIS), P(AXIS, None)), out_specs=P(AXIS))
    def f(nodes_l, adj_l):
        deg = jnp.clip(jnp.sum(adj_l, axis=1).astype(jnp.int32), 0, max_deg)
        hist = jnp.zeros((max_deg + 1,), jnp.int32).at[deg].add(
            nodes_l.astype(jnp.int32))
        total = jax.lax.psum(hist, AXIS)
        # every shard holds the full histogram; emit only shard 0's copy
        keep = jax.lax.axis_index(AXIS) == 0
        return jnp.where(keep, total, 0)

    parts = jax.jit(f)(g.nodes, g.adj)
    return parts.reshape(len(mesh.devices), -1).sum(axis=0)


def dist_triangles(mesh: Mesh, g: DenseGraph):
    """trace(A³)/6 with row-sharded A: local A_l @ A_full (MXU), then
    elementwise with A_l, psum."""
    @partial(shard_map, mesh=mesh, in_specs=(P(AXIS, None),),
             out_specs=P(AXIS))
    def f(adj_l):
        a_l = adj_l.astype(jnp.float32)
        a_full = jax.lax.all_gather(a_l, AXIS, tiled=True)
        m = a_l @ a_full
        contrib = jnp.sum(m * a_l)
        return jax.lax.psum(contrib, AXIS)[None]

    return (jax.jit(f)(g.adj)[0] / 6.0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Batched historical query serving (hybrid plan, DESIGN.md §2.3)
# ---------------------------------------------------------------------------


def dist_batch_point_degree(mesh: Mesh, current: DenseGraph, delta: Delta,
                            vs: jax.Array, ts: jax.Array, t_cur):
    """Serve a batch of point node-centric degree queries:
    degree(vs[i]) at time ts[i].  Current-degree partials come from the
    owning shard (psum); the delta correction is computed redundantly on
    every shard (the log is replicated and the correction is O(B·M) int
    math)."""
    @partial(shard_map, mesh=mesh,
             in_specs=(P(AXIS, None), P(), P(), P(), P()),
             out_specs=P())
    def f(adj_l, delta, vs, ts, t_cur):
        n_loc = adj_l.shape[0]
        row0 = jax.lax.axis_index(AXIS) * n_loc
        lv = vs - row0
        ok = (lv >= 0) & (lv < n_loc)
        lv = jnp.clip(lv, 0, n_loc - 1)
        deg_local = jnp.where(ok, jnp.sum(adj_l[lv], axis=1), 0)
        deg_cur = jax.lax.psum(deg_local.astype(jnp.int32), AXIS)

        win = (delta.t[None, :] > ts[:, None]) & \
              (delta.t[None, :] <= t_cur) & delta.valid_mask()[None, :]
        touch = (delta.u[None, :] == vs[:, None]) | \
                (delta.v[None, :] == vs[:, None])
        sign = jnp.where(delta.op == ADD_EDGE, 1,
                         jnp.where(delta.is_edge_op(), -1, 0))[None, :]
        corr = jnp.sum(sign * (win & touch).astype(jnp.int32), axis=1)
        return deg_cur - corr

    return jax.jit(f)(current.adj, delta, vs, ts, t_cur)
