"""Historical-query plans (paper §3.2, Table 2).

Query taxonomy: {point, range-differential, range-aggregate} ×
{node-centric, global}.  Plans:

* two-phase  — reconstruct snapshot(s), then measure (all query types)
* delta-only — range-differential node-centric, straight off the log
* hybrid     — point / range-aggregate node-centric: one measure on
  SG_tcur + a corrective pass over the window's ops

Each plan comes in an unindexed variant (mask the whole log) and an
indexed variant (temporal index → windowed slice; node-centric index →
per-node op list) — the four curves of the paper's Figure 1.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.delta import ADD_EDGE, REM_EDGE, Delta
from repro.core.graph import DenseGraph, EdgeGraph
from repro.core.index import NodeIndex, gather_node_ops, gather_window
from repro.core.partial import partial_reconstruct, seed_mask
from repro.core.queries import (EDGE_GLOBAL_MEASURES, EDGE_NODE_MEASURES,
                                GLOBAL_MEASURES, NODE_MEASURES)
from repro.core.reconstruct import (node_degree_series, reconstruct_dense,
                                    reconstruct_edge,
                                    reconstruct_sequential)

Aggregate = Literal["mean", "min", "max"]


_KINDS = ("point", "diff", "agg", "evolve")
_RANGE_KINDS = ("diff", "agg", "evolve")
_AGGS = ("mean", "min", "max")


@dataclasses.dataclass(frozen=True)
class Query:
    """A historical query (paper Table 1).

    This dataclass is THE validated construction path for every query
    in the system — the engine, the serving frontend, and the
    ``GraphSession`` facade all consume it as-is, so a malformed query
    fails here with a clear ``ValueError`` instead of deep inside a
    jitted kernel.  ``scope`` may be omitted: it is inferred from ``v``
    (node-centric iff a node is given).  Time-vs-watermark violations
    are intentionally NOT checked here (a Query is store-independent);
    they surface as ``WatermarkError`` — a ``ValueError`` subclass —
    at evaluation time.
    """

    kind: Literal["point", "diff", "agg", "evolve"] = "point"
    scope: Literal["node", "global"] | None = None
    measure: str = ""             # key into NODE_MEASURES / GLOBAL_MEASURES
    t_k: int = 0                  # point time, or range start
    t_l: int | None = None        # range end (diff/agg/evolve)
    v: int | None = None          # node (node-centric)
    agg: Aggregate = "mean"
    stride: int = 1               # evolve: sample every ``stride`` units

    def __post_init__(self):
        from repro.core.queries import edge_supported
        if self.kind not in _KINDS:
            raise ValueError(f"unknown query kind {self.kind!r} "
                             f"(one of {_KINDS})")
        if self.scope is None:
            object.__setattr__(self, "scope",
                               "node" if self.v is not None else "global")
        if self.scope not in ("node", "global"):
            raise ValueError(f"unknown scope {self.scope!r} "
                             "(node | global)")
        known = (NODE_MEASURES if self.scope == "node"
                 else GLOBAL_MEASURES)
        if self.measure not in known and not edge_supported(self.measure,
                                                            self.scope):
            raise ValueError(
                f"unknown {self.scope}-scope measure {self.measure!r} "
                f"(known: {', '.join(sorted(known))})")
        if self.scope == "node" and self.v is None:
            raise ValueError(f"node-scope query {self.measure!r} needs "
                             "v=<node id>")
        if self.kind in _RANGE_KINDS:
            if self.t_l is None:
                raise ValueError(f"{self.kind!r} query needs a time range"
                                 " — pass t_l (range end) as well as t_k")
            if self.t_l < self.t_k:
                raise ValueError(f"empty time range: t_l={self.t_l} < "
                                 f"t_k={self.t_k}")
        if self.kind == "evolve":
            if self.stride <= 0:
                raise ValueError(f"evolve stride must be >= 1, got "
                                 f"{self.stride}")
        elif self.stride != 1:
            raise ValueError(f"stride is an evolve parameter "
                             f"({self.kind!r} query got stride="
                             f"{self.stride})")
        if self.kind == "agg" and self.agg not in _AGGS:
            raise ValueError(f"unknown aggregate {self.agg!r} "
                             f"(one of {_AGGS})")


def _measure(g, q: Query):
    if isinstance(g, EdgeGraph):
        if q.scope == "node":
            return EDGE_NODE_MEASURES[q.measure](g, q.v)
        return EDGE_GLOBAL_MEASURES[q.measure](g)
    if q.scope == "node":
        return NODE_MEASURES[q.measure](g, q.v)
    return GLOBAL_MEASURES[q.measure](g)


def _aggregate(vals: jax.Array, agg: Aggregate):
    if agg == "mean":
        # Explicit sum/width (not jnp.mean, which lowers to a
        # reciprocal-multiply): keeps the scalar path bit-identical to
        # the engine's masked batched aggregation.
        v = vals.astype(jnp.float32)
        return jnp.sum(v) / v.shape[0]
    return jnp.min(vals) if agg == "min" else jnp.max(vals)


# ---------------------------------------------------------------------------
# Two-phase plan (paper §3.2.1) — reconstruct, then evaluate
# ---------------------------------------------------------------------------


def two_phase(current, delta: Delta, t_cur, q: Query, *,
              partial_rows: bool = False, sequential: bool = False,
              passes: int = 2):
    """General plan, all query types, both snapshot layouts.

    ``sequential=True`` replays the paper's Algorithm 2 op-by-op (the
    faithful baseline); otherwise the vectorized LWW reconstruction.
    ``partial_rows=True`` enables partial reconstruction (§3.3.1) for
    node-centric queries.  An ``EdgeGraph`` ``current`` runs the O(E)
    slot-scatter reconstruction instead of the dense N² one
    (sequential / partial variants are dense-layout concepts).
    """
    is_edge = isinstance(current, EdgeGraph)
    if is_edge and (sequential or partial_rows):
        raise ValueError("sequential / partial variants need the dense "
                         "layout")

    def recon_from(g, t_base, t):
        if is_edge:
            return reconstruct_edge(g, delta, t_base, t)
        if sequential:
            return reconstruct_sequential(g, delta, t_base, t)
        return reconstruct_dense(g, delta, t_base, t)

    def recon(t):
        if not is_edge and not sequential and partial_rows \
                and q.scope == "node":
            return partial_reconstruct(current, delta, t_cur, t,
                                       seed_mask(current.n_cap, q.v),
                                       passes=passes)
        return recon_from(current, t_cur, t)

    if q.kind == "point":
        return _measure(recon(q.t_k), q)

    if q.kind == "diff":
        # Reconstruct SG_tl backward from current, then SG_tk backward
        # from SG_tl — reusing the nearer snapshot exactly as the paper's
        # point-range plan does (§3.2.1), so the shared part of the delta
        # is applied once.
        g_l = recon(q.t_l)
        g_k = recon_from(g_l, q.t_l, q.t_k)
        return jnp.abs(_measure(g_l, q) - _measure(g_k, q))

    # aggregate: one snapshot per time unit in [t_k, t_l]
    ts = jnp.arange(q.t_k, q.t_l + 1, dtype=jnp.int32)
    vals = jax.lax.map(lambda t: _measure(recon(t), q), ts)
    return _aggregate(vals, q.agg)


# ---------------------------------------------------------------------------
# Delta-only plan (paper §3.2.2) — range-differential node-centric
# ---------------------------------------------------------------------------


@jax.jit
def delta_only_degree_diff(delta: Delta, v, t_k, t_l):
    """|Δdegree(v)| over [t_k, t_l] by counting add/rem edge ops that
    touch v — no snapshot access at all."""
    win = delta.window_mask(t_k, t_l) & delta.valid_mask()
    touch = win & ((delta.u == v) | (delta.v == v))
    sign = jnp.where(delta.op == ADD_EDGE, 1,
                     jnp.where(delta.op == REM_EDGE, -1, 0))
    return jnp.abs(jnp.sum(sign * touch.astype(jnp.int32)))


@partial(jax.jit, static_argnames=("cap",))
def delta_only_degree_diff_indexed(delta: Delta, index: NodeIndex, v,
                                   t_k, t_l, cap: int):
    """Same, via the node-centric index: O(deg_ops) gathers."""
    sub = gather_node_ops(delta, index, v, cap)
    return delta_only_degree_diff(sub, v, t_k, t_l)


# ---------------------------------------------------------------------------
# Hybrid plan (paper §3.2.3) — point / aggregate node-centric
# ---------------------------------------------------------------------------


@jax.jit
def hybrid_point_degree(current: DenseGraph, delta: Delta, v, t_k, t_cur):
    """degree(v) at t_k = degree on SG_tcur − net additions in (t_k, t_cur]."""
    deg_cur = current.degree(v)
    win = delta.window_mask(t_k, t_cur) & delta.valid_mask()
    touch = win & ((delta.u == v) | (delta.v == v))
    sign = jnp.where(delta.op == ADD_EDGE, 1,
                     jnp.where(delta.op == REM_EDGE, -1, 0))
    return deg_cur - jnp.sum(sign * touch.astype(jnp.int32))


@partial(jax.jit, static_argnames=("cap",))
def hybrid_point_degree_indexed(current: DenseGraph, delta: Delta,
                                index: NodeIndex, v, t_k, t_cur, cap: int):
    sub = gather_node_ops(delta, index, v, cap)
    return hybrid_point_degree(current, sub, v, t_k, t_cur)


def masked_aggregate(vals: jax.Array, width, num_buckets: int,
                     agg: Aggregate):
    """Aggregate the first ``width`` of ``num_buckets`` bucketed values
    (the tail is padding).  Shared by the scalar hybrid plan and the
    engine's batched executors: one definition keeps the bit-identity
    guarantee between the scalar and batched paths (exact f32 sum of
    integer values, true division by the width — not ``jnp.mean``,
    which lowers to a reciprocal-multiply)."""
    keep = jnp.arange(num_buckets, dtype=jnp.int32) < width
    if agg == "mean":
        return jnp.sum(jnp.where(keep, vals, 0).astype(jnp.float32)) / width
    big = jnp.asarray(1 << 30, vals.dtype)
    if agg == "min":
        return jnp.min(jnp.where(keep, vals, big))
    return jnp.max(jnp.where(keep, vals, -big))


@partial(jax.jit, static_argnames=("num_buckets", "agg"))
def hybrid_agg_degree(current: DenseGraph, delta: Delta, v, t_k, t_l,
                      num_buckets: int, agg: Aggregate = "mean"):
    """Aggregate of degree(v) over [t_k, t_l]: measure once on SG_tcur,
    reverse-cumulative correction per time unit (one delta pass)."""
    series = node_degree_series(current.degree(v), delta, v, t_k,
                                num_buckets)
    return masked_aggregate(series, t_l - t_k + 1, num_buckets, agg)


def hybrid_agg_degree_windowed(current: DenseGraph, delta: Delta, v, t_k,
                               t_l, t_cur, num_buckets: int,
                               window_cap: int, agg: Aggregate = "mean"):
    """Temporal-index variant: slice (t_k, t_cur] once, then correct.

    Note the correction window must extend to t_cur (the anchor measure
    is on the *current* snapshot), so the slice is (t_k, t_cur].
    """
    sub = gather_window(delta, t_k, t_cur, window_cap)
    return hybrid_agg_degree(current, sub, v, t_k, t_l, num_buckets, agg)


# ---------------------------------------------------------------------------
# Plan selection (paper Table 2)
# ---------------------------------------------------------------------------

APPLICABLE = {
    ("point", "node"): ("two_phase", "hybrid"),
    ("point", "global"): ("two_phase",),
    ("diff", "node"): ("two_phase", "delta_only", "hybrid"),
    ("diff", "global"): ("two_phase",),
    ("agg", "node"): ("two_phase", "hybrid"),
    ("agg", "global"): ("two_phase",),
    # evolve executes on its own incremental sweep kernel; the planner
    # only chooses the anchor, so two_phase is the (sole) cost model.
    ("evolve", "node"): ("two_phase",),
    ("evolve", "global"): ("two_phase",),
}


def applicable_plans(q: Query) -> tuple[str, ...]:
    return APPLICABLE[(q.kind, q.scope)]


def evaluate(current: DenseGraph, delta: Delta, t_cur, q: Query,
             index: NodeIndex | None = None, plan: str = "auto",
             node_cap: int = 1024, **kw):
    """Evaluate a query with the cheapest applicable plan (or a forced
    one).  Degree queries get the specialised delta-only/hybrid paths;
    everything else falls back to two-phase, as in Table 2.

    Thin wrapper kept for compatibility: plan *choice* is delegated to
    the engine's cost-based ``Planner`` (``core.engine``); the kernels
    below remain the single-query execution path.  Deprecated as an
    entry point — new code should go through ``repro.api.GraphSession``
    (or ``store.evaluate_many`` when holding a bare store).
    """
    plans = applicable_plans(q)
    if plan == "auto":
        import numpy as np
        from repro.core.engine import AnchorSelector, Planner
        # one host copy of the timestamps keeps plan costing free of
        # per-candidate blocking device syncs
        selector = AnchorSelector((), (), t_cur=t_cur, current=current,
                                  t_host=np.asarray(delta.t))
        planner = Planner(selector, n_cap=current.n_cap, index=index,
                          node_cap=node_cap)
        plan = planner.choose(q, delta, t_cur).plan
    if plan not in plans:
        raise ValueError(f"plan {plan} not applicable to {q}")

    if plan == "two_phase" or q.measure != "degree":
        return two_phase(current, delta, t_cur, q, **kw)
    if plan == "delta_only":
        if index is not None:
            return delta_only_degree_diff_indexed(delta, index, q.v, q.t_k,
                                                  q.t_l, node_cap)
        return delta_only_degree_diff(delta, q.v, q.t_k, q.t_l)
    # hybrid
    if q.kind == "point":
        if index is not None:
            return hybrid_point_degree_indexed(current, delta, index, q.v,
                                               q.t_k, t_cur, node_cap)
        return hybrid_point_degree(current, delta, q.v, q.t_k, t_cur)
    if q.kind == "diff":
        d_l = hybrid_point_degree(current, delta, q.v, q.t_l, t_cur)
        d_k = hybrid_point_degree(current, delta, q.v, q.t_k, t_cur)
        return jnp.abs(d_l - d_k)
    num_buckets = int(q.t_l - q.t_k + 1)
    return hybrid_agg_degree(current, delta, q.v, q.t_k, q.t_l,
                             num_buckets, q.agg)
