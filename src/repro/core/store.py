"""The temporal graph store: current snapshot + interval delta.

Implements the paper's storage model (§2.2) and update loop
(Algorithm 3): updates for the running time unit are accumulated in a
temporary delta, applied to the current snapshot at the unit boundary,
and appended to the interval delta.  The store is the host-side
component (ingest is inherently sequential/IO); everything it hands to
queries is device arrays.

Also owns: the persistent edge registry (slot ids, DESIGN.md §2.1), the
materialized-snapshot sequence + policy (§2.2), and the delta indexes
(§3.3.2).  The paper's invertibility discipline is enforced on ingest:
``remNode`` is preceded by ``remEdge`` for every live incident edge at
the same time unit (§2.1).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import queries as Q
from repro.core.delta import (ADD_EDGE, ADD_NODE, NOP, REM_EDGE, REM_NODE,
                              T_PAD, Delta, pow2_capacity)
from repro.core.engine import HistoricalQueryEngine
from repro.core.graph import (DenseGraph, EdgeGraph, dense_to_edge,
                              empty_edge)
from repro.core.index import NodeIndex, build_node_index_host
from repro.core.materialize import (MaterializationPolicy, MaterializedStore)
from repro.core.plans import Query, evaluate
from repro.core.reconstruct import reconstruct_dense, reconstruct_edge
from repro.core.segments import (Segment, SegmentedDeltaView,
                                 build_merged_nodes)


@dataclasses.dataclass
class Op:
    op: int
    u: int
    v: int
    t: int


class TemporalGraphStore:
    """Current snapshot SG_tcur + Δ[t0, tcur] (+ materialized snapshots)."""

    def __init__(self, n_cap: int, e_cap: int | None = None,
                 policy: MaterializationPolicy | None = None,
                 enforce_invertible: bool = True,
                 layout: str = "dense", segmented: bool = True,
                 segment_min_ops: int = 64,
                 segment_device_budget: int | None = None):
        """``layout="edge"`` keeps the current snapshot in edge-slot
        form only — O(E + N) state, no N² array anywhere in the store,
        which is what lets graphs past ~10⁴ nodes fit.  Queries then
        run through the engine's edge-layout kernels (measures without
        an edge implementation are unavailable).  Materialization
        policies need the dense layout (snapshots are stored dense).

        ``segmented=True`` (default) keeps the host log as a sequence
        of immutable ``Segment``s split at materialized-anchor and
        epoch-swap boundaries (``core.segments``): ingest appends to a
        single open tail, an epoch swap seals + converts only that
        tail, and queries materialize only the segments overlapping
        their (anchor, t) window — results stay bit-identical to the
        monolithic log.  ``segmented=False`` is the monolithic
        baseline (one device log rebuilt from the full history).
        ``segment_min_ops`` is the minimum tail size worth sealing
        (smaller tails ride along as a volatile snapshot segment);
        ``segment_device_budget`` caps the device bytes sealed
        segments may occupy — cold segments are spilled to host and
        reloaded on demand (None = keep everything resident)."""
        if layout not in ("dense", "edge"):
            raise ValueError(f"unknown layout {layout!r}")
        if layout == "edge" and policy is not None:
            raise ValueError("materialization policies need the dense "
                             "layout")
        self.layout = layout
        self.n_cap = n_cap
        self.e_cap = e_cap or 8 * n_cap
        self.t0 = 0
        self.t_cur = 0
        # Segmented host log: sealed immutable segments + ONE open tail
        # (python lists; O(1) append, converted lazily).  The _*_l
        # lists hold only the tail; sealed prefixes live in
        # self._segments as compact numpy arrays + device deltas.
        self.segmented = bool(segmented)
        self.segment_min_ops = int(segment_min_ops)
        self.segment_device_budget = segment_device_budget
        self._segments: list[Segment] = []
        # merged-delta tree over the sealed segments, keyed
        # (leaf index, level) — grown at each seal_tail, handed to
        # every delta_view (core.segments.build_merged_nodes)
        self._merged: dict[tuple[int, int], object] = {}
        self._t_sealed = 0            # time cut of the sealed prefix
        self._op_l: list[int] = []
        self._u_l: list[int] = []
        self._v_l: list[int] = []
        self._slot_l: list[int] = []
        self._t_l: list[int] = []
        # host mirrors of current state (for ingest-time legality checks)
        self._nodes = np.zeros((n_cap,), bool)
        self._adj_host: dict[tuple[int, int], bool] = {}
        # persistent edge-slot registry, maintained incrementally on
        # append: slot id -> canonical endpoints + current validity
        self._edge_slots: dict[tuple[int, int], int] = {}
        self._eu_l: list[int] = []
        self._ev_l: list[int] = []
        self._emask_l: list[bool] = []
        self._next_edge_slot = 0
        self.enforce_invertible = enforce_invertible
        # device-side current snapshot (layout-dependent)
        if layout == "edge":
            self.current: DenseGraph | EdgeGraph = empty_edge(n_cap, 1)
        else:
            self.current = DenseGraph(nodes=jnp.zeros((n_cap,), bool),
                                      adj=jnp.zeros((n_cap, n_cap), bool))
        self.materialized = MaterializedStore()
        self.policy = policy
        # Minimum device-log capacity (0 = tightest pow2).  Serving
        # layers pre-size it for expected growth so epoch swaps keep
        # every kernel's delta shape — and its compiled program —
        # stable (LiveGraphStore ``delta_cap_hint``).
        self.delta_cap_min = 0
        self._ops_since_mat = 0
        self._t_last_mat = 0
        self._delta_cache: Delta | None = None
        self._index_cache: NodeIndex | None = None
        self._engine_cache: HistoricalQueryEngine | None = None
        self._edge_cache: EdgeGraph | None = None
        # Host-array caches (alongside _delta_cache, invalidated on
        # append): _tail_cache holds the tail columns as numpy arrays,
        # _host_cache the sealed+tail concatenation the _op/_u/...
        # properties expose — property access used to re-convert the
        # whole python list per call, O(M) each.
        self._tail_cache: dict | None = None
        self._host_cache: dict | None = None
        self._view_cache: SegmentedDeltaView | None = None
        # Durability hooks (repro.persist.StorePersistence): attached
        # by persist.open_store — ingest/advance/seal then log to the
        # WAL and sealed segments are checkpointed to disk.  None (the
        # default) keeps the store fully process-resident.
        self.persist = None

    # ---------------------------------------------------------------- ingest

    def _canon(self, u: int, v: int) -> tuple[int, int]:
        return (u, v) if u <= v else (v, u)

    def _edge_slot(self, u: int, v: int) -> int:
        key = self._canon(u, v)
        if key not in self._edge_slots:
            self._edge_slots[key] = self._next_edge_slot
            self._next_edge_slot += 1
            # registry arrays grow in lockstep (incremental, O(1))
            self._eu_l.append(key[0])
            self._ev_l.append(key[1])
            self._emask_l.append(False)
        return self._edge_slots[key]

    def _append(self, op: int, u: int, v: int, t: int) -> None:
        if op in (ADD_NODE, REM_NODE):
            slot = u
        else:
            slot = self._edge_slot(u, v)
            self._emask_l[slot] = op == ADD_EDGE
        self._op_l.append(op)
        self._u_l.append(u)
        self._v_l.append(v)
        self._slot_l.append(slot)
        self._t_l.append(t)

    _COLS = ("op", "u", "v", "slot", "t")

    def _tail_host(self) -> dict:
        """The open tail as numpy columns (cached; the cached arrays
        are immutable snapshots — appends build new ones)."""
        if self._tail_cache is None:
            self._tail_cache = {
                "op": np.asarray(self._op_l, np.int32),
                "u": np.asarray(self._u_l, np.int32),
                "v": np.asarray(self._v_l, np.int32),
                "slot": np.asarray(self._slot_l, np.int32),
                "t": np.asarray(self._t_l, np.int32),
            }
        return self._tail_cache

    def _host(self, col: str) -> np.ndarray:
        """Full-log host column: sealed segments + tail, concatenated
        (cached — tests/stats/compat path; the serving path never
        needs the full concatenation)."""
        if self._host_cache is None:
            tail = self._tail_host()
            self._host_cache = {
                c: (np.concatenate(
                    [getattr(s, c) for s in self._segments] + [tail[c]])
                    if self._segments else tail[c])
                for c in self._COLS}
        return self._host_cache[col]

    @property
    def _op(self) -> np.ndarray:
        return self._host("op")

    @property
    def _u(self) -> np.ndarray:
        return self._host("u")

    @property
    def _v(self) -> np.ndarray:
        return self._host("v")

    @property
    def _slot(self) -> np.ndarray:
        return self._host("slot")

    @property
    def _t(self) -> np.ndarray:
        return self._host("t")

    @property
    def log_len(self) -> int:
        """Total ops across sealed segments + the open tail."""
        return sum(s.n_ops for s in self._segments) + len(self._op_l)

    def _invalidate(self) -> None:
        self._delta_cache = None
        self._index_cache = None
        self._engine_cache = None
        self._edge_cache = None
        self._tail_cache = None
        self._host_cache = None
        self._view_cache = None

    def _apply_host(self, op: int, u: int, v: int) -> bool:
        """Apply to host mirror; returns False if op is an illegal
        transition (already valid / already absent) — such ops are
        rejected so the log stays a genuine transition log (the paper's
        deltas record real transitions only)."""
        if op == ADD_NODE:
            if self._nodes[u]:
                return False
            self._nodes[u] = True
        elif op == REM_NODE:
            if not self._nodes[u]:
                return False
            self._nodes[u] = False
        elif op == ADD_EDGE:
            key = self._canon(u, v)
            if u == v or self._adj_host.get(key) or not (
                    self._nodes[u] and self._nodes[v]):
                return False
            self._adj_host[key] = True
        elif op == REM_EDGE:
            key = self._canon(u, v)
            if not self._adj_host.get(key):
                return False
            self._adj_host[key] = False
        return True

    def ingest(self, ops: Iterable[Op | tuple]) -> int:
        """Record a batch of update operations (paper Algorithm 3 lines
        1–6).  Ops must be time-ordered and strictly past ``t_cur`` —
        ``advance_to`` closed every unit up to ``t_cur``, and its
        half-open reconstruction window ``(t_cur, t_next]`` would never
        apply an op AT ``t_cur`` to the current snapshot (the host
        mirror would silently diverge from the device state; this is
        the same immutable-served-history contract ``LiveGraphStore``
        enforces at the swap boundary).  Returns #accepted.
        """
        accepted: list[Op] = []
        try:
            for o in ops:
                if not isinstance(o, Op):
                    o = Op(*o)
                if o.t <= self.t_cur:
                    raise ValueError(
                        f"op at t={o.t} is at or before "
                        f"t_cur={self.t_cur}; closed time units are "
                        "immutable (ops must be time-ordered and "
                        "strictly past t_cur)")
                if self._t_l and o.t < self._t_l[-1]:
                    # the log's t column must be non-decreasing: every
                    # binary search (temporal index, seal cuts, advance
                    # counting) assumes it — enforce, don't corrupt
                    raise ValueError(
                        f"ops must be time-ordered: got t={o.t} after "
                        f"t={self._t_l[-1]}")
                if o.op == REM_NODE and self.enforce_invertible:
                    # Paper §2.1: record remEdge for every live incident
                    # edge first, same time point, so the delta stays
                    # invertible.
                    for (a, b), live in list(self._adj_host.items()):
                        if live and (a == o.u or b == o.u):
                            if self._apply_host(REM_EDGE, a, b):
                                self._append(REM_EDGE, a, b, o.t)
                                accepted.append(Op(REM_EDGE, a, b, o.t))
                if self._apply_host(o.op, o.u, o.v):
                    self._append(o.op, o.u, o.v, o.t)
                    accepted.append(o)
        finally:
            # invalidate even when a mid-batch op raises: the accepted
            # prefix is already in the log and host mirror, and stale
            # caches would hide it from delta()/advance_to.  The WAL
            # records exactly what was appended (expansions included),
            # so replay re-accepts it verbatim — and a crash between
            # the mutation and the log write only loses ops this call
            # never acknowledged.
            if accepted:
                self._invalidate()
                if self.persist is not None:
                    self.persist.log_ops(accepted)
        return len(accepted)

    def advance_to(self, t_next: int) -> None:
        """Close the current time unit (Algorithm 3 lines 7–9): apply the
        temporary delta to SG_tcur, append it to the interval delta (the
        host log already holds it), and maybe materialize."""
        assert t_next >= self.t_cur
        if self.persist is not None:
            self.persist.log_advance(t_next)
        # Ops of the units being closed: only those in (t_cur, t_next]
        # count toward the materialization budget — future-dated ops
        # (t > t_next) will be counted by the advance that closes their
        # unit, not by every advance before it.  Sealed segments only
        # hold ops ≤ the last seal time ≤ t_cur, so the tail suffices.
        tail_t = self._tail_host()["t"]
        new_ops = int(np.searchsorted(tail_t, t_next, side="right")
                      - np.searchsorted(tail_t, self.t_cur, side="right"))
        if self.segmented:
            # only the segments overlapping (t_cur, t_next] — the open
            # tail plus at most a boundary segment — are materialized,
            # so closing a unit costs O(ops in it), not O(history)
            delta = self.delta_view().window_delta(self.t_cur, t_next)
        else:
            delta = self.delta()
        if self.layout == "edge":
            # rebase the anchor onto the latest (append-only) registry
            # first, so ops on newly registered slots land in range
            anchor = self.current.with_registry_of(self.edge_graph())
            self.current = reconstruct_edge(anchor, delta,
                                            self.t_cur, t_next)
        else:
            self.current = reconstruct_dense(self.current, delta,
                                             self.t_cur, t_next)
        self.t_cur = t_next
        self._engine_cache = None
        self._ops_since_mat += new_ops
        if self.policy is not None:
            last = (self.materialized.snapshots[-1]
                    if self.materialized.snapshots else None)
            if self.policy.should_materialize(
                    t_now=t_next, t_last=self._t_last_mat,
                    ops_since=self._ops_since_mat, current=self.current,
                    last=last):
                self.materialized.add(t_next, self.current)
                self._ops_since_mat = 0
                self._t_last_mat = t_next
                # materialized anchors are segment boundaries: the log
                # up to the anchor seals into an immutable segment
                self.seal_tail(t_next)

    # ------------------------------------------------------------- segments

    def seal_tail(self, t_seal: int | None = None, *,
                  force: bool = False) -> int:
        """Seal the open tail's ops with t ≤ ``t_seal`` (default
        ``t_cur``) into an immutable ``Segment`` — the epoch-swap /
        materialized-anchor boundary cut.  Tails smaller than
        ``segment_min_ops`` are left open unless ``force`` (a volatile
        snapshot segment represents them in ``delta_view``), so
        pathological swap cadences don't shatter the log into
        thousands of tiny segments.  Returns #ops sealed."""
        if not self.segmented:
            return 0
        t_seal = self.t_cur if t_seal is None else int(t_seal)
        if t_seal > self.t_cur:
            # sealing an open unit would let a later ingest (t > t_cur
            # but below the seal) slip BEHIND the sealed segment,
            # breaking the time-disjointness every binary search over
            # segments assumes
            raise ValueError(f"cannot seal at t={t_seal} past "
                             f"t_cur={self.t_cur}: the unit is open")
        if t_seal <= self._t_sealed:
            return 0
        tail = self._tail_host()
        k = int(np.searchsorted(tail["t"], t_seal, side="right"))
        if k == 0 or (k < self.segment_min_ops and not force):
            return 0
        self._segments.append(Segment(
            tail["op"][:k].copy(), tail["u"][:k].copy(),
            tail["v"][:k].copy(), tail["slot"][:k].copy(),
            tail["t"][:k].copy()))
        self._op_l = self._op_l[k:]
        self._u_l = self._u_l[k:]
        self._v_l = self._v_l[k:]
        self._slot_l = self._slot_l[k:]
        self._t_l = self._t_l[k:]
        self._t_sealed = t_seal
        # grow the merged-delta tree over the now-longer sealed
        # sequence: at most O(log S) new interior nodes per seal,
        # amortized O(ops · log S) total (LSM-style)
        build_merged_nodes(self._segments, self._merged)
        if self.persist is not None:
            # sealed-segment write hook: the immutable segment's compact
            # arrays go to disk once, and the cut is WAL-logged so a
            # policy-less recovery reproduces the same segmentation
            self.persist.on_seal(self, self._segments[-1],
                                 len(self._segments) - 1, t_seal, k, force)
        # log content is unchanged — only the host partitioning moved,
        # so the (content-addressed) delta/index/engine caches survive
        self._tail_cache = None
        self._host_cache = None
        self._view_cache = None
        return k

    def delta_view(self) -> SegmentedDeltaView:
        """The segmented Δ[t0, tcur]: sealed segments plus (when the
        tail is non-empty) one volatile segment snapshotting the tail.
        The snapshot is immutable — later appends build new tail
        arrays — so a frozen engine holding this view never observes
        subsequent ingest (the view's window cache is per-view for the
        same reason: a swap building the next view must not mutate
        cache state a frozen epoch is serving from)."""
        if not self.segmented:
            raise ValueError("monolithic store has no segment view "
                             "(segmented=False)")
        if self._view_cache is None:
            segs = list(self._segments)
            if self._op_l:
                tail = self._tail_host()
                segs.append(Segment(tail["op"], tail["u"], tail["v"],
                                    tail["slot"], tail["t"],
                                    sealed=False))
            self._view_cache = SegmentedDeltaView(
                segs, n_cap=self.n_cap, cap_min=self.delta_cap_min,
                merged=self._merged)
        return self._view_cache

    # ---------------------------------------------------------------- views

    def delta(self, capacity: int | None = None) -> Delta:
        """The full interval delta Δ[t0, tcur] as device arrays
        (cached) — the monolithic compatibility view; segment-aware
        consumers (the engine) go through ``delta_view`` and touch
        only window-overlapping segments."""
        if self._delta_cache is not None and capacity is None:
            return self._delta_cache
        n = self.log_len
        if capacity is not None and capacity < n:
            # mirror delta_from_numpy: fail loudly up front instead of
            # letting the negative pad crash deep inside np.full
            raise ValueError(f"capacity {capacity} < n_ops {n}")
        cap = capacity or pow2_capacity(n, max(1, self.delta_cap_min))
        if self.segmented:
            d = self.delta_view().full_delta(cap)
        else:
            pad = cap - n
            d = Delta(
                op=jnp.asarray(np.concatenate(
                    [self._op, np.full(pad, NOP, np.int32)])),
                u=jnp.asarray(np.concatenate(
                    [self._u, np.zeros(pad, np.int32)])),
                v=jnp.asarray(np.concatenate(
                    [self._v, np.zeros(pad, np.int32)])),
                slot=jnp.asarray(np.concatenate(
                    [self._slot, np.zeros(pad, np.int32)])),
                t=jnp.asarray(np.concatenate(
                    [self._t, np.full(pad, T_PAD, np.int32)])),
                n_ops=jnp.int32(n))
        if capacity is None:
            self._delta_cache = d
        return d

    def op_times_host(self) -> np.ndarray:
        """Sorted host copy of the log timestamps (they are sorted by
        construction — ingest is append-only time-ordered).  Planning
        code (anchor costing, workload materialization) binary-searches
        this instead of syncing ``delta().t`` off device."""
        return self._t

    def op_count_source(self):
        """The cheapest object answering "#ops between two times":
        the segment view (O(log S) per window, no full-log concat) for
        segmented stores, the cached host timestamp array otherwise.
        ``serving.policy`` costs anchor placements against this."""
        return self.delta_view() if self.segmented else self.op_times_host()

    def node_index(self) -> NodeIndex:
        if self._index_cache is None:
            self._index_cache = build_node_index_host(self.delta(),
                                                      self.n_cap)
        return self._index_cache

    def edge_graph(self) -> EdgeGraph:
        """The ingested state in edge-slot layout: the persistent slot
        registry (eu, ev — append-only, maintained incrementally) plus
        the host-mirror edge/node validity.  Cached; O(E) vectorized
        rebuild after an ingest (e_cap rounds to a power of two so jit
        shapes — and slot-shard divisibility — are stable)."""
        if self._edge_cache is not None:
            return self._edge_cache
        n = self._next_edge_slot
        e_cap = pow2_capacity(n)
        eu = np.zeros((e_cap,), np.int32)
        ev = np.zeros((e_cap,), np.int32)
        emask = np.zeros((e_cap,), bool)
        eu[:n] = self._eu_l
        ev[:n] = self._ev_l
        emask[:n] = self._emask_l
        self._edge_cache = EdgeGraph(
            nodes=jnp.asarray(self._nodes.copy()),
            eu=jnp.asarray(eu), ev=jnp.asarray(ev),
            emask=jnp.asarray(emask), n_edges_reg=jnp.int32(n))
        return self._edge_cache

    def current_edge_snapshot(self) -> EdgeGraph:
        """SG_tcur in edge-slot layout, guaranteed consistent with
        ``self.current`` (the engine's parity contract): derived from
        the dense current through the registry for dense-layout stores,
        the (registry-rebased) current itself for edge-layout ones."""
        reg = self.edge_graph()
        if isinstance(self.current, EdgeGraph):
            # rebase whenever slots were registered since the snapshot
            # was built — e_cap alone can stay put below the next pow2
            # boundary while the registration count (and eu/ev of the
            # new slots) moved on
            if (int(self.current.n_edges_reg) < self._next_edge_slot
                    or self.current.e_cap < reg.e_cap):
                return self.current.with_registry_of(reg)
            return self.current
        return dense_to_edge(self.current, reg)

    # ---------------------------------------------------------------- query

    def snapshot_at(self, t: int, *, use_materialized: bool = True,
                    selection: str = "ops",
                    windowed: bool = False) -> DenseGraph:
        """Reconstruct SG_t (anchored at the best materialized snapshot
        if available, else at SG_tcur — Theorem 1).  For application
        code prefer ``repro.api.GraphSession.snapshot_at``, which adds
        the serving watermark semantics; this remains the store-level
        primitive it routes to.

        ``windowed=True`` slices the delta to the anchor→t window
        through the temporal index first (capacity rounded to a power
        of two to bound recompiles).  This is what makes
        operation-based anchor selection pay off in the *vectorized*
        engine: the LWW scatter then does O(window) work instead of
        O(M) masked work (see EXPERIMENTS §Perf — for the sequential
        engine the paper's selection already pays off unmodified).

        Anchor choice (current snapshot competing with every
        materialized one) is delegated to the engine's
        ``AnchorSelector``.  Unwindowed calls route through the
        engine's per-anchor reconstruction LRU, so repeated snapshots
        at hot timestamps skip the delta replay
        (``engine.cache_hits``/``cache_misses`` count them).  An
        edge-layout store returns an ``EdgeGraph``.
        """
        delta = self.delta_view() if self.segmented else self.delta()
        anchor_id = -1
        if use_materialized and self.materialized.times:
            selector = self.engine().selector
            cand = selector.select(t, delta, method=selection)
            anchor_id = cand.anchor_id
            t_a, g_a = selector.get(anchor_id)
        else:
            t_a, g_a = self.t_cur, self.current
        if not windowed:
            return self.engine().reconstruct_cached(anchor_id, t,
                                                    layout=self.layout)
        if self.segmented:
            # segment selection IS the window slice: materialize only
            # the segments overlapping (anchor, t).  The single LWW
            # reconstruction masks exactly at the window bounds, so
            # fully-covered leaf runs may come from the merged tree.
            delta = delta.window_delta(min(t, t_a), max(t, t_a),
                                       merged=True)
        else:
            from repro.core.index import count_window_ops, gather_window
            n_win = int(count_window_ops(delta, min(t, t_a), max(t, t_a)))
            cap = pow2_capacity(n_win, 64)
            if cap < delta.capacity:
                delta = gather_window(delta, min(t, t_a), max(t, t_a), cap)
        if self.layout == "edge":
            return reconstruct_edge(self.current_edge_snapshot()
                                    if anchor_id == -1 else g_a,
                                    delta, t_a, t)
        return reconstruct_dense(g_a, delta, t_a, t)

    def engine(self, *, indexed: bool = False,
               node_cap: int = 1024, mesh=None) -> HistoricalQueryEngine:
        """The unified historical-query engine over the current store
        state (cached; invalidated by ingest/advance, by a change to
        the materialized-snapshot set, by a different ``node_cap`` or
        ``mesh``, or by asking for an index the cached engine lacks.
        An engine built with an index keeps it for later unindexed
        calls — the planner simply has more statistics available.

        ``mesh`` (a 1-D ``sharding.graph.graph_mesh``) makes the engine
        a multi-device serving engine: snapshot/delta arrays are placed
        on the mesh (replicated delta, row-sharded or replicated
        snapshot per group role) and big query groups run as one
        sharded program each (``core.distributed``).  ``mesh=None``
        means "don't care": a cached mesh-bound engine is reused (its
        device placements are expensive; sharded results are
        bit-identical anyway) — only a *different* mesh rebuilds."""
        e = self._engine_cache
        if (e is None or (indexed and e.index is None)
                or e.node_cap != node_cap
                or (mesh is not None and e.mesh != mesh)
                or e.selector.times != self.materialized.times):
            keep_index = indexed or (e is not None and e.index is not None)
            keep_mesh = mesh if mesh is not None else (
                e.mesh if e is not None else None)
            e = HistoricalQueryEngine.from_store(
                self, indexed=keep_index, node_cap=node_cap,
                mesh=keep_mesh)
            self._engine_cache = e
        return e

    def place_on_mesh(self, mesh) -> HistoricalQueryEngine:
        """Eagerly place the store's device state for multi-device
        serving: the interval delta replicated and the current snapshot
        both replicated (batch-axis groups) and row/slot-sharded
        (two-phase groups, per layout), so the first queries pay no
        placement transfers.  Returns the mesh-bound engine (also
        cached as ``engine()``)."""
        eng = self.engine(mesh=mesh)
        from repro.sharding.graph import (rows_divisible, single_device,
                                          slots_divisible)
        if not single_device(mesh):
            if eng.view is None:
                # segmented engines replicate per-group window deltas
                # lazily (the full log never materializes on device)
                eng._replicated(mesh, "delta", eng.delta)
            if eng.current is not None:
                eng._replicated(mesh, "current", eng.current)
                if rows_divisible(self.n_cap, mesh):
                    eng._row_sharded_anchor(mesh, -1)
            if eng.current_edge is not None:
                eng._replicated(mesh, "current_edge", eng.current_edge)
                if slots_divisible(eng.current_edge.e_cap, mesh):
                    eng._slot_sharded_anchor(mesh, -1)
        return eng

    def freeze_serving_state(self, *, mesh=None, indexed: bool = False,
                             node_cap: int = 1024) -> HistoricalQueryEngine:
        """Build the complete frozen serving view of the current store
        state — the epoch-swap hook for ``repro.serving``.

        Everything a query could touch is converted to device arrays
        *now*, off the serving critical path: the interval delta
        (pow2-padded device log), the registry-rebased edge snapshot
        (when slots were registered since the last freeze), the engine
        with its host-side planning copies, and — given a ``mesh`` —
        the eager multi-device placements of ``place_on_mesh``.  The
        returned engine is immutable with respect to later ``ingest``
        calls (its arrays are snapshots), so a serving layer can keep
        answering from it while the store absorbs the next epoch's
        writes and freezes again."""
        if self.segmented:
            # Seal the epoch's tail and convert ONLY it — the sealed
            # history is already device-resident from previous freezes
            # (successive epochs share those arrays by reference), so
            # the swap's conversion cost is O(ops since the last swap),
            # not O(total history).  The residency pass then spills
            # cold segments past the byte budget back to host.
            self.seal_tail(self.t_cur)
            self.delta_view().ensure_device(self.segment_device_budget)
        else:
            self.delta()                 # device conversion of the log
        if self.layout == "edge":
            # rebase the serving snapshot onto the grown registry once,
            # host-side, instead of per query
            self.current = self.current_edge_snapshot()
        eng = self.engine(indexed=indexed, node_cap=node_cap)
        if mesh is not None:
            eng = self.place_on_mesh(mesh)   # keeps the index, adds mesh
        return eng

    # ------------------------------------------------------------ durability

    def flush(self) -> None:
        """Checkpoint the durable state (no-op for a process-resident
        store): rotate the WAL behind a fresh manifest so recovery
        replays only what happened after this call.  The WAL itself is
        fsync'd per record — flush bounds recovery *time*, it is not
        needed for recovery *correctness*."""
        if self.persist is not None:
            self.persist.checkpoint(self)

    def close(self) -> None:
        """Flush and release the durability layer.  The store object
        stays queryable (its state is in memory); further mutations
        would no longer be logged, so treat it as read-only after."""
        if self.persist is not None:
            self.persist.checkpoint(self)
            self.persist.close()

    def query(self, q: Query, plan: str = "auto", indexed: bool = False,
              **kw):
        """Single-query compat shim (prefer ``repro.api.GraphSession``
        — one facade over store/engine/frontend — or ``evaluate_many``
        for anything batched)."""
        index = self.node_index() if indexed else None
        if plan == "auto":
            # the cached engine carries the host timestamp copy, so
            # auto plan choice costs numpy binary searches, not a
            # device transfer per query
            plan = self.engine().planner.choose(q, self.delta(),
                                                self.t_cur).plan
        # edge layout: evaluate against the registry-rebased snapshot —
        # self.current may predate slots registered by a later ingest,
        # whose ops would fall outside its (stale) slot range
        cur = (self.current_edge_snapshot() if self.layout == "edge"
               else self.current)
        return evaluate(cur, self.delta(), self.t_cur, q,
                        index=index, plan=plan, **kw)

    def evaluate_many(self, queries, plan: str = "auto", *,
                      indexed: bool = False, mesh=None,
                      layout: str | None = None, **kw):
        """Batched multi-query serving: route through the engine's
        grouped executor (one device program per (plan, anchor, layout)
        group; one *sharded* program per big group when ``mesh`` spans
        more than one device).  ``layout`` forces dense/edge execution
        ("auto"/None lets the planner's N²-vs-E cost term decide).
        Application code usually wants ``repro.api.GraphSession.
        query_many`` — same executor, plus watermark semantics, request
        coalescing, and the exact result cache."""
        return self.engine(indexed=indexed, mesh=mesh).evaluate_many(
            queries, plan, indexed=True if indexed else None,
            layout=layout, **kw)

    def evolve(self, measure: str, t_lo: int, t_hi: int, *,
               stride: int = 1, v: int | None = None,
               scope: str | None = None, mesh=None, **kw) -> np.ndarray:
        """Time-sweep query: ``measure`` at every sample time
        ``t_lo, t_lo + stride, ..., ≤ t_hi`` as ONE device program —
        reconstruct at ``t_lo`` once, then an incremental
        apply-bucket / measure ``lax.scan`` (``kernels.evolve_sweep``).
        Bit-identical to the corresponding independent point queries
        (tests/test_evolve.py) at a fraction of the cost: the shared
        anchor→t_lo window is applied once instead of once per sample.

        Measures outside the incremental set
        (``kernels.evolve_sweep.SWEEP_MEASURES``) fall back
        transparently to independent point queries — same results,
        none of the speedup.  ``repro.api.GraphSession.sweep`` is the
        serving-aware front door to this."""
        from repro.kernels.evolve_sweep import SWEEP_MEASURES
        scope = scope or ("node" if v is not None else "global")
        if measure in SWEEP_MEASURES:
            q = Query("evolve", scope, measure, t_k=int(t_lo),
                      t_l=int(t_hi), v=v, stride=int(stride))
            return self.evaluate_many([q], mesh=mesh, **kw)[0]
        ts = range(int(t_lo), int(t_hi) + 1, int(stride))
        qs = [Query("point", scope, measure, t_k=t, v=v) for t in ts]
        return np.asarray(self.evaluate_many(qs, mesh=mesh, **kw))

    # stats used by benchmarks (paper Table 3)
    def stats(self) -> dict:
        return {
            "inserted_nodes": int(np.sum(self._op == ADD_NODE)),
            "removed_nodes": int(np.sum(self._op == REM_NODE)),
            "inserted_edges": int(np.sum(self._op == ADD_EDGE)),
            "removed_edges": int(np.sum(self._op == REM_EDGE)),
            "total_ops": int(len(self._op)),
            "t_cur": self.t_cur,
            "live_nodes": int(np.sum(self._nodes)),
            "live_edges": int(sum(self._adj_host.values())),
        }
