"""Delta indexes (paper §3.3.2): temporal and node-centric.

*Temporal index* — the delta is append-only and time-sorted, so the
index is binary search over the ``t`` column (``searchsorted``): a query
window [t_k, t_l] maps to a contiguous op range.  Plans then touch only
``O(window)`` ops (via ``dynamic_slice`` with a static capacity) instead
of masking the whole log.

*Node-centric index* — CSR over nodes: for every node, the sorted list
of op indices that touch it (edge ops are listed under both endpoints).
Built with one argsort; lookups are gathers.  Powers delta-only/hybrid
plans on single nodes and partial reconstruction (paper §3.3.1).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delta import Delta, NOP, T_PAD


# ---------------------------------------------------------------------------
# Temporal index
# ---------------------------------------------------------------------------


def temporal_range(delta: Delta, t_lo, t_hi):
    """Op-index range [i0, i1) of ops with t in (t_lo, t_hi].

    O(log M) binary search — the temporal index. Padding entries sort to
    the end (t == T_PAD).
    """
    i0 = jnp.searchsorted(delta.t, t_lo, side="right")
    i1 = jnp.searchsorted(delta.t, t_hi, side="right")
    return i0.astype(jnp.int32), i1.astype(jnp.int32)


@partial(jax.jit, static_argnames=("window_cap",))
def gather_window(delta: Delta, t_lo, t_hi, window_cap: int) -> Delta:
    """Materialize the ops of (t_lo, t_hi] into a Delta of static
    capacity ``window_cap`` via the temporal index (dynamic_slice).

    Ops beyond ``window_cap`` are dropped — callers size the capacity
    from host-side knowledge (store tracks ops/time-unit).
    """
    i0, i1 = temporal_range(delta, t_lo, t_hi)
    n = jnp.minimum(i1 - i0, window_cap)
    # dynamic_slice clamps an out-of-range start (i0 + window_cap past
    # the capacity) back to capacity - window_cap, which would silently
    # shift the slice onto ops BEFORE the window while dropping in-window
    # ops — exactly the case for suffix windows anchored at the current
    # snapshot.  Slice from the clamped start and roll the in-window ops
    # to the front, preserving the compaction contract (valid_mask is
    # positional).
    start = jnp.clip(i0, 0, max(delta.capacity - window_cap, 0))

    def slice1(x, fill):
        y = jax.lax.dynamic_slice_in_dim(x, start, window_cap)
        y = jnp.roll(y, start - i0)
        keep = jnp.arange(window_cap, dtype=jnp.int32) < n
        return jnp.where(keep, y, fill)

    return Delta(op=slice1(delta.op, NOP), u=slice1(delta.u, 0),
                 v=slice1(delta.v, 0), slot=slice1(delta.slot, 0),
                 t=slice1(delta.t, T_PAD), n_ops=n)


def count_window_ops(delta: Delta, t_lo, t_hi):
    """#ops in (t_lo, t_hi] — the operation-based selection metric
    (paper §2.2) at O(log M)."""
    i0, i1 = temporal_range(delta, t_lo, t_hi)
    return i1 - i0


# ---------------------------------------------------------------------------
# Node-centric index (CSR)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NodeIndex:
    """CSR: ops touching each node. Edge ops appear twice (once per
    endpoint); node ops once."""

    row_ptr: jax.Array   # i32[N + 1]
    op_idx: jax.Array    # i32[2M] — delta op indices, grouped by node,
                         # time-ordered within a node (stable sort)
    n_cap: int = dataclasses.field(metadata=dict(static=True))

    def ops_of(self, v, cap: int):
        """Up to ``cap`` op indices touching node v (padded with -1).

        Explicit gather (not dynamic_slice — slice-start clamping near
        the array end would silently shift the window)."""
        start = self.row_ptr[v]
        count = self.row_ptr[v + 1] - start
        ids = start + jnp.arange(cap, dtype=jnp.int32)
        safe = jnp.clip(ids, 0, self.op_idx.shape[0] - 1)
        keep = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(count, cap)
        return jnp.where(keep, self.op_idx[safe], -1), \
            jnp.minimum(count, cap)


def build_node_index(delta: Delta, n_cap: int) -> NodeIndex:
    """Build the CSR node-centric index with one stable argsort.

    Pure-JAX build (shardable); the store calls this after appends.
    Padding ops are parked under a virtual row ``n_cap`` and truncated.
    """
    m = delta.capacity
    valid = delta.valid_mask() & (delta.op != NOP)
    is_edge = delta.is_edge_op()
    # Two entries per op, *interleaved* (u0, v0, u1, v1, ...) so that a
    # stable sort by node keeps each node's op list in time order.
    key_u = jnp.where(valid, delta.u, n_cap)
    key_v = jnp.where(valid & is_edge, delta.v, n_cap)
    keys = jnp.stack([key_u, key_v], axis=1).reshape(-1)   # i32[2M]
    idxs = jnp.repeat(jnp.arange(m, dtype=jnp.int32), 2)
    order = jnp.argsort(keys, stable=True)
    sorted_keys = keys[order]
    op_idx = idxs[order]
    counts = jnp.zeros((n_cap + 1,), jnp.int32).at[
        jnp.clip(sorted_keys, 0, n_cap)].add(1)
    row_ptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts[:n_cap])])
    return NodeIndex(row_ptr=row_ptr, op_idx=op_idx, n_cap=n_cap)


def build_node_index_host(delta: Delta, n_cap: int) -> NodeIndex:
    """Numpy build (used by the host-side store for large logs)."""
    op = np.asarray(delta.op)
    m = op.shape[0]
    valid = (np.arange(m) < int(delta.n_ops)) & (op != NOP)
    is_edge = (op == 2) | (op == 3)
    u = np.asarray(delta.u)
    v = np.asarray(delta.v)
    keys = np.stack([np.where(valid, u, n_cap),
                     np.where(valid & is_edge, v, n_cap)],
                    axis=1).reshape(-1)
    idxs = np.repeat(np.arange(m, dtype=np.int32), 2)
    order = np.argsort(keys, kind="stable")
    sorted_keys = keys[order]
    op_idx = idxs[order].astype(np.int32)
    counts = np.bincount(np.clip(sorted_keys, 0, n_cap),
                         minlength=n_cap + 1)
    row_ptr = np.concatenate([[0], np.cumsum(counts[:n_cap])]).astype(
        np.int32)
    return NodeIndex(row_ptr=jnp.asarray(row_ptr), op_idx=jnp.asarray(op_idx),
                     n_cap=n_cap)


@partial(jax.jit, static_argnames=("cap",))
def gather_node_ops(delta: Delta, index: NodeIndex, v, cap: int) -> Delta:
    """Delta restricted to ops touching node v, via the node index.

    O(deg_ops) gathers instead of an O(M) scan — this is what makes the
    ``-index`` plan variants of the paper's Figure 1 fast.
    """
    ids, n = index.ops_of(v, cap)
    safe = jnp.clip(ids, 0)
    good = ids >= 0

    def g(x, fill):
        return jnp.where(good, x[safe], fill)

    return Delta(op=g(delta.op, NOP), u=g(delta.u, 0), v=g(delta.v, 0),
                 slot=g(delta.slot, 0), t=g(delta.t, T_PAD), n_ops=n)
