"""Graph snapshots (paper Definition 1) in two TPU-native layouts.

The paper stores snapshots in Neo4j (pointer adjacency).  On TPU we use
dense arrays instead (DESIGN.md §2.1):

* ``DenseGraph`` — node-validity mask ``bool[N]`` + adjacency bitmask
  ``bool[N, N]``.  Global queries become MXU matmuls (BFS = boolean
  frontier products, triangles = trace(A³), PageRank = power iteration).
  Right layout up to a few 10⁴ nodes (the paper's own scale is 5,063).

* ``EdgeGraph`` — persistent edge registry ``(eu, ev)[E]`` + validity
  masks.  Reconstruction scatters over 1-D edge slots; measures are
  segment reductions.  Right layout for large sparse graphs and for the
  row/slot-sharded distributed engine.

Both are immutable pytrees; "applying" a delta produces a new snapshot.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseGraph:
    """SG_t as node mask + dense symmetric adjacency."""

    nodes: jax.Array  # bool[N]
    adj: jax.Array    # bool[N, N], symmetric, zero diagonal

    @property
    def n_cap(self) -> int:
        return self.nodes.shape[0]

    def num_nodes(self) -> jax.Array:
        return jnp.sum(self.nodes.astype(jnp.int32))

    def num_edges(self) -> jax.Array:
        return jnp.sum(self.adj.astype(jnp.int32)) // 2

    def degrees(self) -> jax.Array:
        """Degree of every node (0 for invalid nodes)."""
        return jnp.sum(self.adj, axis=1).astype(jnp.int32)

    def degree(self, v) -> jax.Array:
        return jnp.sum(self.adj[v]).astype(jnp.int32)

    def neighbors_mask(self, v) -> jax.Array:
        return self.adj[v]

    def induced(self, node_mask: jax.Array) -> "DenseGraph":
        m = node_mask & self.nodes
        return DenseGraph(nodes=m, adj=self.adj & m[:, None] & m[None, :])

    def validate(self) -> jax.Array:
        """True iff structurally consistent (edges only between valid
        nodes, symmetric, zero diagonal)."""
        ok_sym = jnp.all(self.adj == self.adj.T)
        ok_diag = ~jnp.any(jnp.diagonal(self.adj))
        live = self.nodes[:, None] & self.nodes[None, :]
        ok_live = ~jnp.any(self.adj & ~live)
        return ok_sym & ok_diag & ok_live


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeGraph:
    """SG_t as a persistent edge registry + validity masks.

    ``eu/ev`` are fixed once an edge slot is registered (host side); only
    the masks evolve.  Slots past ``n_edges_reg`` are unregistered.
    """

    nodes: jax.Array        # bool[N]
    eu: jax.Array           # i32[E] — endpoint 1 per registered edge slot
    ev: jax.Array           # i32[E] — endpoint 2
    emask: jax.Array        # bool[E] — edge validity
    n_edges_reg: jax.Array  # i32[] — number of registered slots

    @property
    def n_cap(self) -> int:
        return self.nodes.shape[0]

    @property
    def e_cap(self) -> int:
        return self.eu.shape[0]

    def reg_mask(self) -> jax.Array:
        return jnp.arange(self.e_cap, dtype=jnp.int32) < self.n_edges_reg

    def live_edges(self) -> jax.Array:
        return self.emask & self.reg_mask()

    def num_nodes(self) -> jax.Array:
        return jnp.sum(self.nodes.astype(jnp.int32))

    def num_edges(self) -> jax.Array:
        return jnp.sum(self.live_edges().astype(jnp.int32))

    def degrees(self) -> jax.Array:
        """Degree of every node — a segment-sum over edge endpoints
        (O(E + N), the edge-layout replacement for the dense row sum)."""
        live = self.live_edges()
        ones = live.astype(jnp.int32)
        deg = jnp.zeros((self.n_cap,), jnp.int32)
        deg = deg.at[self.eu].add(ones)
        deg = deg.at[self.ev].add(ones)
        return deg

    def degree(self, v) -> jax.Array:
        live = self.live_edges()
        touch = ((self.eu == v) | (self.ev == v)) & live
        return jnp.sum(touch.astype(jnp.int32))

    def to_dense(self) -> DenseGraph:
        adj = jnp.zeros((self.n_cap, self.n_cap), bool)
        live = self.live_edges()
        adj = adj.at[self.eu, self.ev].max(live)
        adj = adj.at[self.ev, self.eu].max(live)
        return DenseGraph(nodes=self.nodes, adj=adj)

    def with_registry_of(self, other: "EdgeGraph") -> "EdgeGraph":
        """This snapshot's state re-expressed over ``other``'s (equal
        or larger, append-only-grown) slot registry — host-side helper
        for registry growth.  Slots registered after this snapshot's
        time keep emask=False, which is exactly their state then."""
        e = other.e_cap
        emask = jnp.zeros((e,), bool).at[:self.e_cap].set(self.emask)
        return EdgeGraph(nodes=self.nodes, eu=other.eu, ev=other.ev,
                         emask=emask, n_edges_reg=other.n_edges_reg)


def empty_dense(n_cap: int) -> DenseGraph:
    return DenseGraph(nodes=jnp.zeros((n_cap,), bool),
                      adj=jnp.zeros((n_cap, n_cap), bool))


def empty_edge(n_cap: int, e_cap: int) -> EdgeGraph:
    return EdgeGraph(nodes=jnp.zeros((n_cap,), bool),
                     eu=jnp.zeros((e_cap,), jnp.int32),
                     ev=jnp.zeros((e_cap,), jnp.int32),
                     emask=jnp.zeros((e_cap,), bool),
                     n_edges_reg=jnp.int32(0))


def dense_to_edge(g: DenseGraph, registry: EdgeGraph) -> EdgeGraph:
    """Re-express a dense snapshot in edge-slot layout over an existing
    slot ``registry`` (the store's persistent ``(eu, ev)`` arrays).

    ``emask[s] = adj[eu[s], ev[s]]`` for registered slots — slots whose
    edge did not exist at the snapshot's time simply come out False, so
    any registry that is a superset of the snapshot's edges (the
    current registry always is, slots are append-only) converts any
    historical snapshot exactly.  O(E) gathers, no N² traffic beyond
    the E adjacency lookups.
    """
    live = (jnp.arange(registry.e_cap, dtype=jnp.int32)
            < registry.n_edges_reg)
    emask = g.adj[registry.eu, registry.ev] & live
    return EdgeGraph(nodes=g.nodes, eu=registry.eu, ev=registry.ev,
                     emask=emask, n_edges_reg=registry.n_edges_reg)


def edge_to_dense(g: EdgeGraph) -> DenseGraph:
    """Inverse of ``dense_to_edge`` (alias of ``EdgeGraph.to_dense``)."""
    return g.to_dense()


def dense_from_numpy(nodes: np.ndarray, edges: list[tuple[int, int]],
                     n_cap: int | None = None) -> DenseGraph:
    nodes = np.asarray(nodes, bool)
    n = n_cap or nodes.shape[0]
    mask = np.zeros((n,), bool)
    mask[:nodes.shape[0]] = nodes
    adj = np.zeros((n, n), bool)
    for (a, b) in edges:
        adj[a, b] = adj[b, a] = True
    np.fill_diagonal(adj, False)
    return DenseGraph(nodes=jnp.asarray(mask), adj=jnp.asarray(adj))
