"""Partial snapshot reconstruction (paper §3.3.1).

Node-centric queries touch a subgraph G' = (V', E'); instead of
reconstructing all of SG_t we reconstruct only the rows of V'.  The
paper notes that *multiple passes* over the delta may be needed: ops in
the window can attach new neighbors whose own edges then matter (e.g.
for induced-subgraph measures).  We implement the closure as a bounded
fixpoint over "nodes touched by ops touching the current set".
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.delta import Delta
from repro.core.graph import DenseGraph
from repro.core.reconstruct import reconstruct_dense


def seed_mask(n_cap: int, v) -> jax.Array:
    """Single-node seed set for a node-centric query — the V' of the
    paper's partial reconstruction.  Shared by ``plans.two_phase`` and
    the engine's batched executor so both build bit-identical seeds."""
    return jnp.zeros((n_cap,), bool).at[v].set(True)


@partial(jax.jit, static_argnames=("passes",))
def closure_mask(current: DenseGraph, delta: Delta, seed_mask: jax.Array,
                 t_lo, t_hi, passes: int = 2) -> jax.Array:
    """Expand a seed node set to every node whose state can influence the
    queried subgraph: current neighbors plus endpoints of window ops that
    touch the set.  ``passes`` bounds the paper's multi-pass loop; one
    pass suffices for degree, two for induced-subgraph measures.
    """
    win = delta.window_mask(t_lo, t_hi) & delta.valid_mask()

    def one_pass(_, mask):
        # neighbors in the current snapshot
        nbr = (mask.astype(jnp.float32) @ current.adj.astype(jnp.float32)) > 0
        # endpoints of ops touching the set inside the window
        touch = win & (mask[delta.u] | mask[delta.v])
        scat = jnp.zeros_like(mask).at[delta.u].max(touch)
        scat = scat.at[delta.v].max(touch)
        return mask | nbr | scat

    return jax.lax.fori_loop(0, passes, one_pass, seed_mask)


@partial(jax.jit, static_argnames=("passes",))
def partial_reconstruct(current: DenseGraph, delta: Delta, t_cur, t_query,
                        seed_mask: jax.Array, passes: int = 2) -> DenseGraph:
    """Reconstruct SG_{t_query} restricted to the closure of
    ``seed_mask``.  The returned snapshot is only meaningful on the
    closure (other rows keep current values) — exactly the paper's
    contract: "it suffices to reconstruct the corresponding snapshots of
    the subgraph G'"."""
    t_lo = jnp.minimum(t_cur, t_query)
    t_hi = jnp.maximum(t_cur, t_query)
    mask = closure_mask(current, delta, seed_mask, t_lo, t_hi, passes=passes)
    g = reconstruct_dense(current, delta, t_cur, t_query,
                          row_mask=mask, restrict_rows=True)
    # Zero out rows outside the closure so accidental reads are loud.
    adj = g.adj & mask[:, None] & mask[None, :]
    nodes = g.nodes & mask
    return DenseGraph(nodes=nodes, adj=adj)
