"""Unified historical-query engine: anchor planner + batched executor.

This module centralizes the choice logic that used to be spread across
``store.snapshot_at`` (inline anchor costing), ``plans.evaluate``
(hard-coded auto plan rule) and ``partial.py`` (caller-built seed
masks), mirroring how DeltaGraph centralizes snapshot-retrieval
planning.  Components map to the paper as follows:

* ``AnchorSelector`` — §2.2 (materialized snapshots + Theorem 1): the
  anchor candidates are SG_tcur plus every materialized snapshot,
  costed either by time distance or by #ops in the connecting delta
  window (``count_window_ops``, O(log M) via the temporal index).

* ``Planner`` — §3.2 (Table 2 plans) × §3.3 (partial reconstruction,
  delta indexes): picks {two-phase, delta-only, hybrid} and the
  {indexed, windowed, partial} variant per query from delta/index
  statistics, producing an explicit ``PlanChoice``.

* ``evaluate_many`` — the batched multi-query executor (beyond-paper;
  the successor system "Storing and Analyzing Historical Graph Data at
  Scale" batches multi-snapshot retrieval the same way): B queries are
  grouped by (plan choice, anchor), their times/nodes padded into
  device arrays, and each group runs as ONE ``vmap``'d reconstruction +
  measurement program — one LWW scatter pass amortized over all the
  queries sharing an anchor window — instead of B separate host-side
  dispatches.

The executor reuses the exact kernels from ``plans.py`` under ``vmap``,
so batched results bit-match the single-query path (integer measures
are exact; see tests/test_engine.py).  ``core/distributed.py`` will
shard these groups next: the (anchor, plan) group is precisely the unit
that is device-parallel.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delta import Delta, pow2_capacity as _pow2
from repro.core.graph import DenseGraph, EdgeGraph, dense_to_edge
from repro.core.index import (NodeIndex, count_window_ops, gather_node_ops,
                              gather_window)
from repro.core.partial import partial_reconstruct, seed_mask
from repro.core.plans import (Query, applicable_plans,
                              delta_only_degree_diff, hybrid_point_degree,
                              masked_aggregate)
from repro.core.queries import (EDGE_GLOBAL_MEASURES, EDGE_NODE_MEASURES,
                                GLOBAL_MEASURES, NODE_MEASURES,
                                edge_supported)
from repro.core.reconstruct import (degree_series, reconstruct_dense,
                                    reconstruct_edge)
# window_ops_count: #ops with t in (t_lo, t_hi] on a host timestamp
# copy or a SegmentedDeltaView — keeps the planning loop free of
# device round-trips (costing B queries is binary searches, not 2B
# syncs); one definition shared with serving.policy.
from repro.core.segments import (SegmentedDeltaView,
                                 window_ops_count as _window_ops_host)
from repro.obs import clock as _clock
from repro.obs.metrics import COUNT_BUCKETS, default_registry
from repro.obs.trace import trace_span


class WatermarkError(ValueError, RuntimeError):
    """A query's time lies beyond the engine's serving watermark
    ``t_served``: ops at that time may still sit in a pending ingest
    buffer, so the frozen state cannot answer it exactly.  Raised by
    watermarked engines (``repro.serving``); callers choose between
    surfacing it and blocking on an epoch swap.  Subclasses
    ``ValueError`` (a t-past-watermark query is an invalid argument at
    this instant, and the validated-``Query`` API contract promises
    ``ValueError`` for every malformed request) and keeps the historic
    ``RuntimeError`` base for existing handlers."""




# ---------------------------------------------------------------------------
# Anchor selection (paper §2.2, Theorem 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnchorCandidate:
    """One reconstruction anchor: the current snapshot (id == -1) or a
    materialized snapshot (id == index into the materialized store)."""

    anchor_id: int
    t: int
    cost: int


class AnchorSelector:
    """Picks the cheapest anchor snapshot for reconstructing SG_t.

    Candidates are SG_tcur (when given) plus every materialized
    snapshot; the "current snapshot competes with the materialized
    ones" rule that used to be inlined in ``store.snapshot_at`` lives
    here now.  ``method='ops'`` prices a candidate by #ops in the
    window between it and the query time (operation-based selection,
    exact cost proxy, O(log M) each via the temporal index);
    ``'time'`` by |t_candidate - t_query| (the paper's cheap variant,
    wrong under bursty logs).
    """

    def __init__(self, times: Sequence[int], snapshots: Sequence[DenseGraph],
                 *, t_cur: int | None = None,
                 current: DenseGraph | None = None,
                 t_host=None):
        assert len(times) == len(snapshots)
        self.times = [int(t) for t in times]
        self.snapshots = list(snapshots)
        self.t_cur = t_cur
        self.current = current
        # host copy of delta.t — or a SegmentedDeltaView — for
        # sync-free window costing (see _window_ops_host)
        self.t_host = t_host

    def candidates(self, t_query: int, delta: Delta,
                   method: Literal["time", "ops"] = "ops"
                   ) -> list[AnchorCandidate]:
        cands = []

        def cost(t_a: int) -> int:
            if method == "time":
                return abs(int(t_a) - int(t_query))
            if self.t_host is not None:
                return _window_ops_host(self.t_host, min(t_a, t_query),
                                        max(t_a, t_query))
            return int(count_window_ops(delta, min(t_a, t_query),
                                        max(t_a, t_query)))

        if self.current is not None and self.t_cur is not None:
            cands.append(AnchorCandidate(-1, int(self.t_cur),
                                         cost(self.t_cur)))
        for i, t_a in enumerate(self.times):
            cands.append(AnchorCandidate(i, t_a, cost(t_a)))
        if not cands:
            raise ValueError("no anchor candidates (no current snapshot "
                             "and no materialized snapshots)")
        return cands

    def select(self, t_query: int, delta: Delta,
               method: Literal["time", "ops"] = "ops") -> AnchorCandidate:
        cands = self.candidates(t_query, delta, method)
        # Stable tie-break: earliest candidate wins (current first), so
        # selection is deterministic and batch grouping reproducible.
        return min(cands, key=lambda c: c.cost)

    def get(self, anchor_id: int) -> tuple[int, DenseGraph]:
        if anchor_id == -1:
            if self.current is None:
                raise ValueError("no current snapshot registered")
            return int(self.t_cur), self.current
        return self.times[anchor_id], self.snapshots[anchor_id]


# ---------------------------------------------------------------------------
# Plan choice (paper §3.2 Table 2 × §3.3 variants)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanChoice:
    """A fully resolved execution recipe for one query."""

    plan: str                 # two_phase | delta_only | hybrid
    anchor_id: int = -1       # -1 = current snapshot
    t_anchor: int = 0
    indexed: bool = False     # node-centric index (§3.3.2)
    windowed: bool = False    # temporal-index window slice (§3.3.2)
    partial: bool = False     # partial reconstruction (§3.3.1)
    layout: str = "dense"     # dense (N² adjacency) | edge (E slots)
    cost: int = 0             # planner's op-count estimate


# Fixed per-program surcharge (op-count equivalents) of launching one
# multi-device dispatch: collective setup + per-device launch latency.
# A group is only sharded when the work it *removes* from the critical
# path exceeds this, so tiny groups stay single-device.
DISPATCH_OVERHEAD_OPS = 4096


class Planner:
    """Cost-based plan selection from delta / index statistics.

    Costs are op counts (the paper's unit): a plan pays for the delta
    window it must traverse, plus a layout surcharge for dense
    reconstruction (the N² LWW scatter) that the measure-only plans
    avoid.  Degree queries admit all of Table 2; other measures fall
    back to two-phase, as in the paper.

    The planner also owns the *cross-device dispatch* cost term
    (``shard_mode``): given a (plan, anchor) group and a mesh size it
    decides whether the group is worth sharding at all, and along
    which axis (query batch vs adjacency rows).
    """

    def __init__(self, selector: AnchorSelector, *, n_cap: int,
                 index: NodeIndex | None = None, node_cap: int = 1024,
                 selection: Literal["time", "ops"] = "ops",
                 dispatch_overhead: int = DISPATCH_OVERHEAD_OPS,
                 e_cap: int = 0, dense_available: bool = True,
                 edge_available: bool = False, seg_view=None):
        self.selector = selector
        self.n_cap = int(n_cap)
        self.index = index
        self.node_cap = int(node_cap)
        self.selection = selection
        self.dispatch_overhead = int(dispatch_overhead)
        # edge-slot layout statistics (0 / False when the engine has no
        # slot registry — e.g. engines built from bare arrays)
        self.e_cap = int(e_cap)
        self.dense_available = bool(dense_available)
        self.edge_available = bool(edge_available)
        # Segmented log (core.segments): per-segment node-count
        # statistics stand in for the node-centric index's row extents
        # when no index was built.
        self.seg_view = seg_view
        self._row_ptr_host: np.ndarray | None = None

    def _window_ops(self, delta: Delta, t_lo, t_hi) -> int:
        if self.selector.t_host is not None:
            return _window_ops_host(self.selector.t_host, t_lo, t_hi)
        return int(count_window_ops(delta, t_lo, t_hi))

    def _node_ops(self, v: int) -> int | None:
        """#ops touching node v: node-centric index row extent when an
        index was built, else the segmented log's per-segment node
        counts (same counting rule), else unknown."""
        if v is None:
            return None
        if self.index is not None:
            if self._row_ptr_host is None:
                self._row_ptr_host = np.asarray(self.index.row_ptr)
            ptr = self._row_ptr_host
            return int(ptr[v + 1] - ptr[v])
        if self.seg_view is not None:
            return self.seg_view.node_ops(v)
        return None

    def layout_for(self, q: Query, plan: str) -> str:
        """{dense, edge} execution layout for one query.

        Edge-slot layout is eligible when the engine carries a slot
        registry and the measure has an edge implementation; among
        eligible queries the N²-vs-E cost term decides: a two-phase
        reconstruction pays the dense LWW scatter (O(N²), or O(N) with
        partial reconstruction) vs the slot scatter (O(E)).  The
        measure-only plans (hybrid / delta-only) never materialize N²,
        so they keep the dense row read unless the dense snapshot is
        absent entirely (large-graph edge-only serving).
        """
        if not self.edge_available or not edge_supported(q.measure,
                                                         q.scope):
            return "dense"
        if not self.dense_available:
            return "edge"
        if plan != "two_phase":
            return "dense"
        dense_scatter = (self.n_cap if q.scope == "node"
                         and q.measure == "degree" and q.kind != "diff"
                         else self.n_cap ** 2 // 64)
        return "edge" if self.e_cap // 64 < dense_scatter else "dense"

    def choose(self, q: Query, delta: Delta, t_cur: int) -> PlanChoice:
        plans = applicable_plans(q)
        anchor = self.selector.select(q.t_k, delta, self.selection)
        if q.kind == "evolve":
            # The sweep executor reconstructs ONCE at t_lo and scans the
            # window incrementally — the planner's only real choices are
            # the anchor (nearest to t_lo, same Theorem-1 costing as any
            # two-phase query) and the layout.  Partial / windowed /
            # indexed are point-plan concepts and stay off.
            return PlanChoice(plan="two_phase", anchor_id=anchor.anchor_id,
                              t_anchor=anchor.t,
                              layout=self.layout_for(q, "two_phase"),
                              cost=anchor.cost)
        # two-phase traverses the anchor→query window and pays the dense
        # scatter; partial reconstruction (node scope) reduces the
        # scatter to the closure rows.
        scatter = self.n_cap if q.scope == "node" else self.n_cap ** 2 // 64
        cost_two = anchor.cost + scatter
        # Partial reconstruction is only auto-enabled where its closure
        # provably covers the query: single-window reconstructions of a
        # degree measure.  diff composes a second reconstruction from
        # the first's (already truncated) partial snapshot — stale rows
        # outside the first closure would leak — and non-degree
        # measures keep the scalar auto path's dense behavior.
        use_partial = (q.scope == "node" and q.measure == "degree"
                       and q.kind != "diff")

        best_plan, best_cost = "two_phase", cost_two
        if q.measure == "degree" and q.scope == "node":
            n_ops = self._node_ops(q.v)
            if "hybrid" in plans:
                # one corrective pass over (t_k, t_cur]
                c = self._window_ops(delta, q.t_k, t_cur)
                if n_ops is not None:
                    c = min(c, n_ops)
                if c < best_cost:
                    best_plan, best_cost = "hybrid", c
            if "delta_only" in plans:
                c = self._window_ops(delta, q.t_k, q.t_l)
                if n_ops is not None:
                    c = min(c, n_ops)
                if c < best_cost:
                    best_plan, best_cost = "delta_only", c

        indexed = (self.index is not None and q.scope == "node"
                   and best_plan in ("delta_only", "hybrid")
                   and (self._node_ops(q.v) or 0) <= self.node_cap)
        # windowed pays off when the anchor window is much smaller than
        # the full log (pow2 capacities bound recompiles).
        windowed = (best_plan == "two_phase"
                    and _pow2(anchor.cost, 64) * 2 <= delta.capacity)
        layout = self.layout_for(q, best_plan)
        return PlanChoice(plan=best_plan, anchor_id=anchor.anchor_id,
                          t_anchor=anchor.t, indexed=indexed,
                          windowed=windowed,
                          partial=(use_partial and best_plan == "two_phase"
                                   and layout == "dense"),
                          layout=layout, cost=best_cost)

    # ------------------------------------------------- cross-device dispatch

    def shard_mode(self, key, b: int, n_dev: int, delta_cap: int,
                   *, force: bool = False) -> str | None:
        """How to shard one (plan, anchor) group of ``b`` queries over
        ``n_dev`` devices: ``"rows"`` (dense two-phase row-sharded
        scatter + psum measures), ``"slots"`` (edge two-phase
        slot-sharded scatter + psum measures), ``"batch"`` (replicate
        graph, split the query axis), or ``None`` (stay single-device).

        The decision is a cost term: a multi-device program pays a
        fixed ``dispatch_overhead`` (collective setup + launch), so it
        only wins when the work moved *off* the critical path —
        ``group_work · (1 − 1/D)`` — exceeds that overhead.  ``force``
        skips the threshold (tests, benchmarks) but never makes an
        unshardable group shardable.
        """
        from repro.core.distributed import ROW_MEASURES, SLOT_MEASURES
        if n_dev <= 1:
            return None
        evolve = getattr(key, "kind", "") == "evolve"
        if key.plan == "two_phase" and getattr(key, "layout",
                                               "dense") == "edge":
            # Slot-sharding: the LWW slot scatter splits over the slot
            # axis; measures combine as psum'd integer partials exactly
            # like row-sharding (slots partition the edge set, so
            # per-shard popcounts/degree counts sum to the global
            # value — same exactness argument, 1-D instead of 2-D).
            # evolve additionally admits degree_distribution: the sweep
            # carries full psum'd degree counts, so the histogram is a
            # replicated finalization, not a partial.
            slot_ok = (key.measure in SLOT_MEASURES
                       or (evolve and key.measure == "degree_distribution"))
            if slot_ok and self.e_cap and self.e_cap % n_dev == 0:
                # per query: one masked log scan + one slot scatter
                work = b * max(delta_cap, self.e_cap)
                if force or work - work // n_dev > self.dispatch_overhead:
                    return "slots"
        elif key.plan == "two_phase":
            # Row-sharding needs a row-decomposable measure, an even
            # row split, and no partial reconstruction (the closure
            # mask is a full-graph object).  Evolve's dense path has no
            # row-sharded sweep — it batch-shards instead (the sharded
            # sweep is the slot path above).
            if (key.measure in ROW_MEASURES and not key.partial
                    and not evolve and self.n_cap % n_dev == 0):
                # one dense LWW scatter per query (agg kinds do one per
                # bucket — strictly more, so the bound is conservative)
                work = b * (self.n_cap ** 2 // 64)
                if force or work - work // n_dev > self.dispatch_overhead:
                    return "rows"
            # fall through: a two-phase group is still batch-shardable
            # (each device reconstructs dense, but only for its own
            # queries).
        if b < n_dev and not force:
            return None
        # per-query kernel work is dominated by the masked log scan
        work = b * max(delta_cap, self.n_cap)
        if force or work - work // n_dev > self.dispatch_overhead:
            return "batch"
        return None


# ---------------------------------------------------------------------------
# Batched kernels (vmap over the plans.py kernels)
# ---------------------------------------------------------------------------


def _snapshot_bytes(g) -> int:
    """Approximate device footprint of a cached snapshot (bool N² for
    dense, (4+4+1)·E + N for edge) — drives the reconstruction LRU's
    byte budget."""
    if isinstance(g, EdgeGraph):
        return 9 * g.e_cap + g.n_cap
    return g.n_cap * g.n_cap + g.n_cap


def _measure_named(g, measure: str, scope: str, v):
    """Measure dispatch over both snapshot layouts: the edge-layout
    measures are segment reductions with the exact same integer counts
    and f32 finalizations as the dense ones, so layout never changes a
    result bit (tests/test_engine.py edge-parity)."""
    if isinstance(g, EdgeGraph):
        if scope == "node":
            return EDGE_NODE_MEASURES[measure](g, v)
        return EDGE_GLOBAL_MEASURES[measure](g)
    if scope == "node":
        return NODE_MEASURES[measure](g, v)
    return GLOBAL_MEASURES[measure](g)


@partial(jax.jit, static_argnames=("measure", "scope"))
def batch_measure(g, vs, *, measure: str, scope: str):
    """Measure one (already reconstructed) snapshot at B nodes — the
    execution half of the per-anchor reconstruction cache: a cache hit
    skips the LWW delta replay and runs only this."""
    return jax.vmap(lambda v: _measure_named(g, measure, scope, v))(vs)


@partial(jax.jit, static_argnames=("measure", "scope", "use_partial",
                                   "passes"))
def batch_two_phase_point(anchor: DenseGraph, delta: Delta, t_anchor,
                          ts, vs, *, measure: str, scope: str,
                          use_partial: bool = False, passes: int = 2):
    """B point queries against one anchor: one vmapped LWW pass."""

    def one(t, v):
        if use_partial and scope == "node":
            g = partial_reconstruct(anchor, delta, t_anchor, t,
                                    seed_mask(anchor.n_cap, v),
                                    passes=passes)
        else:
            g = reconstruct_dense(anchor, delta, t_anchor, t)
        return _measure_named(g, measure, scope, v)

    return jax.vmap(one)(ts, vs)


@partial(jax.jit, static_argnames=("measure", "scope", "use_partial",
                                   "passes"))
def batch_two_phase_diff(anchor: DenseGraph, delta: Delta, t_anchor,
                         tks, tls, vs, *, measure: str, scope: str,
                         use_partial: bool = False, passes: int = 2):
    """B range-differential queries: reconstruct SG_tl from the anchor,
    then SG_tk from SG_tl (reusing the nearer snapshot exactly as the
    single-query plan does, so bitwise parity holds)."""

    def one(tk, tl, v):
        if use_partial and scope == "node":
            g_l = partial_reconstruct(anchor, delta, t_anchor, tl,
                                      seed_mask(anchor.n_cap, v),
                                      passes=passes)
        else:
            g_l = reconstruct_dense(anchor, delta, t_anchor, tl)
        g_k = reconstruct_dense(g_l, delta, tl, tk)
        a = _measure_named(g_l, measure, scope, v)
        b = _measure_named(g_k, measure, scope, v)
        return jnp.abs(a - b)

    return jax.vmap(one)(tks, tls, vs)


@partial(jax.jit, static_argnames=("measure", "scope", "num_buckets",
                                   "agg", "use_partial", "passes"))
def batch_two_phase_agg(anchor: DenseGraph, delta: Delta, t_anchor,
                        tks, tls, vs, *, measure: str, scope: str,
                        num_buckets: int, agg: str,
                        use_partial: bool = False, passes: int = 2):
    """B range-aggregate queries, each over ≤ num_buckets time units:
    a vmapped scan of reconstructions (buckets past t_l are masked)."""

    def one(tk, tl, v):
        ts = tk + jnp.arange(num_buckets, dtype=jnp.int32)

        def m_at(t):
            if use_partial and scope == "node":
                g = partial_reconstruct(anchor, delta, t_anchor, t,
                                        seed_mask(anchor.n_cap, v),
                                        passes=passes)
            else:
                g = reconstruct_dense(anchor, delta, t_anchor, t)
            return _measure_named(g, measure, scope, v)

        vals = jax.lax.map(m_at, ts)
        return masked_aggregate(vals, tl - tk + 1, num_buckets, agg)

    return jax.vmap(one)(tks, tls, vs)


# ---- edge-slot-layout two-phase kernels (O(E) per query, no N²) ----
#
# Same shape as the dense batch_two_phase_* kernels with the LWW slot
# scatter (reconstruct_edge) in place of the dense cell scatter; the
# hybrid / delta-only kernels below are layout-polymorphic already
# (they only touch the snapshot through degree()/degrees(), which both
# layouts implement with identical integer results), so edge-layout
# groups of those plans reuse them with an EdgeGraph operand.


@partial(jax.jit, static_argnames=("measure", "scope"))
def batch_edge_two_phase_point(anchor: EdgeGraph, delta: Delta, t_anchor,
                               ts, vs, *, measure: str, scope: str):
    """B point queries against one edge-layout anchor: one vmapped
    1-D LWW slot scatter per query — O(B·(M + E)) instead of
    O(B·(M + N²))."""

    def one(t, v):
        g = reconstruct_edge(anchor, delta, t_anchor, t)
        return _measure_named(g, measure, scope, v)

    return jax.vmap(one)(ts, vs)


@partial(jax.jit, static_argnames=("measure", "scope"))
def batch_edge_two_phase_diff(anchor: EdgeGraph, delta: Delta, t_anchor,
                              tks, tls, vs, *, measure: str, scope: str):
    """B range-differential queries, nearer-snapshot reuse exactly like
    the dense diff kernel (SG_tl from the anchor, SG_tk from SG_tl)."""

    def one(tk, tl, v):
        g_l = reconstruct_edge(anchor, delta, t_anchor, tl)
        g_k = reconstruct_edge(g_l, delta, tl, tk)
        a = _measure_named(g_l, measure, scope, v)
        b = _measure_named(g_k, measure, scope, v)
        return jnp.abs(a - b)

    return jax.vmap(one)(tks, tls, vs)


@partial(jax.jit, static_argnames=("measure", "scope", "num_buckets",
                                   "agg"))
def batch_edge_two_phase_agg(anchor: EdgeGraph, delta: Delta, t_anchor,
                             tks, tls, vs, *, measure: str, scope: str,
                             num_buckets: int, agg: str):
    """B range-aggregate queries: a vmapped scan of slot
    reconstructions (buckets past t_l are masked, identically to the
    dense agg kernel)."""

    def one(tk, tl, v):
        ts = tk + jnp.arange(num_buckets, dtype=jnp.int32)

        def m_at(t):
            g = reconstruct_edge(anchor, delta, t_anchor, t)
            return _measure_named(g, measure, scope, v)

        vals = jax.lax.map(m_at, ts)
        return masked_aggregate(vals, tl - tk + 1, num_buckets, agg)

    return jax.vmap(one)(tks, tls, vs)


@jax.jit
def batch_hybrid_point(current: DenseGraph, delta: Delta, vs, tks, t_cur):
    return jax.vmap(hybrid_point_degree,
                    in_axes=(None, None, 0, 0, None))(current, delta, vs,
                                                      tks, t_cur)


@partial(jax.jit, static_argnames=("cap",))
def batch_hybrid_point_indexed(current: DenseGraph, delta: Delta,
                               index: NodeIndex, vs, tks, t_cur, cap: int):
    def one(v, tk):
        sub = gather_node_ops(delta, index, v, cap)
        return hybrid_point_degree(current, sub, v, tk, t_cur)

    return jax.vmap(one)(vs, tks)


@jax.jit
def batch_hybrid_diff(current: DenseGraph, delta: Delta, vs, tks, tls,
                      t_cur):
    def one(v, tk, tl):
        d_l = hybrid_point_degree(current, delta, v, tl, t_cur)
        d_k = hybrid_point_degree(current, delta, v, tk, t_cur)
        return jnp.abs(d_l - d_k)

    return jax.vmap(one)(vs, tks, tls)


@partial(jax.jit, static_argnames=("cap",))
def batch_hybrid_diff_indexed(current: DenseGraph, delta: Delta,
                              index: NodeIndex, vs, tks, tls, t_cur,
                              cap: int):
    def one(v, tk, tl):
        sub = gather_node_ops(delta, index, v, cap)
        d_l = hybrid_point_degree(current, sub, v, tl, t_cur)
        d_k = hybrid_point_degree(current, sub, v, tk, t_cur)
        return jnp.abs(d_l - d_k)

    return jax.vmap(one)(vs, tks, tls)


@partial(jax.jit, static_argnames=("w_q", "agg"))
def batch_hybrid_agg_per_node(current: DenseGraph, delta: Delta, vs, tks,
                              tls, w_q: int, agg: str):
    """Fallback for groups whose union window is too wide to
    materialize as an all-nodes series: one O(w_q) per-node series per
    query (B scatter passes, O(B·w_q) memory — no n_cap factor)."""
    from repro.core.reconstruct import node_degree_series

    def one(v, tk, tl):
        series = node_degree_series(current.degree(v), delta, v, tk, w_q)
        return masked_aggregate(series, tl - tk + 1, w_q, agg)

    return jax.vmap(one)(vs, tks, tls)


@partial(jax.jit, static_argnames=("w_total", "w_q", "agg"))
def batch_hybrid_agg(current: DenseGraph, delta: Delta, vs, tks, tls, t0,
                     t_cur, w_total: int, w_q: int, agg: str):
    """B range-aggregate degree queries off ONE shared all-nodes degree
    time-series: a single un-vmapped scatter pass over the delta
    (``degree_series``) covering the union window [t0, t0 + w_total),
    then per-query gathers + masked aggregation.  This is the "one
    delta pass amortized over all queries sharing a window" form —
    vmapping the per-node kernel instead costs B scatter passes.

    Bitwise-identical to the scalar ``hybrid_agg_degree``: both compute
    degree(v, τ) = deg_cur(v) − suffix-net(τ) in exact int32 and divide
    the exact f32 sum by the width.
    """
    series = degree_series(current, delta, t0, t0 + w_total - 1, w_total,
                           t_cur)                       # i32[w_total, N]

    def one(v, tk, tl):
        idx = (tk - t0) + jnp.arange(w_q, dtype=jnp.int32)
        vals = series[jnp.clip(idx, 0, w_total - 1), v]
        return masked_aggregate(vals, tl - tk + 1, w_q, agg)

    return jax.vmap(one)(vs, tks, tls)


@jax.jit
def batch_delta_only_diff(delta: Delta, vs, tks, tls):
    return jax.vmap(delta_only_degree_diff,
                    in_axes=(None, 0, 0, 0))(delta, vs, tks, tls)


@partial(jax.jit, static_argnames=("cap",))
def batch_delta_only_diff_indexed(delta: Delta, index: NodeIndex, vs, tks,
                                  tls, cap: int):
    def one(v, tk, tl):
        sub = gather_node_ops(delta, index, v, cap)
        return delta_only_degree_diff(sub, v, tk, tl)

    return jax.vmap(one)(vs, tks, tls)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _GroupKey:
    """Everything that must be equal for two queries to share one
    device program (static shapes / static jit args / anchor)."""

    plan: str
    kind: str
    scope: str
    measure: str
    agg: str            # "" unless kind == "agg"
    anchor_id: int
    indexed: bool
    windowed: bool
    partial: bool
    layout: str = "dense"
    stride: int = 0     # 0 unless kind == "evolve" (sweep sample step)


class GroupStats(list):
    """``last_group_stats``: the per-call list of (group key, batch,
    shard mode) rows, plus the reconstruction-cache counters for the
    call (hits skip the LWW delta replay entirely)."""

    def __init__(self, *a):
        super().__init__(*a)
        self.cache_hits = 0
        self.cache_misses = 0


class HistoricalQueryEngine:
    """Planner + batched executor over one store state.

    Construct via ``HistoricalQueryEngine.from_store(store)`` (or let
    ``TemporalGraphStore.engine()`` cache one).  The engine is a pure
    view: it never mutates the store; re-create it (or let the store's
    cache invalidate) after ingesting new ops.
    """

    def __init__(self, current: DenseGraph | None, delta,
                 t_cur: int, *,
                 mat_times: Sequence[int] = (),
                 mat_snapshots: Sequence[DenseGraph] = (),
                 index: NodeIndex | None = None, node_cap: int = 1024,
                 selection: Literal["time", "ops"] = "ops",
                 passes: int = 2, series_budget: int = 1 << 24,
                 mesh=None, current_edge: EdgeGraph | None = None,
                 snap_cache_cap: int = 16, t_host=None):
        if current is None and current_edge is None:
            raise ValueError("need a current snapshot in at least one "
                             "layout")
        self.current = current
        self.current_edge = current_edge
        # ``delta`` is the full device log (monolithic stores) OR a
        # ``SegmentedDeltaView`` (segmented stores): planning reads
        # only .capacity / window counts from it, and every executor
        # path materializes its per-group window through _plan_delta /
        # _group_delta, so the full log never hits the device when the
        # view is segmented.
        self.delta = delta
        self.view = delta if isinstance(delta, SegmentedDeltaView) else None
        self.t_cur = int(t_cur)
        self.index = index
        self.node_cap = int(node_cap)
        self.passes = int(passes)
        # max elements of the shared all-nodes degree series a single
        # agg group may materialize (i32; 1<<24 ≈ 64 MB)
        self.series_budget = int(series_budget)
        # Serving mesh (None → single-device).  Snapshot/delta arrays
        # are placed on it lazily per role (replicated for batch-axis
        # groups, row/slot-sharded per anchor for two-phase groups) and
        # cached, so steady-state serving does no host→device copies.
        self.mesh = mesh
        self._placed_rep: dict = {}     # (mesh, role) -> replicated tree
        self._placed_rows: dict = {}    # (mesh, anchor_id) -> row-sharded
        self._placed_slots: dict = {}   # (mesh, anchor_id) -> slot-sharded
        # Per-anchor reconstruction LRU: (anchor_id, t, layout) ->
        # reconstructed snapshot.  Hot timestamps skip the delta replay
        # (point groups + store.snapshot_at); hit/miss counters land in
        # last_group_stats per call and on the engine cumulatively.
        # Eviction is bounded by entry count AND by device bytes
        # (``snap_cache_bytes``) — dense N² snapshots are big, so large
        # graphs keep only as many as fit the budget (edge-layout
        # entries are E-sized and effectively always fit).
        self.snap_cache_cap = int(snap_cache_cap)
        self.snap_cache_bytes = 256 << 20
        self._snap_cache_total = 0
        from collections import OrderedDict
        self._snap_cache: "OrderedDict" = OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0
        # Per-call instrumentation: [(group key, batch, shard mode)].
        # The cache counters on it are only live inside evaluate_many —
        # direct reconstruct_cached calls (store.snapshot_at) must not
        # retroactively mutate a previous call's saved stats.
        self.last_group_stats: GroupStats = GroupStats()
        self._stats_active = False
        # Observability: registry-backed counters/histograms (the
        # serving layer rebinds to the session registry at each freeze
        # via ``bind_metrics``) and an optional slow-query log.  The
        # engine-local ``cache_hits``/``cache_misses`` ints above stay
        # as per-engine-lifetime compatibility views — every epoch swap
        # builds a fresh engine, so they reset per epoch by
        # construction, while the registry counters are monotonic for
        # the registry's lifetime.
        self.slow_log = None
        self.bind_metrics(default_registry())
        # Serving-mode plumbing (repro.serving).  ``t_served`` is the
        # live watermark: when set, evaluate_many refuses queries past
        # it (WatermarkError) instead of silently serving a state that
        # may be missing pending ops.  ``workload`` is an optional
        # recorder (``serving.policy.WorkloadStats``): every served
        # query's times land in its histogram, which drives
        # workload-driven materialization at the next epoch swap.
        self.t_served: int | None = None
        self.workload = None
        # Minimum padded group size (1 = tightest pow2).  A serving
        # layer sets this to its micro-batch size so every group runs
        # the same program shape regardless of how a batch fragments
        # across (plan, anchor, measure) groups — bounding compiles to
        # one per group key instead of one per (key, pow2(b)).
        self.group_pad_min = 1
        # Edge-layout anchors are derived lazily from the dense ones
        # through the slot registry (dense_to_edge) and cached.
        self._edge_anchors: dict = {}
        # One host copy of the sorted timestamps — or the segment
        # view's per-segment statistics: all per-query costing (anchor
        # selection + plan choice) runs sync-free on it.  Callers that
        # already hold a host copy (the store caches one) pass it in,
        # skipping the O(M) device sync.
        if self.view is not None:
            self.t_host = self.view
        elif t_host is not None:
            self.t_host = t_host
        else:
            self.t_host = np.asarray(delta.t)  # graphlint: ignore[host-sync] one-time planning copy at engine build, off the hot path
        n_cap = (current.n_cap if current is not None
                 else current_edge.n_cap)
        # edge-only engines register the edge current as the -1 anchor
        # (the planner never routes dense groups without a dense
        # current, so get(-1) always returns the right layout)
        self.selector = AnchorSelector(
            mat_times, mat_snapshots, t_cur=self.t_cur,
            current=current if current is not None else current_edge,
            t_host=self.t_host)
        self.planner = Planner(
            self.selector, n_cap=n_cap, index=index, node_cap=node_cap,
            selection=selection,
            e_cap=current_edge.e_cap if current_edge is not None else 0,
            dense_available=current is not None,
            edge_available=current_edge is not None,
            seg_view=self.view)

    @classmethod
    def from_store(cls, store, *, indexed: bool = False,
                   node_cap: int = 1024,
                   selection: Literal["time", "ops"] = "ops",
                   mesh=None):
        current = store.current
        if not isinstance(current, DenseGraph):
            current = None  # edge-layout store: no N² state anywhere
        get_edge = getattr(store, "current_edge_snapshot", None)
        if getattr(store, "segmented", False):
            # the engine runs over the segment view: no full-log device
            # conversion, no O(M) host timestamp sync — epoch swaps
            # stay O(ops since the last swap)
            dref, t_host = store.delta_view(), None
        else:
            dref, t_host = store.delta(), store.op_times_host()
        return cls(current, dref, store.t_cur,
                   mat_times=store.materialized.times,
                   mat_snapshots=store.materialized.snapshots,
                   index=store.node_index() if indexed else None,
                   node_cap=node_cap, selection=selection, mesh=mesh,
                   current_edge=get_edge() if get_edge else None,
                   t_host=t_host)

    # --------------------------------------------------- device placement

    def _replicated(self, mesh, role: str, tree):
        """Cache a fully-replicated placement of ``tree`` on ``mesh``
        (graph/delta/index operands of batch-axis-sharded groups)."""
        key = (mesh, role)
        if key not in self._placed_rep:
            from repro.sharding.graph import replicate
            self._placed_rep[key] = replicate(tree, mesh)
        return self._placed_rep[key]

    def _row_sharded_anchor(self, mesh, anchor_id: int):
        """Cache the row-sharded placement of one anchor snapshot."""
        key = (mesh, anchor_id)
        if key not in self._placed_rows:
            from repro.core.distributed import shard_graph
            _, g = self.selector.get(anchor_id)
            self._placed_rows[key] = shard_graph(g, mesh)
        return self._placed_rows[key]

    def _slot_sharded_anchor(self, mesh, anchor_id: int):
        """Cache the slot-sharded placement of one edge-layout anchor."""
        key = (mesh, anchor_id)
        if key not in self._placed_slots:
            from repro.sharding.graph import shard_slots
            _, g = self.edge_anchor(anchor_id)
            self._placed_slots[key] = shard_slots(g, mesh)
        return self._placed_slots[key]

    # ------------------------------------------------------ edge anchors

    def edge_anchor(self, anchor_id: int) -> tuple[int, EdgeGraph]:
        """(t, snapshot) of an anchor in edge-slot layout.

        The current snapshot comes straight from the store's registry;
        materialized (dense) anchors are converted once through
        ``dense_to_edge`` over that same registry and cached — an O(E)
        gather, conversion is exact for any snapshot because slots are
        append-only."""
        if self.current_edge is None:
            raise ValueError("engine has no edge-slot registry")
        if anchor_id == -1:
            return self.t_cur, self.current_edge
        cached = self._edge_anchors.get(anchor_id)
        if cached is None:
            t_a, g = self.selector.get(anchor_id)
            if not isinstance(g, EdgeGraph):
                g = dense_to_edge(g, self.current_edge)
            cached = (t_a, g)
            self._edge_anchors[anchor_id] = cached
        return cached

    # -------------------------------------------------------- observability

    def bind_metrics(self, registry) -> None:
        """Resolve this engine's metric children against ``registry``
        (``repro.obs.metrics``).  Called with the process-global
        default at construction; the serving layer rebinds every frozen
        epoch's engine to its session registry."""
        self.metrics = registry
        self._m_queries = registry.counter(
            "engine_queries_total", "queries evaluated (batched path)")
        self._m_calls = registry.counter(
            "engine_calls_total", "evaluate_many invocations")
        self._m_eval_seconds = registry.histogram(
            "engine_evaluate_seconds",
            "wall seconds per evaluate_many call")
        self._m_group_batch = registry.histogram(
            "engine_group_batch", "queries per dispatched group",
            buckets=COUNT_BUCKETS)
        self._m_cache_hits = registry.counter(
            "engine_snap_cache_hits_total",
            "reconstruction-LRU hits (LWW replay skipped)")
        self._m_cache_misses = registry.counter(
            "engine_snap_cache_misses_total",
            "reconstruction-LRU misses (full LWW replay)")
        self._m_slow = registry.counter(
            "engine_slow_queries_total",
            "evaluate_many calls past the slow-query threshold")

    def _slow_entry(self, queries, seconds: float, trace_seq) -> dict:
        """Full plan attribution for one slow call (lazy — only built
        when the threshold triggered)."""
        from repro.obs.trace import active_tracer
        entry = {
            "n_queries": len(queries),
            "cache_hits": self.last_group_stats.cache_hits,
            "cache_misses": self.last_group_stats.cache_misses,
            "groups": [
                {"plan": k.plan, "kind": k.kind, "measure": k.measure,
                 "layout": k.layout, "anchor_id": k.anchor_id,
                 "indexed": k.indexed, "windowed": k.windowed,
                 "partial": k.partial, "batch": b, "shard_mode": mode}
                for k, b, mode in self.last_group_stats],
        }
        tracer = active_tracer()
        if tracer is not None and trace_seq is not None:
            entry["spans"] = tracer.events_since(trace_seq)
        return entry

    # ------------------------------------------- reconstruction cache

    def reconstruct_cached(self, anchor_id: int, t: int,
                           layout: str = "dense"):
        """LWW reconstruction of SG_t from one anchor, through the
        per-anchor LRU: repeated queries at hot timestamps skip the
        delta replay and only pay the measure."""
        key = (int(anchor_id), int(t), layout)
        g = self._snap_cache.get(key)
        if g is not None:
            self._snap_cache.move_to_end(key)
            self.cache_hits += 1
            self._m_cache_hits.inc()
            if self._stats_active:
                self.last_group_stats.cache_hits += 1
            return g
        self.cache_misses += 1
        self._m_cache_misses.inc()
        if self._stats_active:
            self.last_group_stats.cache_misses += 1
        with trace_span("reconstruct", anchor=int(anchor_id), t=int(t),
                        layout=layout):
            if layout == "edge":
                t_a, g_a = self.edge_anchor(anchor_id)
            else:
                t_a, g_a = self.selector.get(anchor_id)
            # single-window LWW reconstruction masks exactly at the
            # window bounds, so the merged-delta tree may cover the
            # whole window
            d = (self.view.window_delta(min(t_a, t), max(t_a, t),
                                        merged=True)
                 if self.view is not None else self.delta)
            if layout == "edge":
                g = reconstruct_edge(g_a, d, t_a, t)
            else:
                g = reconstruct_dense(g_a, d, t_a, t)
        if self.snap_cache_cap > 0:
            self._snap_cache[key] = g
            self._snap_cache_total += _snapshot_bytes(g)
            while self._snap_cache and (
                    len(self._snap_cache) > self.snap_cache_cap
                    or self._snap_cache_total > self.snap_cache_bytes):
                _, old = self._snap_cache.popitem(last=False)
                self._snap_cache_total -= _snapshot_bytes(old)
        return g

    # ------------------------------------------------------------- planning

    def plan(self, q: Query) -> PlanChoice:
        return self.planner.choose(q, self.delta, self.t_cur)

    def _resolve(self, q: Query, plan: str, indexed: bool | None,
                 partial_rows: bool | None, windowed: bool | None,
                 layout: str | None = None) -> PlanChoice:
        """Forced-plan / forced-variant resolution (test compatibility:
        mirrors the ``plans.evaluate`` kwargs).  ``layout`` forces the
        execution layout: ``"edge"`` falls back to dense per query when
        the measure has no edge implementation (mirroring how forced
        plans fall back for non-degree measures); ``"dense"`` /
        ``"edge"`` raise when the engine lacks that layout entirely."""
        if plan == "auto":
            c = self.plan(q)
        else:
            if plan not in applicable_plans(q):
                raise ValueError(f"plan {plan} not applicable to {q}")
            anchor = (self.selector.select(q.t_k, self.delta)
                      if plan == "two_phase"
                      else AnchorCandidate(-1, self.t_cur, 0))
            c = PlanChoice(plan=plan, anchor_id=anchor.anchor_id,
                           t_anchor=anchor.t,
                           layout=self.planner.layout_for(q, plan))
        if indexed is not None:
            c = dataclasses.replace(
                c, indexed=indexed and self.index is not None)
        if partial_rows is not None:
            c = dataclasses.replace(c, partial=partial_rows)
        if windowed is not None:
            c = dataclasses.replace(c, windowed=windowed)
        if c.plan != "two_phase" and q.measure != "degree":
            # The delta-only/hybrid kernels are degree-specialised;
            # mirror plans.evaluate's fallback to two-phase for every
            # other measure instead of running the wrong kernel.
            anchor = self.selector.select(q.t_k, self.delta)
            c = dataclasses.replace(
                c, plan="two_phase", anchor_id=anchor.anchor_id,
                t_anchor=anchor.t, indexed=False,
                layout=self.planner.layout_for(q, "two_phase"))
        if c.plan != "two_phase":
            c = dataclasses.replace(c, partial=False, windowed=False,
                                    anchor_id=-1, t_anchor=self.t_cur)
        if layout is not None and layout != "auto":
            if layout == "edge":
                ok = (self.current_edge is not None
                      and edge_supported(q.measure, q.scope))
                if not ok and self.current is None:
                    raise ValueError(f"measure {q.measure} has no "
                                     "edge-layout implementation and "
                                     "the engine has no dense state")
                c = dataclasses.replace(c,
                                        layout="edge" if ok else "dense")
            elif layout == "dense":
                if self.current is None:
                    raise ValueError("engine has no dense snapshot")
                c = dataclasses.replace(c, layout="dense")
            else:
                raise ValueError(f"unknown layout {layout!r}")
        if c.layout == "edge":
            # partial reconstruction is a dense-rows concept
            c = dataclasses.replace(c, partial=False)
        if q.kind == "evolve":
            # the sweep executor does its own (full) reconstruction and
            # windowing — forced point-plan variants must not leak in
            c = dataclasses.replace(c, indexed=False, windowed=False,
                                    partial=False)
        return c

    def _group_key(self, q: Query, c: PlanChoice) -> _GroupKey:
        return _GroupKey(plan=c.plan, kind=q.kind, scope=q.scope,
                         measure=q.measure, agg=q.agg if q.kind == "agg"
                         else "", anchor_id=c.anchor_id,
                         indexed=c.indexed, windowed=c.windowed,
                         partial=c.partial, layout=c.layout,
                         stride=q.stride if q.kind == "evolve" else 0)

    # ------------------------------------------------------------ execution

    def _group_delta(self, key: _GroupKey, t_anchor: int,
                     ts: np.ndarray) -> Delta:
        """The delta operand of one two-phase group: the union window
        covering every query in the group (pow2 capacity).  A
        segmented engine always materializes just the overlapping
        segments; a monolithic one slices via the temporal index when
        the planner marked the group windowed.  Reconstruction only
        reads in-window ops, so results are identical to the full
        log."""
        t_lo = int(min(ts.min(), t_anchor))
        t_hi = int(max(ts.max(), t_anchor))
        if self.view is not None:
            # Merged-tree nodes are only safe where every reconstruction
            # window in the group fully contains them (the LWW collapse
            # dropped superseded ops, so a window that *straddles* a
            # node would read a torn state).  Every window runs between
            # the anchor and one query time, so the common fully-covered
            # subrange is (t_anchor, min ts] going forward / (max ts,
            # t_anchor] going backward; a mixed-direction group keeps
            # leaves everywhere.
            ts_min, ts_max = int(ts.min()), int(ts.max())
            if ts_min >= t_anchor:
                return self.view.window_delta(t_lo, t_hi, merged=True,
                                              merged_lo=t_anchor,
                                              merged_hi=ts_min)
            if ts_max <= t_anchor:
                return self.view.window_delta(t_lo, t_hi, merged=True,
                                              merged_lo=ts_max,
                                              merged_hi=t_anchor)
            return self.view.window_delta(t_lo, t_hi)
        if not key.windowed:
            return self.delta
        n_win = _window_ops_host(self.t_host, t_lo, t_hi)
        cap = _pow2(n_win, 64)
        if cap >= self.delta.capacity:
            return self.delta
        return gather_window(self.delta, t_lo, t_hi, cap)

    def _plan_delta(self, key: _GroupKey, tks: np.ndarray,
                    tls: np.ndarray, b: int) -> Delta:
        """The delta operand of one delta-only / hybrid group.  The
        monolithic path hands every group the full log (their kernels
        window-mask internally); the segmented path materializes the
        union window — (min t_k, max t_l] for delta-only, the
        (min t_k, log end] suffix for hybrid (its corrective pass runs
        against SG_tcur, and matching the monolithic operand exactly —
        including any future-dated ops — keeps bit-parity
        unconditional).  Indexed groups gather by log position, so
        they use the full (position-stable) materialization."""
        if self.view is None:
            return self.delta
        if key.indexed:
            return self.view.full_delta()
        if key.plan == "delta_only":
            return self.view.window_delta(int(tks[:b].min()),
                                          int(tls[:b].max()))
        return self.view.window_delta(int(tks[:b].min()), None)

    def _maybe_replicated_delta(self, mesh, d: Delta) -> Delta:
        """Replicate a group's delta operand on the mesh: only the
        monolithic full log is worth caching under a stable role.
        Window materializations — segmented OR monolithic
        gather_window slices — pass through and shard_map places them
        on the fly, exactly like the pre-segmented windowed path (an
        identity-keyed cache would both leak replicated copies and
        risk serving a stale window after id reuse)."""
        if self.view is None and d is self.delta:
            return self._replicated(mesh, "delta", d)
        return d

    def _shard_mode(self, key: _GroupKey, b: int, mesh,
                    shard: str) -> str | None:
        """Group-level sharding decision (host fallback on 1 device)."""
        if mesh is None or shard == "never":
            return None
        from repro.sharding.graph import mesh_size, single_device
        if single_device(mesh):
            return None
        return self.planner.shard_mode(key, b, mesh_size(mesh),
                                       self.delta.capacity,
                                       force=(shard == "force"))

    def _run_group(self, key: _GroupKey, qs: list[Query], mesh=None,
                   shard: str = "auto"):
        """Dispatch one group as a single device program; returns the
        (padded) device array — callers slice to len(qs) after one
        batch-wide ``device_get``, so group dispatches overlap.

        With a multi-device ``mesh``, the group may run as one sharded
        program (``core.distributed``): the planner's dispatch cost
        term picks the axis — query batch for hybrid/delta-only (and
        non-decomposable two-phase), adjacency rows for two-phase with
        psum-combinable measures.  Either way the padded device array
        that comes back holds bit-identical per-query values.
        """
        b = len(qs)
        mode = self._shard_mode(key, b, mesh, shard)
        b_floor = max(b, self.group_pad_min)
        if mode is not None:
            from repro.sharding.graph import batch_pad, mesh_size
            padded = (batch_pad(b_floor, mesh_size(mesh))
                      if mode == "batch" else _pow2(b_floor))
        else:
            padded = _pow2(b_floor)
        self.last_group_stats.append((key, b, mode))
        # per-group accounting: plan/layout/shard-mode labels come from
        # closed vocabularies (bounded label cardinality); batch size
        # goes to a histogram, not a label
        self.metrics.counter(
            "engine_groups_total", "device programs dispatched",
            plan=key.plan, layout=key.layout,
            shard=mode or "none").inc()
        self._m_group_batch.observe(b)
        pad = padded - b
        tks = np.asarray([q.t_k for q in qs] + [qs[-1].t_k] * pad,
                         np.int32)
        last_tl = qs[-1].t_l if qs[-1].t_l is not None else qs[-1].t_k
        tls = np.asarray([q.t_l if q.t_l is not None else q.t_k
                          for q in qs] + [last_tl] * pad, np.int32)
        last_v = qs[-1].v if qs[-1].v is not None else 0
        vs = np.asarray([q.v if q.v is not None else 0 for q in qs]
                        + [last_v] * pad, np.int32)
        tks_d, tls_d, vs_d = map(jnp.asarray, (tks, tls, vs))

        # Per-anchor reconstruction cache: a point group whose times
        # repeat (or already sit in the LRU) reconstructs each unique
        # time once — cache hits skip even that — and pays only the
        # measures.  Same reconstruct + measure functions as the batch
        # kernel, so results are bit-identical.
        if (key.plan == "two_phase" and key.kind == "point"
                and mode is None and not key.partial
                and self.snap_cache_cap > 0):
            uts = np.unique(tks[:b])
            hits = sum((key.anchor_id, int(t), key.layout)
                       in self._snap_cache for t in uts)
            # worth it only when dedup at least halves the replays or
            # the LRU already covers every time in the group — a stray
            # single hit must not demote a large distinct-time batch to
            # the sequential per-time loop
            if 2 * len(uts) <= b or hits == len(uts):
                return self._run_point_group_cached(key, b, tks, vs)

        # Replicated operand placement for batch-axis sharded groups
        # (cached on the engine; plain single-device arrays otherwise).
        # The delta operand of a delta-only / hybrid group is its union
        # window (segmented engines materialize only the overlapping
        # segments); two-phase groups window separately below.
        base_cur = (self.current_edge if key.layout == "edge"
                    else self.current)
        if key.plan in ("delta_only", "hybrid"):
            with trace_span("window_delta", plan=key.plan):
                dlt = self._plan_delta(key, tks, tls, b)
        else:
            dlt = None
        if mode == "batch":
            cur_role = ("current_edge" if key.layout == "edge"
                        else "current")
            cur = self._replicated(mesh, cur_role, base_cur)
            if dlt is not None:
                dlt = self._maybe_replicated_delta(mesh, dlt)
            idx = (self._replicated(mesh, "index", self.index)
                   if self.index is not None else None)
        else:
            cur, idx = base_cur, self.index

        # Build one dispatch descriptor: (kernel, static kwargs,
        # positional args, query-axis mask).  The same descriptor runs
        # locally or under shard_map — the kernel body is identical.
        if key.plan == "delta_only":
            if key.indexed:
                desc = (batch_delta_only_diff_indexed,
                        (("cap", self.node_cap),),
                        (dlt, idx, vs_d, tks_d, tls_d),
                        (0, 0, 1, 1, 1))
            else:
                desc = (batch_delta_only_diff, (),
                        (dlt, vs_d, tks_d, tls_d), (0, 1, 1, 1))
        elif key.plan == "hybrid":
            if key.kind == "point":
                if key.indexed:
                    desc = (batch_hybrid_point_indexed,
                            (("cap", self.node_cap),),
                            (cur, dlt, idx, vs_d, tks_d, self.t_cur),
                            (0, 0, 0, 1, 1, 0))
                else:
                    desc = (batch_hybrid_point, (),
                            (cur, dlt, vs_d, tks_d, self.t_cur),
                            (0, 0, 1, 1, 0))
            elif key.kind == "diff":
                if key.indexed:
                    desc = (batch_hybrid_diff_indexed,
                            (("cap", self.node_cap),),
                            (cur, dlt, idx, vs_d, tks_d, tls_d,
                             self.t_cur),
                            (0, 0, 0, 1, 1, 1, 0))
                else:
                    desc = (batch_hybrid_diff, (),
                            (cur, dlt, vs_d, tks_d, tls_d, self.t_cur),
                            (0, 0, 1, 1, 1, 0))
            else:  # agg
                # Shared series covers the union window [t0, max t_l];
                # per-query values past each query's own t_l are masked
                # inside the kernel, so results are bit-identical for
                # any capacity ≥ width (pow2 bounds recompiles).
                t0 = int(tks[:b].min())
                w_total = _pow2(int(tls[:b].max()) - t0 + 1)
                w_q = _pow2(max(int(tl - tk) + 1
                                for tk, tl in zip(tks[:b], tls[:b])))
                if w_total * base_cur.n_cap > self.series_budget:
                    # one temporally-distant query would inflate the
                    # shared series to O(w_total · n_cap); fall back to
                    # per-node series (identical values, no n_cap term)
                    desc = (batch_hybrid_agg_per_node,
                            (("w_q", w_q), ("agg", key.agg)),
                            (cur, dlt, vs_d, tks_d, tls_d),
                            (0, 0, 1, 1, 1))
                else:
                    desc = (batch_hybrid_agg,
                            (("w_total", w_total), ("w_q", w_q),
                             ("agg", key.agg)),
                            (cur, dlt, vs_d, tks_d, tls_d, t0,
                             self.t_cur),
                            (0, 0, 1, 1, 1, 0, 0))
        else:  # two_phase
            with trace_span("anchor_select", anchor=key.anchor_id,
                            layout=key.layout):
                if key.layout == "edge":
                    t_anchor, g_anchor = self.edge_anchor(key.anchor_id)
                else:
                    t_anchor, g_anchor = self.selector.get(key.anchor_id)
            if key.kind == "evolve":
                return self._run_evolve_group(key, b, mode, mesh, t_anchor,
                                              g_anchor, tks, tls, vs_d)
            with trace_span("window_delta", plan="two_phase",
                            anchor=key.anchor_id):
                d = self._group_delta(
                    key, t_anchor,
                    np.concatenate([tks, tls])
                    if key.kind != "point" else tks)
            nb = 0
            if key.kind == "agg":
                nb = _pow2(max(int(tl - tk) + 1
                               for tk, tl in zip(tks[:b], tls[:b])))
            if mode == "rows":
                from repro.core import distributed as D
                anchor_rows = self._row_sharded_anchor(mesh, key.anchor_id)
                d = self._maybe_replicated_delta(mesh, d)
                return D.two_phase_rows(
                    mesh, anchor_rows, d, t_anchor, tks_d, tls_d, vs_d,
                    kind=key.kind, measure=key.measure, agg=key.agg,
                    num_buckets=nb)
            if mode == "slots":
                from repro.core import distributed as D
                anchor_slots = self._slot_sharded_anchor(mesh,
                                                         key.anchor_id)
                d = self._maybe_replicated_delta(mesh, d)
                return D.two_phase_slots(
                    mesh, anchor_slots, d, t_anchor, tks_d, tls_d, vs_d,
                    kind=key.kind, measure=key.measure, agg=key.agg,
                    num_buckets=nb)
            if mode == "batch":
                # anchor -1 IS the current snapshot — share its cached
                # placement instead of replicating the array twice
                if key.layout == "edge":
                    role = ("current_edge" if key.anchor_id == -1
                            else ("edge_anchor", key.anchor_id))
                else:
                    role = ("current" if key.anchor_id == -1
                            else ("anchor", key.anchor_id))
                g_anchor = self._replicated(mesh, role, g_anchor)
                d = self._maybe_replicated_delta(mesh, d)
            if key.layout == "edge":
                if key.kind == "point":
                    desc = (batch_edge_two_phase_point,
                            (("measure", key.measure),
                             ("scope", key.scope)),
                            (g_anchor, d, t_anchor, tks_d, vs_d),
                            (0, 0, 0, 1, 1))
                elif key.kind == "diff":
                    desc = (batch_edge_two_phase_diff,
                            (("measure", key.measure),
                             ("scope", key.scope)),
                            (g_anchor, d, t_anchor, tks_d, tls_d, vs_d),
                            (0, 0, 0, 1, 1, 1))
                else:
                    desc = (batch_edge_two_phase_agg,
                            (("measure", key.measure),
                             ("scope", key.scope),
                             ("num_buckets", nb), ("agg", key.agg)),
                            (g_anchor, d, t_anchor, tks_d, tls_d, vs_d),
                            (0, 0, 0, 1, 1, 1))
            elif key.kind == "point":
                desc = (batch_two_phase_point,
                        (("measure", key.measure), ("scope", key.scope),
                         ("use_partial", key.partial),
                         ("passes", self.passes)),
                        (g_anchor, d, t_anchor, tks_d, vs_d),
                        (0, 0, 0, 1, 1))
            elif key.kind == "diff":
                desc = (batch_two_phase_diff,
                        (("measure", key.measure), ("scope", key.scope),
                         ("use_partial", key.partial),
                         ("passes", self.passes)),
                        (g_anchor, d, t_anchor, tks_d, tls_d, vs_d),
                        (0, 0, 0, 1, 1, 1))
            else:
                desc = (batch_two_phase_agg,
                        (("measure", key.measure), ("scope", key.scope),
                         ("num_buckets", nb), ("agg", key.agg),
                         ("use_partial", key.partial),
                         ("passes", self.passes)),
                        (g_anchor, d, t_anchor, tks_d, tls_d, vs_d),
                        (0, 0, 0, 1, 1, 1))

        kernel, statics, args, qmask = desc
        if mode == "batch":
            from repro.core import distributed as D
            return D.batch_sharded(mesh, kernel, statics, args, qmask)
        return kernel(*args, **dict(statics))

    def _run_evolve_group(self, key: _GroupKey, b: int, mode, mesh,
                          t_anchor: int, g_anchor, tks: np.ndarray,
                          tls: np.ndarray, vs_d):
        """Dispatch one sweep group as ONE device program
        (``kernels.evolve_sweep.batch_evolve``): reconstruct each
        query's start state from the shared anchor, then an incremental
        apply-net / measure scan over the sweep window.

        Two delta operands with different coverage contracts:

        * ``d_rec`` (anchor ↔ every t_lo) feeds pure LWW
          reconstructions, so the merged-delta tree may cover its
          anchor-side common subrange;
        * ``d_net`` (every sweep window) feeds the signed NET-count
          scatter, which needs EVERY logged op — leaf segments only
          (the LWW collapse would corrupt the counts).
        """
        from repro.kernels.evolve_sweep.ops import (SWEEP_MEASURES,
                                                    batch_evolve)
        if key.measure not in SWEEP_MEASURES:
            raise ValueError(
                f"measure {key.measure!r} has no incremental sweep; "
                "store.evolve falls back to point queries for it")
        stride = max(int(key.stride), 1)
        widths = ((tls - tks) // stride + 1).astype(np.int32)
        nb = _pow2(int(widths.max()))
        ts_last = tks + (widths - 1) * stride
        lo_all, hi_all = int(tks.min()), int(tks.max())
        if self.view is not None:
            w_lo = min(lo_all, t_anchor)
            w_hi = max(hi_all, t_anchor)
            if lo_all >= t_anchor:
                d_rec = self.view.window_delta(w_lo, w_hi, merged=True,
                                               merged_lo=t_anchor,
                                               merged_hi=lo_all)
            elif hi_all <= t_anchor:
                d_rec = self.view.window_delta(w_lo, w_hi, merged=True,
                                               merged_lo=hi_all,
                                               merged_hi=t_anchor)
            else:
                d_rec = self.view.window_delta(w_lo, w_hi)
            d_net = self.view.window_delta(lo_all, int(ts_last.max()))
        else:
            d_rec = d_net = self.delta
        tlos_d = jnp.asarray(tks)
        widths_d = jnp.asarray(widths)
        if mode == "slots":
            from repro.core import distributed as D
            anchor_slots = self._slot_sharded_anchor(mesh, key.anchor_id)
            d_rec = self._maybe_replicated_delta(mesh, d_rec)
            d_net = self._maybe_replicated_delta(mesh, d_net)
            return D.evolve_slots(mesh, anchor_slots, d_rec, d_net,
                                  t_anchor, tlos_d, widths_d, vs_d,
                                  measure=key.measure, scope=key.scope,
                                  stride=stride, num_buckets=nb)
        if mode == "batch":
            if key.layout == "edge":
                role = ("current_edge" if key.anchor_id == -1
                        else ("edge_anchor", key.anchor_id))
            else:
                role = ("current" if key.anchor_id == -1
                        else ("anchor", key.anchor_id))
            g_anchor = self._replicated(mesh, role, g_anchor)
            d_rec = self._maybe_replicated_delta(mesh, d_rec)
            d_net = self._maybe_replicated_delta(mesh, d_net)
        statics = (("measure", key.measure), ("scope", key.scope),
                   ("stride", stride), ("num_buckets", nb))
        args = (g_anchor, d_rec, d_net, t_anchor, tlos_d, widths_d, vs_d)
        if mode == "batch":
            from repro.core import distributed as D
            return D.batch_sharded(mesh, batch_evolve, statics, args,
                                   (0, 0, 0, 0, 1, 1, 1))
        return batch_evolve(*args, **dict(statics))

    def _run_point_group_cached(self, key: _GroupKey, b: int,
                                tks: np.ndarray, vs: np.ndarray):
        """Serve one two-phase point group through the per-anchor
        reconstruction LRU: one LWW replay per *unique* query time
        (cache hits skip even that), then one vmapped measure pass per
        time.  Uses the same reconstruct/measure functions as the
        batch kernel, so per-query values are bit-identical."""
        uts, inv = np.unique(tks[:b], return_inverse=True)
        out = None
        for k, t in enumerate(uts):
            sel = np.nonzero(inv == k)[0]
            g = self.reconstruct_cached(key.anchor_id, int(t), key.layout)
            m = batch_measure(g, jnp.asarray(vs[sel]),
                              measure=key.measure, scope=key.scope)
            if out is None:
                # trailing dims carry vector measures
                # (degree_distribution) through unchanged
                out = jnp.zeros((b,) + m.shape[1:], m.dtype)
            out = out.at[jnp.asarray(sel)].set(m)
        return out

    def evaluate_many(self, queries: Sequence[Query], plan: str = "auto",
                      *, indexed: bool | None = None,
                      partial_rows: bool | None = None,
                      windowed: bool | None = None,
                      layout: str | None = None,
                      return_choices: bool = False,
                      mesh=None, shard: str = "auto",
                      enforce_watermark: bool = True):
        """Evaluate B historical queries, grouped by (plan, anchor) and
        executed as one device program per group.

        ``plan``/``indexed``/``partial_rows``/``windowed``/``layout``
        force the planner's choice uniformly (same semantics as
        ``plans.evaluate``); the default lets the cost model decide per
        query — ``layout`` picks between the dense N² adjacency and the
        O(E) edge-slot registry (``"edge"`` falls back to dense per
        query for measures without an edge implementation).  Returns a
        list of scalars in query order (and the per-query
        ``PlanChoice`` list when ``return_choices``).

        ``mesh`` (default: the engine's construction-time mesh) turns
        each large-enough group into one multi-device program —
        ``shard`` is ``"auto"`` (planner cost term decides per group),
        ``"force"`` (shard every shardable group) or ``"never"``.
        Sharded and single-device execution return bit-identical
        results; with one visible device the mesh is ignored (host
        fallback).

        A watermarked engine (``t_served`` set by the serving layer)
        refuses queries past the watermark with ``WatermarkError``;
        ``enforce_watermark=False`` bypasses the check for a caller
        that already applied its own staleness policy
        (``serving.LiveGraphStore`` with ``stale="serve"``).
        """
        mesh = mesh if mesh is not None else self.mesh
        if self.t_served is not None and enforce_watermark:
            for q in queries:
                t_hi = q.t_k if q.t_l is None else max(q.t_k, q.t_l)
                if t_hi > self.t_served:
                    raise WatermarkError(
                        f"query time {t_hi} is past the serving "
                        f"watermark t_served={self.t_served}; swap the "
                        "ingest epoch (or pass stale='block' at the "
                        "serving layer) to advance it")
        if self.workload is not None:
            self.workload.record_queries(queries)
        from repro.obs.trace import active_tracer
        tracer = active_tracer()
        trace_seq = tracer.seq if tracer is not None else None
        t_call = _clock.now()
        with trace_span("query", n=len(queries)) as top:
            with trace_span("plan", n=len(queries)):
                choices = [self._resolve(q, plan, indexed, partial_rows,
                                         windowed, layout)
                           for q in queries]
                groups: dict[_GroupKey, list[int]] = {}
                for i, (q, c) in enumerate(zip(queries, choices)):
                    groups.setdefault(self._group_key(q, c), []).append(i)
            top.set(groups=len(groups))
            # Dispatch every group first (async), then fetch everything
            # with one device_get so transfers don't serialize the
            # group programs.
            self.last_group_stats = GroupStats()
            self._stats_active = True
            try:
                outs = []
                for key, idxs in groups.items():
                    with trace_span("dispatch", plan=key.plan,
                                    layout=key.layout,
                                    measure=key.measure, batch=len(idxs)):
                        outs.append(
                            (idxs,
                             self._run_group(key,
                                             [queries[i] for i in idxs],
                                             mesh=mesh, shard=shard)))
            finally:
                self._stats_active = False
            with trace_span("measure", groups=len(outs)):
                fetched = jax.device_get([o for _, o in outs])
            results: list = [None] * len(queries)
            for (idxs, _), host in zip(outs, fetched):
                arr = np.asarray(host)
                for j, i in enumerate(idxs):
                    q = queries[i]
                    if q.kind == "evolve":
                        # sweep rows past a query's own width repeat
                        # its last sample (group padding) — slice off
                        t_l = q.t_k if q.t_l is None else q.t_l
                        bq = (int(t_l) - q.t_k) // max(int(q.stride),
                                                       1) + 1
                        results[i] = arr[j][:bq]
                    else:
                        results[i] = arr[j]
        seconds = _clock.now() - t_call
        self._m_calls.inc()
        self._m_queries.inc(len(queries))
        self._m_eval_seconds.observe(seconds)
        if self.slow_log is not None and self.slow_log.record(
                seconds,
                lambda: self._slow_entry(queries, seconds, trace_seq)):
            self._m_slow.inc()
        if return_choices:
            return results, choices
        return results

    def evaluate(self, q: Query, plan: str = "auto", **kw):
        """Single-query entry point: ``evaluate_many([q])[0]``."""
        return self.evaluate_many([q], plan, **kw)[0]
