"""Segmented interval delta log: O(epoch-ops) epoch swaps.

The paper keeps ONE monolithic interval delta Δ[t0, tcur]; our device
log used to mirror that, so every serving epoch swap rebuilt the whole
device log from the full host history — O(total history) host→device
conversion per swap, a scalability cliff under continuous ingest.  This
module partitions the log at materialized-anchor and epoch-swap
boundaries instead, which is exactly the paper's "materialize
intermediate snapshots + partial reconstruction" combination applied to
*storage*: DeltaGraph partitions its event lists hierarchically the
same way (Khurana & Deshpande), and AeonG splits current vs historical
storage along the identical hot/cold line.

* ``Segment`` — an immutable, sealed chunk of the host log covering a
  half-open time window (ops strictly time-disjoint from every other
  segment).  Holds compact host (numpy) arrays, per-segment op-count /
  node-count statistics (the planner's per-segment costing), and a
  lazily built pow2-capacity device ``Delta`` that can be *spilled*
  back to host-only under a residency budget and reloaded on demand.

* ``SegmentedDeltaView`` — an ordered sequence of segments behaving
  like one logical Δ[t0, tcur] for planning (``window_ops``,
  ``capacity``, ``node_ops`` — all host-side, O(log S) per window) and
  for execution (``window_delta`` materializes ONE compact device Delta
  from exactly the segments overlapping an (anchor, t) window,
  concatenating already-resident per-segment device arrays; results
  are bit-identical to the monolithic log because in-window ops keep
  their relative order and every kernel masks by time window anyway).

An epoch swap then seals + converts ONLY the open tail segment — swap
cost drops from O(total history) to O(ops since the last swap) — while
successive frozen epochs share the sealed segments' device arrays by
reference.
"""
from __future__ import annotations

import itertools
import threading
from collections import OrderedDict

import jax.numpy as jnp
import numpy as np

from repro.core.delta import (ADD_EDGE, NOP, REM_EDGE, T_PAD, Delta,
                              empty_delta, pow2_capacity as _pow2)

_UID = itertools.count(1)
_CLOCK = itertools.count(1)


def window_ops_count(times, t_lo, t_hi) -> int:
    """#ops with t in (t_lo, t_hi] — THE host-side window counting
    rule, over either a sorted host timestamp array (binary searches)
    or anything exposing ``.window_ops`` (a ``SegmentedDeltaView``).
    Shared by the engine's planner and the serving materialization
    policy so both cost windows identically."""
    window_ops = getattr(times, "window_ops", None)
    if window_ops is not None:
        return int(window_ops(t_lo, t_hi))
    i0 = np.searchsorted(times, t_lo, side="right")
    i1 = np.searchsorted(times, t_hi, side="right")
    return int(i1 - i0)


class Segment:
    """One immutable chunk of the host delta log.

    ``op/u/v/slot/t`` are compact host arrays (no padding); ``t`` is
    non-decreasing and strictly disjoint from every other segment's
    time range (the store seals by time cut, so ops with the boundary
    timestamp always land on one side).  The device ``Delta`` is built
    lazily at pow2 capacity, can be spilled (host arrays stay), and is
    rebuilt on the next access — the residency policy's unit.
    """

    __slots__ = ("uid", "sealed", "op", "u", "v", "slot", "t", "n_ops",
                 "t_min", "t_max", "_delta", "_node_counts", "_touch")

    def __init__(self, op, u, v, slot, t, *, sealed: bool = True):
        self.uid = next(_UID)
        self.sealed = sealed
        self.op = np.ascontiguousarray(op, np.int32)
        self.u = np.ascontiguousarray(u, np.int32)
        self.v = np.ascontiguousarray(v, np.int32)
        self.slot = np.ascontiguousarray(slot, np.int32)
        self.t = np.ascontiguousarray(t, np.int32)
        self.n_ops = int(self.op.shape[0])
        if self.n_ops == 0:
            raise ValueError("segments hold at least one op")
        self.t_min = int(self.t[0])
        self.t_max = int(self.t[-1])
        self._delta: Delta | None = None
        self._node_counts: np.ndarray | None = None
        # creation counts as a touch: a freshly sealed (never yet
        # queried) segment must not be the residency pass's first
        # spill victim — it is the newest, hottest data
        self._touch = next(_CLOCK)

    # ------------------------------------------------------------- stats

    @property
    def capacity(self) -> int:
        return _pow2(self.n_ops)

    def window_ops(self, t_lo, t_hi) -> int:
        """#ops of this segment with t in (t_lo, t_hi] (binary search —
        the per-segment temporal index)."""
        i0 = np.searchsorted(self.t, t_lo, side="right")
        i1 = np.searchsorted(self.t, t_hi, side="right")
        return int(i1 - i0)

    def ops_at_or_before(self, t) -> int:
        return int(np.searchsorted(self.t, t, side="right"))

    def node_counts(self, n_cap: int) -> np.ndarray:
        """Per-node op counts (edge ops under both endpoints, node ops
        once — the ``NodeIndex`` counting rule), the segment's
        node-centric index statistic.  Lazy, cached, host-side."""
        if self._node_counts is None or self._node_counts.shape[0] < n_cap:
            is_edge = (self.op == ADD_EDGE) | (self.op == REM_EDGE)
            c = np.bincount(np.clip(self.u, 0, n_cap - 1),
                            minlength=n_cap)
            c = c + np.bincount(np.clip(self.v[is_edge], 0, n_cap - 1),
                                minlength=n_cap)
            self._node_counts = c.astype(np.int64)
        return self._node_counts

    # --------------------------------------------------------- residency

    @property
    def is_resident(self) -> bool:
        return self._delta is not None

    def device_bytes(self) -> int:
        """Device footprint of the (resident) pow2 Delta: five i32
        columns plus the scalar."""
        return 5 * 4 * self.capacity + 4

    @property
    def delta(self) -> Delta:
        """The segment's device Delta (pow2 capacity), built on first
        access and after a spill — reload-on-demand.  Reads/returns a
        local so a residency pass spilling concurrently (the swap
        thread) can never make an in-flight access observe None."""
        self._touch = next(_CLOCK)
        d = self._delta
        if d is None:
            cap = self.capacity
            pad = cap - self.n_ops

            def col(x, fill):
                return jnp.asarray(np.concatenate(
                    [x, np.full((pad,), fill, np.int32)]) if pad else x)

            d = Delta(op=col(self.op, NOP), u=col(self.u, 0),
                      v=col(self.v, 0), slot=col(self.slot, 0),
                      t=col(self.t, T_PAD), n_ops=jnp.int32(self.n_ops))
            self._delta = d
        return d

    def spill(self) -> None:
        """Drop the device arrays (host arrays remain); the next
        ``delta`` access rebuilds them."""
        self._delta = None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Segment(uid={self.uid}, ops={self.n_ops}, "
                f"t=({self.t_min}..{self.t_max}), "
                f"resident={self.is_resident})")


class SegmentedDeltaView:
    """Δ[t0, tcur] as an ordered sequence of time-disjoint segments.

    Planning-side it quacks like the host timestamp copy the engine
    used to keep (``window_ops``, ``capacity``, ``node_ops``), but at
    O(log S + log seg) per window via per-segment statistics instead of
    one O(M) array.  Execution-side, ``window_delta`` materializes one
    compact device ``Delta`` from exactly the segments overlapping a
    query window; materializations are cached per view (successive
    serving epochs share the per-segment device arrays by reference —
    segments are immutable — while each epoch's view keeps its own
    window cache, so an in-flight swap never mutates state a frozen
    epoch is serving from).
    """

    def __init__(self, segments, *, n_cap: int = 0, cap_min: int = 0,
                 window_cache_cap: int = 8):
        self.segments: tuple[Segment, ...] = tuple(segments)
        self.n_cap = int(n_cap)
        self.cap_min = int(cap_min)
        self._cache: "OrderedDict" = OrderedDict()
        self._cache_cap = int(window_cache_cap)
        # full-log materializations keyed by capacity, OUTSIDE the
        # window LRU: indexed groups fetch the full delta per dispatch
        # and window churn must not evict it into an O(history)
        # re-concat (the view is immutable, so no invalidation needed)
        self._full: dict[int, Delta] = {}
        # concurrent readers (serving threads) and the residency pass
        # (swap thread) share this view's cache state
        self._lock = threading.Lock()
        self._tmin = np.asarray([s.t_min for s in self.segments], np.int64)
        self._tmax = np.asarray([s.t_max for s in self.segments], np.int64)
        self._cum = np.concatenate(
            [[0], np.cumsum([s.n_ops for s in self.segments])]).astype(
                np.int64)
        self._node_ops_sum: np.ndarray | None = None

    # ------------------------------------------------------------ planning

    @property
    def n_ops(self) -> int:
        return int(self._cum[-1])

    @property
    def capacity(self) -> int:
        """The monolithic log's device capacity, virtually: what
        ``store.delta()`` would pad to.  Planner cost terms (windowed
        thresholds, shard-mode work estimates) read this."""
        return max(1, self.cap_min, _pow2(self.n_ops))

    def ops_at_or_before(self, t) -> int:
        """#ops with timestamp ≤ t: two boundary binary searches (the
        segments are strictly time-disjoint and time-ordered)."""
        j = int(np.searchsorted(self._tmax, t, side="right"))
        n = int(self._cum[j])
        if j < len(self.segments) and self.segments[j].t_min <= t:
            n += self.segments[j].ops_at_or_before(t)
        return n

    def window_ops(self, t_lo, t_hi) -> int:
        """#ops with t in (t_lo, t_hi] — the temporal-index count the
        AnchorSelector/Planner charge reconstruction with."""
        return self.ops_at_or_before(t_hi) - self.ops_at_or_before(t_lo)

    def node_ops(self, v) -> int | None:
        """#ops touching node v — the per-segment node-count
        statistics summed once over the (immutable) view and cached,
        so the planner's per-query lookups are O(1) regardless of
        segment count (the segmented stand-in for the node-centric
        index's row extents)."""
        if not self.n_cap or v is None or not (0 <= int(v) < self.n_cap):
            return None
        c = self._node_ops_sum
        if c is None:
            c = np.zeros((self.n_cap,), np.int64)
            for s in self.segments:
                c = c + s.node_counts(self.n_cap)
            self._node_ops_sum = c  # benign race: idempotent value
        return int(c[int(v)])

    def window_range(self, t_lo, t_hi=None) -> tuple[int, int]:
        """[i0, i1) segment-index range overlapping (t_lo, t_hi]
        (``t_hi=None`` → through the end of the log)."""
        i0 = int(np.searchsorted(self._tmax, t_lo, side="right"))
        i1 = (len(self.segments) if t_hi is None
              else int(np.searchsorted(self._tmin, t_hi, side="right")))
        return i0, max(i0, i1)

    # ----------------------------------------------------------- execution

    def _materialize(self, sel: tuple[Segment, ...], cap: int) -> Delta:
        n = sum(s.n_ops for s in sel)
        if not sel:
            return empty_delta(cap)
        if len(sel) == 1 and cap == sel[0].capacity:
            return sel[0].delta
        pad = cap - n

        def cat(field, fill):
            parts = [getattr(s.delta, field)[:s.n_ops] for s in sel]
            if pad:
                parts.append(jnp.full((pad,), fill, jnp.int32))
            return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

        return Delta(op=cat("op", NOP), u=cat("u", 0), v=cat("v", 0),
                     slot=cat("slot", 0), t=cat("t", T_PAD),
                     n_ops=jnp.int32(n))

    def _cached(self, sel: tuple[Segment, ...], cap: int) -> Delta:
        # serving through a cached window still counts as touching its
        # segments — otherwise the residency LRU would spill the very
        # segments every request reads (and purge their hot window)
        for s in sel:
            s._touch = next(_CLOCK)
        key = ((sel[0].uid, sel[-1].uid, len(sel), cap) if sel
               else ("empty", cap))
        with self._lock:
            d = self._cache.get(key)
            if d is not None:
                self._cache.move_to_end(key)
                return d
        d = self._materialize(sel, cap)
        with self._lock:
            self._cache[key] = d
            while len(self._cache) > self._cache_cap:
                self._cache.popitem(last=False)
        return d

    def window_delta(self, t_lo, t_hi=None, *, pad_min: int = 64) -> Delta:
        """ONE compact device Delta holding every op with t in
        (t_lo, t_hi] — possibly more (whole overlapping segments are
        taken), never fewer.  Kernels mask by time window, and relative
        op order is preserved, so reconstruction/measure results are
        bit-identical to running against the monolithic log.  pow2
        capacity (floor ``pad_min``) bounds recompiles."""
        i0, i1 = self.window_range(t_lo, t_hi)
        sel = self.segments[i0:i1]
        cap = _pow2(sum(s.n_ops for s in sel), pad_min)
        return self._cached(sel, cap)

    def full_delta(self, capacity: int | None = None) -> Delta:
        """The whole log as one device Delta — the monolithic
        compatibility view (node-index consumers, ``store.delta()``).
        Op positions match the monolithic log exactly.  Cached per
        capacity for the view's lifetime (never evicted by window
        churn; callers opting into the full log opt into its
        residency)."""
        cap = max(1, capacity if capacity is not None else self.capacity)
        if cap < self.n_ops:
            raise ValueError(f"capacity {cap} < n_ops {self.n_ops}")
        with self._lock:
            d = self._full.get(cap)
        if d is None:
            d = self._materialize(self.segments, cap)
            with self._lock:
                self._full[cap] = d
        return d

    # ----------------------------------------------------------- residency

    def device_bytes(self) -> int:
        return sum(s.device_bytes() for s in self.segments
                   if s.is_resident)

    def _purge_windows_of(self, uids: set) -> None:
        """Drop cached window materializations that contain any of the
        given segments — a spill must release EVERY device reference
        to the segment's arrays, or the residency budget is fiction
        (uids are assigned in log order, so a key's (first, last) uid
        pair brackets exactly the segments its window concatenated)."""
        with self._lock:
            for key in list(self._cache):
                if key[0] == "empty":
                    continue
                u0, u1 = key[0], key[1]
                if any(u0 <= u <= u1 for u in uids):
                    del self._cache[key]

    def ensure_device(self, budget: int | None = None, *,
                      hot: int = 2) -> int:
        """Epoch-swap residency pass: convert the ``hot`` newest
        segments — the freshly sealed epoch plus, when future-dated
        ops left one, the volatile tail snapshot (O(epoch ops) either
        way) — leave older segments in whatever residency state
        queries drove them to, and spill the least-recently-touched
        resident segments down to the byte ``budget`` (None =
        unlimited).  Returns resident bytes (cached multi-segment
        window concatenations of still-resident segments are derived
        copies on top of this, bounded by the window-cache entry
        cap)."""
        for s in self.segments[-hot:]:
            s.delta  # noqa: B018 — property access builds the device log
        if budget is not None:
            keep = set(s.uid for s in self.segments[-hot:])
            resident = sorted(
                (s for s in self.segments if s.is_resident),
                key=lambda s: s._touch)
            total = sum(s.device_bytes() for s in resident)
            spilled = set()
            for s in resident:
                if total <= budget:
                    break
                if s.uid in keep:
                    continue
                s.spill()
                spilled.add(s.uid)
                total -= s.device_bytes()
            if spilled:
                self._purge_windows_of(spilled)
        return self.device_bytes()
