"""Segmented interval delta log: O(epoch-ops) epoch swaps.

The paper keeps ONE monolithic interval delta Δ[t0, tcur]; our device
log used to mirror that, so every serving epoch swap rebuilt the whole
device log from the full host history — O(total history) host→device
conversion per swap, a scalability cliff under continuous ingest.  This
module partitions the log at materialized-anchor and epoch-swap
boundaries instead, which is exactly the paper's "materialize
intermediate snapshots + partial reconstruction" combination applied to
*storage*: DeltaGraph partitions its event lists hierarchically the
same way (Khurana & Deshpande), and AeonG splits current vs historical
storage along the identical hot/cold line.

* ``Segment`` — an immutable, sealed chunk of the host log covering a
  half-open time window (ops strictly time-disjoint from every other
  segment).  Holds compact host (numpy) arrays, per-segment op-count /
  node-count statistics (the planner's per-segment costing), and a
  lazily built pow2-capacity device ``Delta`` that can be *spilled*
  back to host-only under a residency budget and reloaded on demand.

* ``SegmentedDeltaView`` — an ordered sequence of segments behaving
  like one logical Δ[t0, tcur] for planning (``window_ops``,
  ``capacity``, ``node_ops`` — all host-side, O(log S) per window) and
  for execution (``window_delta`` materializes ONE compact device Delta
  from exactly the segments overlapping an (anchor, t) window,
  concatenating already-resident per-segment device arrays; results
  are bit-identical to the monolithic log because in-window ops keep
  their relative order and every kernel masks by time window anyway).

An epoch swap then seals + converts ONLY the open tail segment — swap
cost drops from O(total history) to O(ops since the last swap) — while
successive frozen epochs share the sealed segments' device arrays by
reference.

* ``MergedNode`` / ``build_merged_nodes`` — the hierarchical
  merged-delta tree (DeltaGraph's eventlist hierarchy): interior nodes
  at pow2 leaf spans, each holding an LWW-collapsed merge of its
  children's ops.  Collapse keeps, per key — the canonical edge slot
  for edge ops, the node id for node ops — only the FIRST and LAST op
  inside the node's span, in original log order: for any query window
  that fully covers the span, forward reconstruction is decided by the
  key's last in-window op and backward reconstruction by its first
  (``reconstruct._lww_decide``), and both survive the collapse exactly;
  every dropped interior op is superseded in both directions.  A window
  that only *partially* covers a node must not use it (a dropped
  interior op could be the window's first/last for its key), so
  ``window_delta(..., merged=True)`` substitutes tree nodes only inside
  the caller-declared fully-covered subrange and keeps boundary leaves
  as leaves — O(log S) tree nodes instead of O(S) leaf segments, and
  strictly fewer ops wherever history churns (≥ 3 ops on one key).
  Merged nodes are NOT valid for the sign-sum kernels (hybrid /
  delta-only net counting) — dropping a superseded ADD/REM pair changes
  a net — which is why the merged path is opt-in per call site.
"""
from __future__ import annotations

import itertools
import threading
from collections import OrderedDict

import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import default_registry
from repro.core.delta import (ADD_EDGE, NOP, REM_EDGE, T_PAD, Delta,
                              empty_delta, pow2_capacity as _pow2)

_UID = itertools.count(1)
_CLOCK = itertools.count(1)


def window_ops_count(times, t_lo, t_hi) -> int:
    """#ops with t in (t_lo, t_hi] — THE host-side window counting
    rule, over either a sorted host timestamp array (binary searches)
    or anything exposing ``.window_ops`` (a ``SegmentedDeltaView``).
    Shared by the engine's planner and the serving materialization
    policy so both cost windows identically."""
    window_ops = getattr(times, "window_ops", None)
    if window_ops is not None:
        return int(window_ops(t_lo, t_hi))
    i0 = np.searchsorted(times, t_lo, side="right")
    i1 = np.searchsorted(times, t_hi, side="right")
    return int(i1 - i0)


class Segment:
    """One immutable chunk of the host delta log.

    ``op/u/v/slot/t`` are compact host arrays (no padding); ``t`` is
    non-decreasing and strictly disjoint from every other segment's
    time range (the store seals by time cut, so ops with the boundary
    timestamp always land on one side).  The device ``Delta`` is built
    lazily at pow2 capacity, can be spilled (host arrays stay), and is
    rebuilt on the next access — the residency policy's unit.
    """

    __slots__ = ("uid", "sealed", "op", "u", "v", "slot", "t", "n_ops",
                 "t_min", "t_max", "_delta", "_node_counts", "_touch",
                 "_spilled")

    def __init__(self, op, u, v, slot, t, *, sealed: bool = True):
        self.uid = next(_UID)
        self.sealed = sealed
        self.op = np.ascontiguousarray(op, np.int32)
        self.u = np.ascontiguousarray(u, np.int32)
        self.v = np.ascontiguousarray(v, np.int32)
        self.slot = np.ascontiguousarray(slot, np.int32)
        self.t = np.ascontiguousarray(t, np.int32)
        self.n_ops = int(self.op.shape[0])
        if self.n_ops == 0:
            raise ValueError("segments hold at least one op")
        self.t_min = int(self.t[0])
        self.t_max = int(self.t[-1])
        self._delta: Delta | None = None
        self._spilled = False
        self._node_counts: np.ndarray | None = None
        # creation counts as a touch: a freshly sealed (never yet
        # queried) segment must not be the residency pass's first
        # spill victim — it is the newest, hottest data
        self._touch = next(_CLOCK)

    # ----------------------------------------------------------- serialize

    _COLS = ("op", "u", "v", "slot", "t")

    def host_columns(self) -> dict[str, np.ndarray]:
        """The compact host columns, for serialization
        (``persist.manifest.save_segment_file`` writes them as one
        (5, n) int32 block)."""
        return {c: getattr(self, c) for c in self._COLS}

    def save(self, path: str) -> int:
        """Persist this segment atomically; returns the block crc32."""
        from repro.persist.manifest import save_segment_file
        return save_segment_file(path, self.host_columns())

    @classmethod
    def load(cls, path: str, *, mmap: bool = True,
             expected_crc: int | None = None) -> "Segment":
        """Rehydrate a sealed segment from disk.  With ``mmap`` (the
        default) the columns are mmap-backed views — construction reads
        only the header and boundary pages, and the residency pass's
        spill/reload cycle pages op data in and out on demand exactly
        as it does for RAM-resident history (``np.ascontiguousarray``
        adopts the contiguous int32 rows without copying).
        ``expected_crc`` re-checks the manifest's CRC32 stamp against
        the block content before the segment is trusted."""
        from repro.persist.manifest import load_segment_file
        cols = load_segment_file(path, mmap=mmap, expected_crc=expected_crc)
        return cls(cols["op"], cols["u"], cols["v"], cols["slot"],
                   cols["t"])

    # ------------------------------------------------------------- stats

    @property
    def capacity(self) -> int:
        return _pow2(self.n_ops)

    def window_ops(self, t_lo, t_hi) -> int:
        """#ops of this segment with t in (t_lo, t_hi] (binary search —
        the per-segment temporal index)."""
        i0 = np.searchsorted(self.t, t_lo, side="right")
        i1 = np.searchsorted(self.t, t_hi, side="right")
        return int(i1 - i0)

    def ops_at_or_before(self, t) -> int:
        return int(np.searchsorted(self.t, t, side="right"))

    def node_counts(self, n_cap: int) -> np.ndarray:
        """Per-node op counts (edge ops under both endpoints, node ops
        once — the ``NodeIndex`` counting rule), the segment's
        node-centric index statistic.  Lazy, cached, host-side."""
        if self._node_counts is None or self._node_counts.shape[0] < n_cap:
            is_edge = (self.op == ADD_EDGE) | (self.op == REM_EDGE)
            c = np.bincount(np.clip(self.u, 0, n_cap - 1),
                            minlength=n_cap)
            c = c + np.bincount(np.clip(self.v[is_edge], 0, n_cap - 1),
                                minlength=n_cap)
            self._node_counts = c.astype(np.int64)
        return self._node_counts

    # --------------------------------------------------------- residency

    @property
    def is_resident(self) -> bool:
        return self._delta is not None

    def device_bytes(self) -> int:
        """Device footprint of the (resident) pow2 Delta: five i32
        columns plus the scalar."""
        return 5 * 4 * self.capacity + 4

    @property
    def delta(self) -> Delta:
        """The segment's device Delta (pow2 capacity), built on first
        access and after a spill — reload-on-demand.  Reads/returns a
        local so a residency pass spilling concurrently (the swap
        thread) can never make an in-flight access observe None."""
        self._touch = next(_CLOCK)
        d = self._delta
        if d is None:
            if self._spilled:
                # reload-on-demand after a residency spill (first-ever
                # build is construction cost, not residency traffic)
                reg = default_registry()
                reg.counter("segments_reloads_total",
                            "spilled segments rebuilt on access").inc()
                reg.counter("segments_reload_bytes_total",
                            "device bytes rebuilt after spills"
                            ).inc(self.device_bytes())
                self._spilled = False
            cap = self.capacity
            pad = cap - self.n_ops

            def col(x, fill):
                return jnp.asarray(np.concatenate(
                    [x, np.full((pad,), fill, np.int32)]) if pad else x)

            d = Delta(op=col(self.op, NOP), u=col(self.u, 0),
                      v=col(self.v, 0), slot=col(self.slot, 0),
                      t=col(self.t, T_PAD), n_ops=jnp.int32(self.n_ops))
            self._delta = d
        return d

    def spill(self) -> None:
        """Drop the device arrays (host arrays remain); the next
        ``delta`` access rebuilds them."""
        if self._delta is None:
            return
        self._delta = None
        self._spilled = True
        reg = default_registry()
        reg.counter("segments_spills_total",
                    "resident segments evicted to host").inc()
        reg.counter("segments_spill_bytes_total",
                    "device bytes released by spills"
                    ).inc(self.device_bytes())

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Segment(uid={self.uid}, ops={self.n_ops}, "
                f"t=({self.t_min}..{self.t_max}), "
                f"resident={self.is_resident})")


def _lww_keep(op: np.ndarray, slot: np.ndarray) -> np.ndarray:
    """Sorted indices of the ops an LWW collapse keeps: the FIRST and
    LAST op per key.  The key is the canonical edge slot for edge ops
    and the node id for node ops (the store writes ``slot = u`` for
    node ops, so ``slot`` keys both, disambiguated by the op family) —
    exactly the cell each op lands on in either layout's LWW scatter."""
    is_edge = ((op == ADD_EDGE) | (op == REM_EDGE)).astype(np.int64)
    key = slot.astype(np.int64) * 2 + is_edge
    _, first = np.unique(key, return_index=True)
    _, last = np.unique(key[::-1], return_index=True)
    last = key.shape[0] - 1 - last
    return np.union1d(first, last)


class MergedNode(Segment):
    """One interior node of the merged-delta tree: the LWW-collapsed
    merge of an aligned pow2 run of sealed leaf segments.

    Covers leaves ``[lo, lo + 2**level)`` of the sealed sequence.  Ops
    keep their original relative order, so for windows fully covering
    the node's time span the materialized delta reconstructs
    bit-identically to the leaf concatenation (the collapse only drops
    ops superseded in BOTH reconstruction directions).  Inherits the
    leaf's residency machinery — lazy device build, ``spill()``,
    ``device_bytes`` — so the ``segment_device_budget`` pass treats
    tree nodes exactly like cold leaves.
    """

    __slots__ = ("lo", "level", "span")

    def __init__(self, op, u, v, slot, t, *, lo: int, level: int):
        super().__init__(op, u, v, slot, t, sealed=True)
        self.lo = int(lo)
        self.level = int(level)
        self.span = 1 << self.level

    @classmethod
    def merge(cls, a: Segment, b: Segment, *, lo: int,
              level: int) -> "MergedNode":
        """Collapse the concatenation of two children (leaves or
        lower-level nodes).  First/last-per-key collapse is
        associative — a child's kept first/last ops contain the
        concatenation's — so building from already-collapsed children
        equals collapsing the raw leaf run, at O(child ops) cost
        (each op takes part in ≤ log S merges over its lifetime)."""
        cols = {f: np.concatenate([getattr(a, f), getattr(b, f)])
                for f in ("op", "u", "v", "slot", "t")}
        keep = _lww_keep(cols["op"], cols["slot"])
        return cls(cols["op"][keep], cols["u"][keep], cols["v"][keep],
                   cols["slot"][keep], cols["t"][keep], lo=lo, level=level)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"MergedNode(uid={self.uid}, leaves=[{self.lo}, "
                f"{self.lo + self.span}), ops={self.n_ops}, "
                f"t=({self.t_min}..{self.t_max}), "
                f"resident={self.is_resident})")


def build_merged_nodes(segments, merged: dict) -> list[tuple[int, int]]:
    """Complete the merged-delta tree over a sealed segment sequence.

    ``merged`` maps ``(lo, level)`` → ``MergedNode`` covering leaves
    ``[lo, lo + 2**level)``; this fills in every aligned block the
    (append-only) sequence has completed, bottom-up so each node merges
    two already-collapsed children.  Called at ``seal_tail`` — the
    sequence only grows, so each call builds at most O(log S) new
    nodes and total build work is O(ops · log S) amortized over the
    store's lifetime.  Returns the (lo, level) pairs built."""
    n = len(segments)
    built: list[tuple[int, int]] = []
    level = 1
    while (1 << level) <= n:
        span = 1 << level
        for lo in range(0, n - span + 1, span):
            if (lo, level) in merged:
                continue
            if level == 1:
                a, b = segments[lo], segments[lo + 1]
            else:
                a = merged.get((lo, level - 1))
                b = merged.get((lo + span // 2, level - 1))
                if a is None or b is None:  # pragma: no cover
                    continue
            merged[(lo, level)] = MergedNode.merge(a, b, lo=lo,
                                                   level=level)
            built.append((lo, level))
        level += 1
    return built


class SegmentedDeltaView:
    """Δ[t0, tcur] as an ordered sequence of time-disjoint segments.

    Planning-side it quacks like the host timestamp copy the engine
    used to keep (``window_ops``, ``capacity``, ``node_ops``), but at
    O(log S + log seg) per window via per-segment statistics instead of
    one O(M) array.  Execution-side, ``window_delta`` materializes one
    compact device ``Delta`` from exactly the segments overlapping a
    query window; materializations are cached per view (successive
    serving epochs share the per-segment device arrays by reference —
    segments are immutable — while each epoch's view keeps its own
    window cache, so an in-flight swap never mutates state a frozen
    epoch is serving from).
    """

    def __init__(self, segments, *, n_cap: int = 0, cap_min: int = 0,
                 window_cache_cap: int = 8, merged: dict | None = None):
        self.segments: tuple[Segment, ...] = tuple(segments)
        # merged-delta tree nodes, keyed (leaf index, level) — leaf
        # indices refer to positions in ``segments``.  Snapshotted at
        # construction (the store's dict keeps growing with later
        # seals; a frozen epoch's view must not see them appear).
        self.merged: dict[tuple[int, int], MergedNode] = dict(merged or {})
        self.n_cap = int(n_cap)
        self.cap_min = int(cap_min)
        self._cache: "OrderedDict" = OrderedDict()
        self._cache_cap = int(window_cache_cap)
        # full-log materializations keyed by capacity, OUTSIDE the
        # window LRU: indexed groups fetch the full delta per dispatch
        # and window churn must not evict it into an O(history)
        # re-concat (the view is immutable, so no invalidation needed)
        self._full: dict[int, Delta] = {}
        # concurrent readers (serving threads) and the residency pass
        # (swap thread) share this view's cache state
        self._lock = threading.Lock()
        self._tmin = np.asarray([s.t_min for s in self.segments], np.int64)
        self._tmax = np.asarray([s.t_max for s in self.segments], np.int64)
        self._cum = np.concatenate(
            [[0], np.cumsum([s.n_ops for s in self.segments])]).astype(
                np.int64)
        self._node_ops_sum: np.ndarray | None = None

    # ------------------------------------------------------------ planning

    @property
    def n_ops(self) -> int:
        return int(self._cum[-1])

    @property
    def capacity(self) -> int:
        """The monolithic log's device capacity, virtually: what
        ``store.delta()`` would pad to.  Planner cost terms (windowed
        thresholds, shard-mode work estimates) read this."""
        return max(1, self.cap_min, _pow2(self.n_ops))

    def ops_at_or_before(self, t) -> int:
        """#ops with timestamp ≤ t: two boundary binary searches (the
        segments are strictly time-disjoint and time-ordered)."""
        j = int(np.searchsorted(self._tmax, t, side="right"))
        n = int(self._cum[j])
        if j < len(self.segments) and self.segments[j].t_min <= t:
            n += self.segments[j].ops_at_or_before(t)
        return n

    def window_ops(self, t_lo, t_hi) -> int:
        """#ops with t in (t_lo, t_hi] — the temporal-index count the
        AnchorSelector/Planner charge reconstruction with."""
        return self.ops_at_or_before(t_hi) - self.ops_at_or_before(t_lo)

    def node_ops(self, v) -> int | None:
        """#ops touching node v — the per-segment node-count
        statistics summed once over the (immutable) view and cached,
        so the planner's per-query lookups are O(1) regardless of
        segment count (the segmented stand-in for the node-centric
        index's row extents)."""
        if not self.n_cap or v is None or not (0 <= int(v) < self.n_cap):
            return None
        c = self._node_ops_sum
        if c is None:
            with self._lock:
                c = self._node_ops_sum
                if c is None:
                    c = np.zeros((self.n_cap,), np.int64)
                    for s in self.segments:
                        c = c + s.node_counts(self.n_cap)
                    self._node_ops_sum = c
        return int(c[int(v)])

    def window_range(self, t_lo, t_hi=None) -> tuple[int, int]:
        """[i0, i1) segment-index range overlapping (t_lo, t_hi]
        (``t_hi=None`` → through the end of the log)."""
        i0 = int(np.searchsorted(self._tmax, t_lo, side="right"))
        i1 = (len(self.segments) if t_hi is None
              else int(np.searchsorted(self._tmin, t_hi, side="right")))
        return i0, max(i0, i1)

    # ----------------------------------------------------------- execution

    def _tree_cover(self, i0: int, i1: int, safe_lo, safe_hi):
        """Cover the leaf run [i0, i1) with the largest merged nodes
        whose time span lies fully inside (safe_lo, safe_hi]; leaves
        elsewhere.  Greedy left-to-right over aligned pow2 blocks —
        the canonical segment-tree decomposition, O(log S) items for a
        fully-safe run."""
        out: list[Segment] = []
        i = i0
        while i < i1:
            best: MergedNode | None = None
            level = 1
            while True:
                span = 1 << level
                if i % span or i + span > i1:
                    break
                node = self.merged.get((i, level))
                # a node's t_min is its first leaf's (shared by every
                # level at this position) and t_max grows with level,
                # so the first span/time violation is final
                if node is None or not (safe_lo < node.t_min
                                        and node.t_max <= safe_hi):
                    break
                best = node
                level += 1
            if best is not None:
                out.append(best)
                i += best.span
            else:
                out.append(self.segments[i])
                i += 1
        return tuple(out)

    def window_cover(self, t_lo, t_hi=None, *, merged: bool = False,
                     merged_lo=None, merged_hi=None):
        """The segment/node selection ``window_delta`` materializes for
        (t_lo, t_hi] — exposed so benches/tests can count the ops a
        covering actually scatters.  ``merged=True`` substitutes tree
        nodes for leaf runs whose time span is fully inside
        (``merged_lo``, ``merged_hi``] (defaulting to the window
        itself); see the module docstring for why partial coverage
        must keep leaves."""
        i0, i1 = self.window_range(t_lo, t_hi)
        if not merged or not self.merged or i1 - i0 < 2:
            return self.segments[i0:i1]
        s_lo = t_lo if merged_lo is None else merged_lo
        if merged_hi is not None:
            s_hi = merged_hi
        elif t_hi is not None:
            s_hi = t_hi
        else:
            s_hi = self._tmax[-1] if len(self.segments) else t_lo
        return self._tree_cover(i0, i1, int(s_lo), int(s_hi))

    def _materialize(self, sel: tuple[Segment, ...], cap: int) -> Delta:
        n = sum(s.n_ops for s in sel)
        if not sel:
            return empty_delta(cap)
        if len(sel) == 1 and cap == sel[0].capacity:
            return sel[0].delta
        pad = cap - n

        def cat(field, fill):
            parts = [getattr(s.delta, field)[:s.n_ops] for s in sel]
            if pad:
                parts.append(jnp.full((pad,), fill, jnp.int32))
            return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

        return Delta(op=cat("op", NOP), u=cat("u", 0), v=cat("v", 0),
                     slot=cat("slot", 0), t=cat("t", T_PAD),
                     n_ops=jnp.int32(n))

    def _cached(self, sel: tuple[Segment, ...], cap: int) -> Delta:
        # serving through a cached window still counts as touching its
        # segments — otherwise the residency LRU would spill the very
        # segments every request reads (and purge their hot window)
        for s in sel:
            s._touch = next(_CLOCK)
        # (min uid, max uid) brackets every selected item — merged
        # nodes carry later uids than their leaves, so the bracket is
        # what _purge_windows_of tests; the full uid tuple keeps
        # distinct coverings of the same range distinct
        key = ((min(s.uid for s in sel), max(s.uid for s in sel),
                tuple(s.uid for s in sel), cap) if sel
               else ("empty", cap))
        with self._lock:
            d = self._cache.get(key)
            if d is not None:
                self._cache.move_to_end(key)
                return d
        d = self._materialize(sel, cap)
        with self._lock:
            self._cache[key] = d
            while len(self._cache) > self._cache_cap:
                self._cache.popitem(last=False)
        return d

    def window_delta(self, t_lo, t_hi=None, *, pad_min: int = 64,
                     merged: bool = False, merged_lo=None,
                     merged_hi=None) -> Delta:
        """ONE compact device Delta holding every op with t in
        (t_lo, t_hi] — possibly more (whole overlapping segments are
        taken), never fewer.  Kernels mask by time window, and relative
        op order is preserved, so reconstruction/measure results are
        bit-identical to running against the monolithic log.  pow2
        capacity (floor ``pad_min``) bounds recompiles.

        ``merged=True`` opts in to the merged-delta tree: leaf runs
        whose time span lies fully inside (``merged_lo``,
        ``merged_hi``] — defaulting to the window itself — are served
        by O(log S) collapsed interior nodes instead of O(S) leaves.
        ONLY safe for LWW reconstruction consumers whose time masks
        fully cover that subrange (the collapse drops interior ops, so
        sign-sum consumers and partially-covering masks must stay on
        the leaf path)."""
        sel = self.window_cover(t_lo, t_hi, merged=merged,
                                merged_lo=merged_lo, merged_hi=merged_hi)
        cap = _pow2(sum(s.n_ops for s in sel), pad_min)
        return self._cached(sel, cap)

    def full_delta(self, capacity: int | None = None) -> Delta:
        """The whole log as one device Delta — the monolithic
        compatibility view (node-index consumers, ``store.delta()``).
        Op positions match the monolithic log exactly.  Cached per
        capacity for the view's lifetime (never evicted by window
        churn; callers opting into the full log opt into its
        residency)."""
        cap = max(1, capacity if capacity is not None else self.capacity)
        if cap < self.n_ops:
            raise ValueError(f"capacity {cap} < n_ops {self.n_ops}")
        with self._lock:
            d = self._full.get(cap)
        if d is None:
            d = self._materialize(self.segments, cap)
            with self._lock:
                self._full[cap] = d
        return d

    # ----------------------------------------------------------- residency

    def device_bytes(self) -> int:
        return sum(s.device_bytes()
                   for s in (*self.segments, *self.merged.values())
                   if s.is_resident)

    def _purge_windows_of(self, uids: set) -> None:
        """Drop cached window materializations that contain any of the
        given segments/nodes — a spill must release EVERY device
        reference to the spilled arrays, or the residency budget is
        fiction.  A key's (min, max) uid pair brackets everything its
        window concatenated; purging on the bracket is conservative
        (a tree-covered window may be dropped for a leaf it serves
        through a merged node) but never leaks a reference."""
        with self._lock:
            for key in list(self._cache):
                if key[0] == "empty":
                    continue
                u0, u1 = key[0], key[1]
                if any(u0 <= u <= u1 for u in uids):
                    del self._cache[key]

    def ensure_device(self, budget: int | None = None, *,
                      hot: int = 2) -> int:
        """Epoch-swap residency pass: convert the ``hot`` newest
        segments — the freshly sealed epoch plus, when future-dated
        ops left one, the volatile tail snapshot (O(epoch ops) either
        way) — leave older segments in whatever residency state
        queries drove them to, and spill the least-recently-touched
        resident segments down to the byte ``budget`` (None =
        unlimited).  Returns resident bytes (cached multi-segment
        window concatenations of still-resident segments are derived
        copies on top of this, bounded by the window-cache entry
        cap)."""
        for s in self.segments[-hot:]:
            s.delta  # noqa: B018 — property access builds the device log
        if budget is not None:
            keep = set(s.uid for s in self.segments[-hot:])
            # merged tree nodes are residency citizens like cold
            # leaves: they build device arrays lazily on first cover
            # use, count against the budget, and spill by LRU touch
            resident = sorted(
                (s for s in (*self.segments, *self.merged.values())
                 if s.is_resident),
                key=lambda s: s._touch)
            total = sum(s.device_bytes() for s in resident)
            spilled = set()
            for s in resident:
                if total <= budget:
                    break
                if s.uid in keep:
                    continue
                s.spill()
                spilled.add(s.uid)
                total -= s.device_bytes()
            if spilled:
                self._purge_windows_of(spilled)
        resident_bytes = self.device_bytes()
        default_registry().gauge(
            "segments_resident_bytes",
            "device bytes held by resident segments").set(resident_bytes)
        return resident_bytes
