"""Graph measures used by historical queries (paper Table 1).

Node-centric measures: degree, neighborhood, induced-subgraph stats,
k-core membership.  Global measures: diameter, connected components,
degree distribution, PageRank, triangle count, density.

On the dense layout, global measures are deliberately formulated as
(boolean) matrix products so that on TPU they run on the MXU
(DESIGN.md §2.2): BFS by frontier expansion, components by label
propagation, triangles by trace(A³).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import DenseGraph, EdgeGraph

INF = jnp.int32(0x3FFFFFFF)

# ---------------------------------------------------------------------------
# Node-centric measures
# ---------------------------------------------------------------------------


def degree(g: DenseGraph, v) -> jax.Array:
    return g.degree(v)


def neighborhood_size(g: DenseGraph, v, hops: int = 2) -> jax.Array:
    """|{u : dist(v, u) ≤ hops}| − 1, via frontier matmuls."""
    reached = jnp.zeros((g.n_cap,), bool).at[v].set(True)
    frontier = reached
    for _ in range(hops):
        nxt = (frontier.astype(jnp.float32) @ g.adj.astype(jnp.float32)) > 0
        frontier = nxt & ~reached
        reached = reached | nxt
    return jnp.sum(reached.astype(jnp.int32)) - 1


def induced_subgraph_mask(g: DenseGraph, v) -> jax.Array:
    """v plus its neighbors (the paper's induced-subgraph example)."""
    return g.adj[v] | jnp.zeros((g.n_cap,), bool).at[v].set(g.nodes[v])


def induced_avg_degree(g: DenseGraph, v) -> jax.Array:
    """Average degree of the subgraph induced by v and its neighbors —
    the paper's §3.2.3 multi-pass hybrid example."""
    m = induced_subgraph_mask(g, v)
    sub = g.induced(m)
    nn = jnp.maximum(sub.num_nodes(), 1)
    return (2.0 * sub.num_edges()) / nn


def in_k_core(g: DenseGraph, v, k: int) -> jax.Array:
    """Whether v survives k-core peeling."""
    def cond(state):
        keep, changed = state
        return changed

    def body(state):
        keep, _ = state
        deg = jnp.sum(g.adj & keep[None, :], axis=1)
        new = keep & (deg >= k) & g.nodes
        return new, jnp.any(new != keep)

    keep0 = g.nodes
    keep, _ = jax.lax.while_loop(cond, body, (keep0, jnp.bool_(True)))
    return keep[v]


# ---------------------------------------------------------------------------
# Global measures
# ---------------------------------------------------------------------------


def num_nodes(g: DenseGraph):
    return g.num_nodes()


def num_edges(g: DenseGraph):
    return g.num_edges()


def density(g: DenseGraph) -> jax.Array:
    n = g.num_nodes().astype(jnp.float32)
    e = g.num_edges().astype(jnp.float32)
    return jnp.where(n > 1, 2.0 * e / (n * (n - 1.0)), 0.0)


def avg_degree(g: DenseGraph) -> jax.Array:
    n = jnp.maximum(g.num_nodes(), 1).astype(jnp.float32)
    return 2.0 * g.num_edges().astype(jnp.float32) / n


# Registered degree-distribution bin count: degrees past the last bin
# clip into it, so the histogram shape is static (one jit program per
# measure) at any graph size.
DEGREE_DIST_BINS = 64


def _degree_histogram(deg: jax.Array, nodes: jax.Array,
                      max_deg: int) -> jax.Array:
    """Validity-weighted degree bincount, bins [0, max_deg] with
    overflow clipped into the last bin.  Shared by BOTH layouts: the
    dense/edge parity contract is exactly 'same degrees in, same bits
    out', so the histogram arithmetic must live in one place."""
    deg = jnp.clip(deg, 0, max_deg)
    w = nodes.astype(jnp.int32)
    return jnp.zeros((max_deg + 1,), jnp.int32).at[deg].add(w)


def degree_distribution(g: DenseGraph,
                        max_deg: int = DEGREE_DIST_BINS) -> jax.Array:
    """Histogram of degrees over valid nodes, bins [0, max_deg]."""
    return _degree_histogram(g.degrees(), g.nodes, max_deg)


@partial(jax.jit, static_argnames=("max_iters",))
def connected_components(g: DenseGraph, max_iters: int = 64) -> jax.Array:
    """Component labels via min-label propagation (MXU-friendly)."""
    n = g.n_cap
    labels0 = jnp.where(g.nodes, jnp.arange(n, dtype=jnp.int32), INF)

    def body(state):
        labels, _, it = state
        neigh = jnp.where(g.adj, labels[None, :], INF)
        new = jnp.minimum(labels, jnp.min(neigh, axis=1))
        new = jnp.where(g.nodes, new, INF)
        return new, jnp.any(new != labels), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    labels, _, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), jnp.int32(0)))
    return labels


def num_components(g: DenseGraph) -> jax.Array:
    labels = connected_components(g)
    own = labels == jnp.arange(g.n_cap, dtype=jnp.int32)
    return jnp.sum((own & g.nodes).astype(jnp.int32))


@partial(jax.jit, static_argnames=("num_sources", "max_iters"))
def diameter(g: DenseGraph, num_sources: int = 0, max_iters: int = 64):
    """(Estimated) diameter via multi-source BFS frontier matmuls.

    ``num_sources == 0`` → exact: BFS from every node.  Unreachable pairs
    are ignored (per-component eccentricity).
    """
    n = g.n_cap
    if num_sources and num_sources < n:
        src = jnp.linspace(0, n - 1, num_sources).astype(jnp.int32)
    else:
        src = jnp.arange(n, dtype=jnp.int32)
    s = src.shape[0]
    reached = jnp.zeros((s, n), bool).at[jnp.arange(s), src].set(
        g.nodes[src])
    dist = jnp.where(reached, 0, INF)
    adj_f = g.adj.astype(jnp.float32)

    def body(state):
        reached, dist, d, _ = state
        nxt = (reached.astype(jnp.float32) @ adj_f) > 0
        new = nxt & ~reached
        dist = jnp.where(new, d + 1, dist)
        return reached | new, dist, d + 1, jnp.any(new)

    def cond(state):
        _, _, d, changed = state
        return changed & (d < max_iters)

    _, dist, _, _ = jax.lax.while_loop(
        cond, body, (reached, dist, jnp.int32(0), jnp.bool_(True)))
    dist = jnp.where(dist >= INF, -1, dist)  # unreachable
    ecc = jnp.max(dist, axis=1)
    ecc = jnp.where(g.nodes[src], ecc, -1)
    return jnp.max(ecc)


def triangle_count(g: DenseGraph) -> jax.Array:
    a = g.adj.astype(jnp.float32)
    return (jnp.trace(a @ a @ a) / 6.0).astype(jnp.int32)


@partial(jax.jit, static_argnames=("iters",))
def pagerank(g: DenseGraph, iters: int = 20, damp: float = 0.85):
    """Power iteration on the degree-normalized adjacency."""
    n_valid = jnp.maximum(g.num_nodes(), 1).astype(jnp.float32)
    deg = jnp.maximum(g.degrees().astype(jnp.float32), 1.0)
    a = g.adj.astype(jnp.float32) / deg[:, None]
    r = jnp.where(g.nodes, 1.0 / n_valid, 0.0)

    def body(_, r):
        r2 = damp * (r @ a) + (1.0 - damp) / n_valid
        return jnp.where(g.nodes, r2, 0.0)

    return jax.lax.fori_loop(0, iters, body, r)


# Registry: name -> (fn, scope). Node-centric fns take (g, v).
NODE_MEASURES = {
    "degree": degree,
    "neighborhood2": neighborhood_size,
    "induced_avg_degree": induced_avg_degree,
}
GLOBAL_MEASURES = {
    "num_nodes": num_nodes,
    "num_edges": num_edges,
    "density": density,
    "avg_degree": avg_degree,
    "num_components": num_components,
    "diameter": diameter,
    "triangles": triangle_count,
    "degree_distribution": degree_distribution,
}


# ---------------------------------------------------------------------------
# Edge-slot-layout measures (segment reductions — O(E + N), no N² state)
# ---------------------------------------------------------------------------
#
# Each mirrors the dense measure's arithmetic exactly: the integer
# counts are the same values, and the float finalizations are the same
# f32 expressions of those integers, so edge-layout results bit-match
# the dense layout (tests/test_engine.py, tests/test_property.py).


def edge_degree(g: EdgeGraph, v) -> jax.Array:
    return g.degree(v)


def edge_num_nodes(g: EdgeGraph) -> jax.Array:
    return g.num_nodes()


def edge_num_edges(g: EdgeGraph) -> jax.Array:
    # slots hold each undirected edge once — the popcount equals the
    # dense sum(adj) // 2 exactly
    return g.num_edges()


def edge_density(g: EdgeGraph) -> jax.Array:
    n = g.num_nodes().astype(jnp.float32)
    e = g.num_edges().astype(jnp.float32)
    return jnp.where(n > 1, 2.0 * e / (n * (n - 1.0)), 0.0)


def edge_avg_degree(g: EdgeGraph) -> jax.Array:
    n = jnp.maximum(g.num_nodes(), 1).astype(jnp.float32)
    return 2.0 * g.num_edges().astype(jnp.float32) / n


def edge_degree_distribution(g: EdgeGraph,
                             max_deg: int = DEGREE_DIST_BINS) -> jax.Array:
    """Degree histogram without the N² adjacency: the shared bincount
    over the slot-registry degrees (``EdgeGraph.degrees`` is the
    validity-masked segment-sum over ``eu``/``ev``).  The integer
    counts equal the dense row-sum degrees exactly, so the histogram
    bit-matches ``degree_distribution``."""
    return _degree_histogram(g.degrees(), g.nodes, max_deg)


EDGE_NODE_MEASURES = {
    "degree": edge_degree,
}
EDGE_GLOBAL_MEASURES = {
    "num_nodes": edge_num_nodes,
    "num_edges": edge_num_edges,
    "density": edge_density,
    "avg_degree": edge_avg_degree,
    "degree_distribution": edge_degree_distribution,
}


def edge_supported(measure: str, scope: str) -> bool:
    """True iff the measure has an edge-slot-layout implementation."""
    table = EDGE_NODE_MEASURES if scope == "node" else EDGE_GLOBAL_MEASURES
    return measure in table
